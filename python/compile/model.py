"""Layer-2 JAX model: a small MoE transformer with externalised KV cache.

The model is decomposed into *shard-granular* entry points so the Rust
coordinator can drive real expert parallelism: the attention/gating prefix of
every layer is one executable (weights are runtime inputs, so a single
executable serves all layers), each expert's SwiGLU FFN is a separate
executable invoked with whichever expert weights live on the owning simulated
device, and the Rust router performs dispatch/combine between them. A
monolithic ``decode_step_full`` (which routes through the Pallas MoE kernel)
is also exported for calibration and for cross-checking the composed path.

All entry points are pure functions of flat tensor arguments — no closed-over
parameters — so the AOT artifacts can be fed weights owned by the Rust HMM.
"""

import functools

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import moe_ffn, attn_decode
from .kernels.ref import ref_gate


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-5):
    """RMSNorm over the last dimension."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, pos, theta=10000.0):
    """Rotary position embedding.

    Args:
      x: ``[..., H, dh]`` queries or keys.
      pos: integer positions broadcastable to ``x.shape[:-2]``.
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos[..., None, None].astype(jnp.float32) * freqs  # [..., 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def gate(x, w_gate, top_k):
    """Top-k softmax gate with renormalisation -> dense combine weights."""
    return ref_gate(x, w_gate, top_k)


def expert_ffn(x, w1, w3, w2):
    """One expert's SwiGLU MLP — the per-shard executable the Rust EP router
    invokes on the device owning this expert."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


# ---------------------------------------------------------------------------
# Layer prefix: attention + residual + gate (shared across EP shards)
# ---------------------------------------------------------------------------

def attn_gate_decode(cfg: ModelConfig, x, lens, ln1, wq, wk, wv, wo, ln2,
                     w_gate, k_cache, v_cache):
    """Decode-step attention + gating prefix of one layer.

    Args:
      x: ``[B, D]`` layer input.
      lens: ``[B]`` int32 sequence lengths *including* the current token.
      k_cache/v_cache: ``[B, S, H, dh]`` caches holding the previous
        ``lens-1`` tokens; the current token's K/V are computed here.

    Returns:
      ``(h, xn2, cw, k_new, v_new)`` where ``h = x + attn_out`` is the
      residual carried to the expert combine, ``xn2 = rmsnorm(h)`` feeds the
      experts, ``cw [B, E]`` are combine weights, and ``k_new/v_new
      [B, H, dh]`` must be persisted into the cache at position ``lens-1``.
    """
    b = x.shape[0]
    h_, dh = cfg.n_heads, cfg.head_dim
    xn1 = rmsnorm(x, ln1, cfg.norm_eps)
    q = (xn1 @ wq).reshape(b, h_, dh)
    k = (xn1 @ wk).reshape(b, h_, dh)
    v = (xn1 @ wv).reshape(b, h_, dh)
    pos = lens - 1
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    # Insert the current token's K/V at its position, then attend (Pallas).
    idx = jnp.arange(b)
    kc = k_cache.at[idx, pos].set(k)
    vc = v_cache.at[idx, pos].set(v)
    attn = attn_decode(q, kc, vc, lens)                  # [B, H, dh]
    out = attn.reshape(b, h_ * dh) @ wo
    h = x + out
    xn2 = rmsnorm(h, ln2, cfg.norm_eps)
    cw = gate(xn2, w_gate, cfg.top_k)
    return h, xn2, cw, k, v


def attn_gate_prefill(cfg: ModelConfig, x, lens, ln1, wq, wk, wv, wo, ln2,
                      w_gate):
    """Prefill attention + gating prefix of one layer.

    Args:
      x: ``[B, P, D]`` padded prompt activations.
      lens: ``[B]`` valid prompt lengths (<= P).

    Returns:
      ``(h, xn2, cw, k, v)`` with ``k/v [B, P, H, dh]`` to persist into the
      cache (positions >= lens[b] are padding).
    """
    b, p, d = x.shape
    h_, dh = cfg.n_heads, cfg.head_dim
    xn1 = rmsnorm(x, ln1, cfg.norm_eps)
    q = (xn1 @ wq).reshape(b, p, h_, dh)
    k = (xn1 @ wk).reshape(b, p, h_, dh)
    v = (xn1 @ wv).reshape(b, p, h_, dh)
    pos = jnp.arange(p)[None, :].repeat(b, axis=0)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    scale = 1.0 / (dh ** 0.5)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    causal = jnp.arange(p)[None, :] <= jnp.arange(p)[:, None]   # [q, k]
    valid = jnp.arange(p)[None, None, :] < lens[:, None, None]  # [b, 1, k]
    mask = causal[None, None, :, :] & valid[:, None, :, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    out = attn.reshape(b, p, h_ * dh) @ wo
    h = x + out
    xn2 = rmsnorm(h, ln2, cfg.norm_eps)
    cw = gate(xn2.reshape(b * p, d), w_gate, cfg.top_k).reshape(
        b, p, cfg.n_experts
    )
    return h, xn2, cw, k, v


def embed(emb, ids):
    """Token embedding lookup (decode: ``[B]``, prefill: ``[B, P]``)."""
    return jnp.take(emb, ids, axis=0)


def final_logits(x, ln_f, emb, eps=1e-5):
    """Final RMSNorm + tied-embedding output projection."""
    return rmsnorm(x, ln_f, eps) @ emb.T


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

LAYER_TENSORS = ("ln1", "wq", "wk", "wv", "wo", "ln2", "w_gate",
                 "w1", "w3", "w2")


def layer_shapes(cfg: ModelConfig):
    d, qkv, f, e = cfg.d_model, cfg.qkv_dim, cfg.d_ff, cfg.n_experts
    return {
        "ln1": (d,), "wq": (d, qkv), "wk": (d, qkv), "wv": (d, qkv),
        "wo": (qkv, d), "ln2": (d,), "w_gate": (d, e),
        "w1": (e, d, f), "w3": (e, d, f), "w2": (e, f, d),
    }


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic parameter initialisation (scaled normal)."""
    key = jax.random.key(seed)
    n_tensors = 2 + cfg.n_layers * len(LAYER_TENSORS)
    keys = iter(jax.random.split(key, n_tensors))

    def dense(k, shape):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)

    params = {
        "emb": jax.random.normal(next(keys), (cfg.vocab, cfg.d_model),
                                 jnp.float32) * 0.02,
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": [],
    }
    shapes = layer_shapes(cfg)
    for _ in range(cfg.n_layers):
        layer = {}
        for name in LAYER_TENSORS:
            k = next(keys)
            if name.startswith("ln"):
                layer[name] = jnp.ones(shapes[name], jnp.float32)
            else:
                layer[name] = dense(k, shapes[name])
        params["layers"].append(layer)
    return params


# ---------------------------------------------------------------------------
# Monolithic steps (Pallas MoE kernel on the hot path)
# ---------------------------------------------------------------------------

def moe_block(cfg: ModelConfig, h, xn2, cw, w1, w3, w2):
    """Expert combine via the Pallas grouped-FFN kernel."""
    t = xn2.shape[0]
    tile = min(128, max(8, t))
    y = moe_ffn(xn2, w1, w3, w2, cw, token_tile=tile)
    return h + y


def decode_step(cfg: ModelConfig, params, ids, lens, k_caches, v_caches):
    """Full single decode step over all layers (monolithic path).

    Args:
      ids: ``[B]`` current token ids.
      lens: ``[B]`` lengths including the current token.
      k_caches/v_caches: lists of ``[B, S, H, dh]`` per layer.

    Returns:
      ``(logits, k_news, v_news)``.
    """
    x = embed(params["emb"], ids)
    k_news, v_news = [], []
    for li, layer in enumerate(params["layers"]):
        h, xn2, cw, k_new, v_new = attn_gate_decode(
            cfg, x, lens, layer["ln1"], layer["wq"], layer["wk"],
            layer["wv"], layer["wo"], layer["ln2"], layer["w_gate"],
            k_caches[li], v_caches[li])
        x = moe_block(cfg, h, xn2, cw, layer["w1"], layer["w3"], layer["w2"])
        k_news.append(k_new)
        v_news.append(v_new)
    logits = final_logits(x, params["ln_f"], params["emb"], cfg.norm_eps)
    return logits, k_news, v_news


def prefill(cfg: ModelConfig, params, ids, lens):
    """Full prefill over all layers (monolithic path).

    Args:
      ids: ``[B, P]`` padded prompt token ids.
      lens: ``[B]`` valid prompt lengths.

    Returns:
      ``(logits_last, k_caches, v_caches)`` where ``logits_last [B, V]`` are
      the logits at each sequence's final prompt token and the caches are
      ``[B, P, H, dh]`` per layer.
    """
    b, p = ids.shape
    x = embed(params["emb"], ids)
    ks, vs = [], []
    for layer in params["layers"]:
        h, xn2, cw, k, v = attn_gate_prefill(
            cfg, x, lens, layer["ln1"], layer["wq"], layer["wk"],
            layer["wv"], layer["wo"], layer["ln2"], layer["w_gate"])
        d = x.shape[-1]
        x = moe_block(cfg, h.reshape(b * p, d), xn2.reshape(b * p, d),
                      cw.reshape(b * p, cfg.n_experts),
                      layer["w1"], layer["w3"], layer["w2"]).reshape(b, p, d)
        ks.append(k)
        vs.append(v)
    last = jnp.take_along_axis(
        x, (lens - 1)[:, None, None].repeat(x.shape[-1], axis=2), axis=1
    )[:, 0]
    logits = final_logits(last, params["ln_f"], params["emb"], cfg.norm_eps)
    return logits, ks, vs


# ---------------------------------------------------------------------------
# Composed-path reference (mirrors exactly what the Rust engine does)
# ---------------------------------------------------------------------------

def composed_decode_step(cfg: ModelConfig, params, ids, lens, k_caches,
                         v_caches):
    """Decode step composed the way the Rust EP router composes artifacts:
    per-layer attention prefix, then per-expert FFN executables combined in
    ascending expert order. Used to validate that the composed execution is
    numerically equivalent to the monolithic Pallas path."""
    x = embed(params["emb"], ids)
    k_news, v_news = [], []
    for li, layer in enumerate(params["layers"]):
        h, xn2, cw, k_new, v_new = attn_gate_decode(
            cfg, x, lens, layer["ln1"], layer["wq"], layer["wk"],
            layer["wv"], layer["wo"], layer["ln2"], layer["w_gate"],
            k_caches[li], v_caches[li])
        y = jnp.zeros_like(h)
        for e in range(cfg.n_experts):
            ye = expert_ffn(xn2, layer["w1"][e], layer["w3"][e],
                            layer["w2"][e])
            y = y + ye * cw[:, e:e + 1]
        x = h + y
        k_news.append(k_new)
        v_news.append(v_new)
    logits = final_logits(x, params["ln_f"], params["emb"], cfg.norm_eps)
    return logits, k_news, v_news
