"""Single-step (decode) KV-cache attention Pallas kernel.

Each decode step attends one query token per sequence against that sequence's
KV cache, masked to the sequence's current length. The grid iterates
``(batch, head)``; each program streams one head's cache slice ``[S, dh]``
into VMEM, computes masked scores, a numerically-stable softmax, and the
weighted value sum.

VMEM working set per program: ``2*S*dh + 2*dh + S`` f32 words — for the e2e
config (S=256, dh=64) about 132 KB, trivially double-bufferable.

``interpret=True``: see kernels/__init__.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, scale):
    q = q_ref[0, 0]            # [dh]
    k = k_ref[0, :, 0, :]      # [S, dh]
    v = v_ref[0, :, 0, :]      # [S, dh]
    n = len_ref[0]             # scalar current length (includes this token)

    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # [S]
    positions = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    s = jnp.where(positions < n, s, -1e30)
    # Stable softmax over the masked scores.
    m = jnp.max(s)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p)
    o_ref[0, 0] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=())
def attn_decode(q, k_cache, v_cache, lens):
    """Masked decode attention over a padded KV cache.

    Args:
      q: ``[B, H, dh]`` current-step queries.
      k_cache: ``[B, S, H, dh]`` key cache, padded to S; position ``lens[b]-1``
        holds the current token's key.
      v_cache: ``[B, S, H, dh]`` value cache.
      lens: ``[B]`` int32 valid lengths (including the current token).

    Returns:
      ``[B, H, dh]`` attention outputs.
    """
    b, h, dh = q.shape
    _, s, _, _ = k_cache.shape
    assert k_cache.shape == (b, s, h, dh) and v_cache.shape == (b, s, h, dh)
    assert lens.shape == (b,)
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(_attn_decode_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda bi, hi: (bi, hi, 0)),
            pl.BlockSpec((1, s, 1, dh), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, s, 1, dh), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1,), lambda bi, hi: (bi,)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda bi, hi: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=True,
    )(q, k_cache, v_cache, lens)
