"""Pure-jnp oracles for the Pallas kernels and the gating function.

These are the correctness ground truth: every kernel must match its oracle
under the hypothesis sweeps in python/tests/, and aot.py uses them to emit
golden outputs for the Rust integration tests.
"""

import jax
import jax.numpy as jnp


def ref_moe_ffn(x, w1, w3, w2, combine_weights):
    """Dense reference MoE FFN: loop over experts in index order.

    Matches the kernel's accumulation order (expert 0 first) so float32
    results agree to tight tolerance.
    """
    t, d = x.shape
    e = w1.shape[0]
    out = jnp.zeros((t, d), dtype=jnp.float32)
    for ei in range(e):
        h = jax.nn.silu(x @ w1[ei]) * (x @ w3[ei])
        y = h @ w2[ei]
        out = out + y * combine_weights[:, ei:ei + 1]
    return out.astype(x.dtype)


def ref_attn_decode(q, k_cache, v_cache, lens):
    """Reference masked decode attention."""
    b, h, dh = q.shape
    s = k_cache.shape[1]
    scale = 1.0 / (dh ** 0.5)
    # [B, H, S] scores
    scores = jnp.einsum("bhd,bshd->bhs", q, k_cache) * scale
    mask = jnp.arange(s)[None, None, :] < lens[:, None, None]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v_cache).astype(q.dtype)


def ref_gate(x, w_gate, top_k):
    """Reference top-k softmax gate with renormalisation.

    Returns ``[T, E]`` combine weights, zero outside the top-k set — the
    same representation the kernel and the Rust router consume.

    Implementation note: top-k is computed by iterated argmax rather than
    ``jax.lax.top_k`` — lax.top_k lowers to an HLO `topk` instruction with a
    ``largest=`` attribute that the runtime's xla_extension 0.5.1 text
    parser rejects; iterated argmax lowers to plain reduce/select ops.
    Argmax tie-breaking (lowest index) matches lax.top_k's ordering.
    """
    logits = x @ w_gate                       # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    e = probs.shape[-1]
    cw = jnp.zeros_like(probs)
    remaining = probs
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)            # [T]
        onehot = jax.nn.one_hot(idx, e, dtype=probs.dtype)
        cw = cw + onehot * probs
        remaining = jnp.where(onehot > 0, -jnp.inf, remaining)
    return cw / jnp.sum(cw, axis=-1, keepdims=True)


def ref_moe_layer(x, w_gate, w1, w3, w2, top_k):
    """Gate + expert FFN, the full MoE layer oracle."""
    cw = ref_gate(x, w_gate, top_k)
    return ref_moe_ffn(x, w1, w3, w2, cw), cw
