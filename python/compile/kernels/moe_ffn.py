"""Grouped MoE expert-FFN Pallas kernel (the paper's compute hot-spot).

The paper's serving stack spends its FFN time in expert-parallel SwiGLU MLPs:
tokens are routed to ``top_k`` of ``E`` experts, each selected expert applies

    y_e = (silu(x @ w1[e]) * (x @ w3[e])) @ w2[e]

and results are combined with the (renormalised) gate weights.

GPU implementations gather tokens per expert and launch per-expert GEMMs from
thread blocks. On TPU we re-think this as a *masked dense dispatch*: the grid
iterates ``(expert, token_tile)``, every program streams one token tile plus
one expert's weights from HBM into VMEM, runs full-tile MXU matmuls, scales by
that expert's combine weight column (zero for tokens not routed there) and
accumulates into the output tile. This trades ``E/top_k`` overcompute for
fully dense MXU work and no gather/scatter — the standard TPU formulation.

VMEM working set per program (f32): ``BT*D + 2*D*F + F*D + 2*BT*F + BT*D``
bytes/4; for the e2e config (BT=128, D=256, F=512) that is ~1.4 MB, far under
the ~16 MB VMEM budget and double-bufferable. See DESIGN.md §8.

``interpret=True`` everywhere: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Token-tile size. 128 keeps the MXU's 128x128 systolic array full along the
# token dimension while bounding the VMEM working set.
DEFAULT_TOKEN_TILE = 128


def _moe_ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, cw_ref, o_ref):
    """One (expert, token-tile) program of the masked dense dispatch."""
    e = pl.program_id(0)
    x = x_ref[...]            # [BT, D]   token tile
    w1 = w1_ref[0]            # [D, F]    this expert's gate projection
    w3 = w3_ref[0]            # [D, F]    this expert's up projection
    w2 = w2_ref[0]            # [F, D]    this expert's down projection
    cw = cw_ref[...]          # [BT, 1]   combine weight column for expert e

    # SwiGLU expert MLP, full-tile matmuls (MXU-shaped on real hardware).
    h = jax.nn.silu(jnp.dot(x, w1, preferred_element_type=jnp.float32))
    h = h * jnp.dot(x, w3, preferred_element_type=jnp.float32)
    y = jnp.dot(h, w2, preferred_element_type=jnp.float32) * cw

    # The output tile is revisited once per expert (grid dim 0 is outermost);
    # initialise on the first visit, accumulate afterwards.
    @pl.when(e == 0)
    def _init():
        o_ref[...] = y.astype(o_ref.dtype)

    @pl.when(e != 0)
    def _accum():
        o_ref[...] = o_ref[...] + y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("token_tile",))
def moe_ffn(x, w1, w3, w2, combine_weights, *, token_tile=DEFAULT_TOKEN_TILE):
    """Dense-dispatch MoE FFN.

    Args:
      x: ``[T, D]`` tokens (post attention + RMSNorm).
      w1: ``[E, D, F]`` per-expert SwiGLU gate projections.
      w3: ``[E, D, F]`` per-expert SwiGLU up projections.
      w2: ``[E, F, D]`` per-expert down projections.
      combine_weights: ``[T, E]`` gate combine weights; zero for experts a
        token was not routed to (this encodes both routing and scaling).
      token_tile: token-tile size; ``T`` is padded up to a multiple of it.

    Returns:
      ``[T, D]`` combined expert outputs, same dtype as ``x``.
    """
    t, d = x.shape
    e, _, f = w1.shape
    assert w1.shape == (e, d, f) and w3.shape == (e, d, f)
    assert w2.shape == (e, f, d)
    assert combine_weights.shape == (t, e)

    bt = min(token_tile, max(t, 1))
    pad = (-t) % bt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        combine_weights = jnp.pad(combine_weights, ((0, pad), (0, 0)))
    tp = t + pad
    grid = (e, tp // bt)

    out = pl.pallas_call(
        _moe_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda ei, ti: (ti, 0)),      # x tile
            pl.BlockSpec((1, d, f), lambda ei, ti: (ei, 0, 0)),  # w1[e]
            pl.BlockSpec((1, d, f), lambda ei, ti: (ei, 0, 0)),  # w3[e]
            pl.BlockSpec((1, f, d), lambda ei, ti: (ei, 0, 0)),  # w2[e]
            pl.BlockSpec((bt, 1), lambda ei, ti: (ti, ei)),      # cw[:, e]
        ],
        out_specs=pl.BlockSpec((bt, d), lambda ei, ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, d), x.dtype),
        interpret=True,
    )(x, w1, w3, w2, combine_weights)
    return out[:t]
