"""Layer-1 Pallas kernels for the ElasticMoE reproduction.

Both kernels are authored for the TPU execution model (VMEM tiles feeding the
MXU, BlockSpec expressing the HBM->VMEM schedule) but are lowered with
``interpret=True`` so the resulting HLO runs on the CPU PJRT plugin used by
the Rust runtime. See DESIGN.md §Hardware-Adaptation.
"""

from .moe_ffn import moe_ffn, DEFAULT_TOKEN_TILE
from .attention import attn_decode

__all__ = ["moe_ffn", "attn_decode", "DEFAULT_TOKEN_TILE"]
