"""Model configuration for the compile path.

``E2E`` is the real model the repository serves end-to-end through PJRT: a
small MoE transformer (~14.5M parameters) with the same structural shape as
the paper's models (shared attention + gated SwiGLU experts, top-k routing).
The paper's 16B/30B/671B models are represented on the Rust side as
*accounting configs* (rust/src/config) that drive the memory/timing model;
this config drives the live numerics.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    head_dim: int
    d_ff: int          # per-expert SwiGLU hidden dim
    n_experts: int
    top_k: int
    max_seq: int       # padded KV-cache length (decode)
    prefill_len: int   # padded prompt length (prefill artifacts)
    batch: int         # compiled decode batch size
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    def param_count(self) -> int:
        d, f = self.d_model, self.d_ff
        attn = 4 * d * self.qkv_dim            # wq, wk, wv, wo
        experts = self.n_experts * 3 * d * f   # w1, w3, w2 per expert
        gate = d * self.n_experts
        norms = 2 * d
        per_layer = attn + experts + gate + norms
        return self.vocab * d + self.n_layers * per_layer + d  # + final norm


# The end-to-end model: small enough to interpret-execute quickly on CPU,
# structurally identical to the paper's MoE models.
E2E = ModelConfig(
    name="elastic-moe-e2e",
    vocab=2048,
    d_model=256,
    n_layers=4,
    n_heads=4,
    head_dim=64,
    d_ff=512,
    n_experts=8,
    top_k=2,
    max_seq=256,
    prefill_len=64,
    batch=8,
)

# A miniature config used by the python test-suite for fast full-model checks.
TINY = ModelConfig(
    name="tiny",
    vocab=64,
    d_model=32,
    n_layers=2,
    n_heads=2,
    head_dim=16,
    d_ff=48,
    n_experts=4,
    top_k=2,
    max_seq=32,
    prefill_len=8,
    batch=2,
)
