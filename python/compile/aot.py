"""AOT compile path: lower the L2 model to HLO text artifacts for Rust.

Run once via ``make artifacts``. Emits into ``artifacts/``:

- ``<name>.hlo.txt``   — HLO **text** per entry point (NOT ``.serialize()``:
  jax >= 0.5 emits protos with 64-bit instruction ids which the runtime's
  xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
  cleanly — see /opt/xla-example/README.md).
- ``weights/*.bin``    — raw little-endian f32 weight tensors. The Rust HMM's
  ``disk_copy`` primitive loads these, mirroring the paper's disk->HBM path.
- ``manifest.json``    — model dims + per-artifact argument/output specs +
  weight index, consumed by ``rust/src/runtime/artifacts.rs``.
- ``golden.json``      — a deterministic prefill + multi-step decode trace
  (tokens and first-step logits) computed with the composed path the Rust
  engine replicates; the Rust integration tests must match it.

Python never runs at serving time: after this script completes, the Rust
binary is self-contained.
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .config import E2E, ModelConfig


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dtype_name(d):
    return jnp.dtype(d).name


class ArtifactWriter:
    def __init__(self, out_dir: str, cfg: ModelConfig):
        self.out_dir = out_dir
        self.cfg = cfg
        self.artifacts = []
        self.weights = []
        os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)

    def add(self, name, fn, args, arg_names, out_names):
        """Lower ``fn`` at ``args`` specs and record its interface."""
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *args)
        flat_outs = jax.tree.leaves(outs)
        assert len(flat_outs) == len(out_names), (name, len(flat_outs),
                                                  out_names)
        self.artifacts.append({
            "name": name,
            "file": fname,
            "args": [
                {"name": n, "dtype": _dtype_name(a.dtype),
                 "shape": list(a.shape)}
                for n, a in zip(arg_names, args)
            ],
            "outputs": [
                {"name": n, "dtype": _dtype_name(o.dtype),
                 "shape": list(o.shape)}
                for n, o in zip(out_names, flat_outs)
            ],
        })
        print(f"  lowered {name}: {len(text)} chars")

    def add_weight(self, name, array):
        arr = np.asarray(array, dtype=np.float32)
        fname = f"weights/{name}.bin"
        path = os.path.join(self.out_dir, fname)
        arr.tofile(path)
        self.weights.append({
            "name": name,
            "file": fname,
            "shape": list(arr.shape),
            "dtype": "float32",
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
        })


def export_weights(w: ArtifactWriter, params):
    w.add_weight("emb", params["emb"])
    w.add_weight("ln_f", params["ln_f"])
    for li, layer in enumerate(params["layers"]):
        for name in M.LAYER_TENSORS:
            if name in ("w1", "w3", "w2"):
                # Expert tensors are exported per expert: they are the unit
                # of EP migration in the Rust HMM (one vpage run each).
                for e in range(w.cfg.n_experts):
                    w.add_weight(f"layer{li}.{name}.e{e}", layer[name][e])
            else:
                w.add_weight(f"layer{li}.{name}", layer[name])


def export_artifacts(w: ArtifactWriter):
    cfg = w.cfg
    b, p, s = cfg.batch, cfg.prefill_len, cfg.max_seq
    v, d, e, f = cfg.vocab, cfg.d_model, cfg.n_experts, cfg.d_ff
    h, dh, qkv = cfg.n_heads, cfg.head_dim, cfg.qkv_dim
    i32 = jnp.int32

    attn_args = [spec((d,)), spec((d, qkv)), spec((d, qkv)), spec((d, qkv)),
                 spec((qkv, d)), spec((d,)), spec((d, e))]
    attn_names = ["ln1", "wq", "wk", "wv", "wo", "ln2", "w_gate"]

    w.add("embed_decode",
          lambda emb, ids: M.embed(emb, ids),
          [spec((v, d)), spec((b,), i32)],
          ["emb", "ids"], ["x"])

    w.add("embed_prefill",
          lambda emb, ids: M.embed(emb, ids),
          [spec((v, d)), spec((b, p), i32)],
          ["emb", "ids"], ["x"])

    w.add("attn_gate_decode",
          functools.partial(M.attn_gate_decode, cfg),
          [spec((b, d)), spec((b,), i32), *attn_args,
           spec((b, s, h, dh)), spec((b, s, h, dh))],
          ["x", "lens", *attn_names, "k_cache", "v_cache"],
          ["h", "xn2", "cw", "k_new", "v_new"])

    w.add("attn_gate_prefill",
          functools.partial(M.attn_gate_prefill, cfg),
          [spec((b, p, d)), spec((b,), i32), *attn_args],
          ["x", "lens", *attn_names],
          ["h", "xn2", "cw", "k", "v"])

    w.add("expert_ffn_decode",
          M.expert_ffn,
          [spec((b, d)), spec((d, f)), spec((d, f)), spec((f, d))],
          ["x", "w1", "w3", "w2"], ["y"])

    w.add("expert_ffn_prefill",
          M.expert_ffn,
          [spec((b * p, d)), spec((d, f)), spec((d, f)), spec((f, d))],
          ["x", "w1", "w3", "w2"], ["y"])

    w.add("final_logits",
          lambda x, ln_f, emb: M.final_logits(x, ln_f, emb, cfg.norm_eps),
          [spec((b, d)), spec((d,)), spec((v, d))],
          ["x", "ln_f", "emb"], ["logits"])

    # Monolithic decode step (Pallas MoE kernel on the hot path): used for
    # cost-model calibration and as the single-device fast path.
    n_l = cfg.n_layers

    def decode_step_flat(ids, lens, *rest):
        kcs = list(rest[:n_l])
        vcs = list(rest[n_l:2 * n_l])
        emb, ln_f = rest[2 * n_l], rest[2 * n_l + 1]
        layers = []
        off = 2 * n_l + 2
        per = len(M.LAYER_TENSORS)
        for li in range(n_l):
            layers.append(dict(zip(M.LAYER_TENSORS,
                                   rest[off + li * per: off + (li + 1) * per])))
        params = {"emb": emb, "ln_f": ln_f, "layers": layers}
        logits, k_news, v_news = M.decode_step(cfg, params, ids, lens, kcs,
                                               vcs)
        return (logits, *k_news, *v_news)

    shapes = M.layer_shapes(cfg)
    layer_specs, layer_names = [], []
    for li in range(n_l):
        for name in M.LAYER_TENSORS:
            layer_specs.append(spec(shapes[name]))
            layer_names.append(f"layer{li}.{name}")
    w.add("decode_step_full",
          decode_step_flat,
          [spec((b,), i32), spec((b,), i32),
           *([spec((b, s, h, dh))] * (2 * n_l)),
           spec((v, d)), spec((d,)), *layer_specs],
          ["ids", "lens",
           *[f"k_cache{i}" for i in range(n_l)],
           *[f"v_cache{i}" for i in range(n_l)],
           "emb", "ln_f", *layer_names],
          ["logits",
           *[f"k_new{i}" for i in range(n_l)],
           *[f"v_new{i}" for i in range(n_l)]])


def export_golden(out_dir: str, cfg: ModelConfig, params, n_steps=8,
                  seed=1234):
    """Deterministic composed-path trace the Rust engine must reproduce."""
    b, p, s = cfg.batch, cfg.prefill_len, cfg.max_seq
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    ids = jax.random.randint(k1, (b, p), 0, cfg.vocab, jnp.int32)
    lens = jnp.clip(
        jax.random.randint(k2, (b,), p // 2, p + 1, jnp.int32), 2, p)

    logits, ks, vs = M.prefill(cfg, params, ids, lens)
    hd = (cfg.n_heads, cfg.head_dim)
    kc = [jnp.zeros((b, s, *hd), jnp.float32).at[:, :p].set(k) for k in ks]
    vc = [jnp.zeros((b, s, *hd), jnp.float32).at[:, :p].set(v) for v in vs]

    first_logits = logits
    tokens = []
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    cur_lens = lens
    for _ in range(n_steps):
        tokens.append(cur)
        cur_lens = cur_lens + 1
        logits, k_news, v_news = M.composed_decode_step(
            cfg, params, cur, cur_lens, kc, vc)
        idx = jnp.arange(b)
        for li in range(cfg.n_layers):
            kc[li] = kc[li].at[idx, cur_lens - 1].set(k_news[li])
            vc[li] = vc[li].at[idx, cur_lens - 1].set(v_news[li])
        cur = jnp.argmax(logits, -1).astype(jnp.int32)

    golden = {
        "seed": seed,
        "n_steps": n_steps,
        "prompt_ids": np.asarray(ids).tolist(),
        "prompt_lens": np.asarray(lens).tolist(),
        "tokens": np.asarray(jnp.stack(tokens)).tolist(),  # [n_steps, B]
        "prefill_logits_row0": np.asarray(first_logits[0]).tolist(),
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print(f"  golden: {n_steps} steps, batch {b}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts output directory")
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()
    cfg = E2E
    out = args.out
    os.makedirs(out, exist_ok=True)

    print(f"model {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    params = M.init_params(cfg, seed=0)

    w = ArtifactWriter(out, cfg)
    export_weights(w, params)
    export_artifacts(w)
    if not args.skip_golden:
        export_golden(out, cfg, params)

    manifest = {
        "model": {
            "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim, "d_ff": cfg.d_ff,
            "n_experts": cfg.n_experts, "top_k": cfg.top_k,
            "max_seq": cfg.max_seq, "prefill_len": cfg.prefill_len,
            "batch": cfg.batch, "param_count": cfg.param_count(),
        },
        "layer_tensors": list(M.LAYER_TENSORS),
        "artifacts": w.artifacts,
        "weights": w.weights,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(w.artifacts)} artifacts, {len(w.weights)} weight "
          f"tensors to {out}/")


if __name__ == "__main__":
    main()
