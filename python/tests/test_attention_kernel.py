"""Hypothesis sweeps: Pallas decode-attention kernel vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attn_decode
from compile.kernels.ref import ref_attn_decode

jax.config.update("jax_platform_name", "cpu")


def _mk(seed, b, h, dh, s):
    key = jax.random.key(seed)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, h, dh), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s, h, dh), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s, h, dh), jnp.float32)
    lens = jax.random.randint(ks[3], (b,), 1, s + 1, jnp.int32)
    return q, kc, vc, lens


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 5),
    h=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([4, 8, 16]),
    s=st.sampled_from([1, 7, 16, 33]),
)
def test_attn_decode_matches_ref(seed, b, h, dh, s):
    q, kc, vc, lens = _mk(seed, b, h, dh, s)
    out = attn_decode(q, kc, vc, lens)
    ref = ref_attn_decode(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_attn_decode_len_one():
    """A sequence of length 1 attends only to itself: output == v[0]."""
    q, kc, vc, _ = _mk(0, 2, 2, 8, 16)
    lens = jnp.ones((2,), jnp.int32)
    out = attn_decode(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vc[:, 0]),
                               rtol=1e-5, atol=1e-5)


def test_attn_decode_full_cache():
    """lens == S uses every cache slot (no masking)."""
    q, kc, vc, _ = _mk(1, 3, 2, 8, 12)
    lens = jnp.full((3,), 12, jnp.int32)
    out = attn_decode(q, kc, vc, lens)
    ref = ref_attn_decode(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_attn_decode_mask_independence():
    """Positions beyond lens must not affect the output."""
    q, kc, vc, _ = _mk(2, 2, 2, 8, 16)
    lens = jnp.array([5, 9], jnp.int32)
    out1 = attn_decode(q, kc, vc, lens)
    # Corrupt the masked region; result must be identical.
    kc2 = kc.at[0, 5:].set(1e4)
    vc2 = vc.at[0, 5:].set(-1e4)
    kc2 = kc2.at[1, 9:].set(1e4)
    vc2 = vc2.at[1, 9:].set(-1e4)
    out2 = attn_decode(q, kc2, vc2, lens)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


def test_attn_decode_softmax_rows():
    """Output lies in the convex hull of the unmasked values (1-D check)."""
    b, h, dh, s = 1, 1, 4, 8
    q, kc, vc, _ = _mk(3, b, h, dh, s)
    lens = jnp.array([4], jnp.int32)
    out = np.asarray(attn_decode(q, kc, vc, lens))[0, 0]
    vals = np.asarray(vc)[0, :4, 0, :]
    assert (out <= vals.max(axis=0) + 1e-5).all()
    assert (out >= vals.min(axis=0) - 1e-5).all()


@pytest.mark.parametrize("s", [1, 256])
def test_attn_decode_seq_extremes(s):
    q, kc, vc, lens = _mk(4, 2, 4, 64, s)
    out = attn_decode(q, kc, vc, lens)
    ref = ref_attn_decode(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
