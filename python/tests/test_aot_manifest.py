"""Validate the AOT artifact bundle consumed by the Rust runtime."""

import hashlib
import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_model_block(manifest):
    m = manifest["model"]
    assert m["n_experts"] >= 2 and m["top_k"] >= 1
    assert m["param_count"] > 10_000_000
    assert m["d_model"] == m["n_heads"] * m["head_dim"]


def test_artifact_files_exist(manifest):
    names = {a["name"] for a in manifest["artifacts"]}
    required = {
        "embed_decode", "embed_prefill", "attn_gate_decode",
        "attn_gate_prefill", "expert_ffn_decode", "expert_ffn_prefill",
        "final_logits", "decode_step_full",
    }
    assert required <= names
    for a in manifest["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        head = open(path).read(200)
        assert "HloModule" in head, f"{a['file']} is not HLO text"


def test_artifact_arg_specs(manifest):
    m = manifest["model"]
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    ag = by_name["attn_gate_decode"]
    assert [a["name"] for a in ag["args"][:2]] == ["x", "lens"]
    assert ag["args"][0]["shape"] == [m["batch"], m["d_model"]]
    assert ag["outputs"][2]["shape"] == [m["batch"], m["n_experts"]]  # cw
    ef = by_name["expert_ffn_decode"]
    assert ef["args"][1]["shape"] == [m["d_model"], m["d_ff"]]


def test_weight_files_and_checksums(manifest):
    m = manifest["model"]
    total = 0
    for w in manifest["weights"][:20] + manifest["weights"][-5:]:
        path = os.path.join(ART, w["file"])
        arr = np.fromfile(path, dtype=np.float32)
        assert arr.size == int(np.prod(w["shape"])), w["name"]
        assert w["sha256"] == hashlib.sha256(arr.tobytes()).hexdigest()
    for w in manifest["weights"]:
        total += int(np.prod(w["shape"]))
    assert total == m["param_count"]


def test_expert_weights_are_per_expert(manifest):
    """Expert tensors must be exported one file per expert — the unit of
    EP migration in the Rust HMM."""
    m = manifest["model"]
    names = {w["name"] for w in manifest["weights"]}
    for li in range(m["n_layers"]):
        for e in range(m["n_experts"]):
            assert f"layer{li}.w1.e{e}" in names
            assert f"layer{li}.w2.e{e}" in names
            assert f"layer{li}.w3.e{e}" in names


def test_golden_trace(manifest):
    with open(os.path.join(ART, "golden.json")) as f:
        g = json.load(f)
    m = manifest["model"]
    b = m["batch"]
    assert len(g["prompt_ids"]) == b
    assert len(g["tokens"]) == g["n_steps"]
    assert all(len(row) == b for row in g["tokens"])
    assert len(g["prefill_logits_row0"]) == m["vocab"]
    assert all(0 <= t < m["vocab"] for row in g["tokens"] for t in row)
