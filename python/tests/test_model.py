"""L2 model invariants: composed path ≡ monolithic path, prefill/decode
consistency, gating properties, parameter accounting."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.config import TINY as cfg
from compile.config import E2E

jax.config.update("jax_platform_name", "cpu")

PARAMS = M.init_params(cfg, seed=0)
HD = (cfg.n_heads, cfg.head_dim)


def _prompt(seed, b=None, p=None):
    b = b or cfg.batch
    p = p or cfg.prefill_len
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    ids = jax.random.randint(k1, (b, p), 0, cfg.vocab, jnp.int32)
    lens = jax.random.randint(k2, (b,), 2, p + 1, jnp.int32)
    return ids, lens


def _pad_caches(ks, vs, b):
    s = cfg.max_seq
    kc = [jnp.zeros((b, s, *HD), jnp.float32).at[:, :ks[0].shape[1]].set(k)
          for k in ks]
    vc = [jnp.zeros((b, s, *HD), jnp.float32).at[:, :vs[0].shape[1]].set(v)
          for v in vs]
    return kc, vc


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_monolithic_equals_composed(seed):
    """The Pallas-kernel decode step must equal the per-expert composed path
    the Rust EP router executes."""
    ids, lens = _prompt(seed)
    _, ks, vs = M.prefill(cfg, PARAMS, ids, lens)
    kc, vc = _pad_caches(ks, vs, cfg.batch)
    cur = jnp.zeros((cfg.batch,), jnp.int32)
    l1, ka, va = M.decode_step(cfg, PARAMS, cur, lens + 1, kc, vc)
    l2, kb, vb = M.composed_decode_step(cfg, PARAMS, cur, lens + 1, kc, vc)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-4, atol=1e-4)
    for a, b_ in zip(ka + va, kb + vb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)


def test_prefill_decode_consistency():
    """prefill(n tokens) + decode(token n) == prefill(n+1 tokens) logits."""
    b, p = 2, cfg.prefill_len
    key = jax.random.key(7)
    full_ids = jax.random.randint(key, (b, p), 0, cfg.vocab, jnp.int32)
    n = p - 1
    lens_n = jnp.full((b,), n, jnp.int32)
    # Path A: prefill the first n tokens, then decode token n.
    _, ks, vs = M.prefill(cfg, PARAMS, full_ids.at[:, n:].set(0), lens_n)
    kc, vc = _pad_caches(ks, vs, b)
    logits_a, _, _ = M.decode_step(cfg, PARAMS, full_ids[:, n],
                                   lens_n + 1, kc, vc)
    # Path B: prefill all n+1 tokens at once.
    logits_b, _, _ = M.prefill(cfg, PARAMS, full_ids,
                               jnp.full((b,), n + 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b),
                               rtol=2e-3, atol=2e-3)


def test_prefill_padding_invariance():
    """Tokens beyond lens must not influence the valid-token logits."""
    ids, lens = _prompt(3, b=2)
    lens = jnp.minimum(lens, cfg.prefill_len - 2)
    l1, ks1, _ = M.prefill(cfg, PARAMS, ids, lens)
    ids2 = ids.at[:, -1].set((ids[:, -1] + 17) % cfg.vocab)
    l2, ks2, _ = M.prefill(cfg, PARAMS, ids2, lens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


def test_gate_renormalised_topk():
    x = jax.random.normal(jax.random.key(0), (10, cfg.d_model))
    wg = jax.random.normal(jax.random.key(1), (cfg.d_model, cfg.n_experts))
    cw = np.asarray(M.gate(x, wg, cfg.top_k))
    np.testing.assert_allclose(cw.sum(1), np.ones(10), rtol=1e-5)
    assert ((cw > 0).sum(1) == cfg.top_k).all()


def test_rope_rotation_preserves_norm():
    x = jax.random.normal(jax.random.key(2), (3, 4, cfg.head_dim))
    pos = jnp.array([0, 5, 11])
    y = M.rope(x, pos)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        np.asarray(jnp.linalg.norm(y, axis=-1)), rtol=1e-5)
    # Position 0 is the identity rotation.
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(x[0]),
                               rtol=1e-6, atol=1e-6)


def test_rmsnorm_scale_invariant():
    x = jax.random.normal(jax.random.key(3), (4, cfg.d_model))
    w = jnp.ones((cfg.d_model,))
    y1 = M.rmsnorm(x, w)
    y2 = M.rmsnorm(x * 100.0, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-4)


def test_param_count_matches_tree():
    p = M.init_params(cfg, seed=0)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p))
    assert n == cfg.param_count()
    assert E2E.param_count() > 10_000_000  # e2e model is "real-sized"


def test_init_deterministic():
    a = M.init_params(cfg, seed=0)
    b = M.init_params(cfg, seed=0)
    c = M.init_params(cfg, seed=1)
    for xa, xb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    diffs = [float(jnp.abs(xa - xc).max()) > 0
             for xa, xc in zip(jax.tree.leaves(a), jax.tree.leaves(c))
             if xa.ndim > 1]
    assert any(diffs)


def test_greedy_generation_stable():
    """Greedy decode for several steps stays finite and in-vocab."""
    ids, lens = _prompt(11)
    logits, ks, vs = M.prefill(cfg, PARAMS, ids, lens)
    kc, vc = _pad_caches(ks, vs, cfg.batch)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    cur_lens = lens
    idx = jnp.arange(cfg.batch)
    for _ in range(5):
        cur_lens = cur_lens + 1
        logits, kn, vn = M.decode_step(cfg, PARAMS, cur, cur_lens, kc, vc)
        assert bool(jnp.isfinite(logits).all())
        for li in range(cfg.n_layers):
            kc[li] = kc[li].at[idx, cur_lens - 1].set(kn[li])
            vc[li] = vc[li].at[idx, cur_lens - 1].set(vn[li])
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        assert int(cur.max()) < cfg.vocab and int(cur.min()) >= 0
