"""Hypothesis sweeps: Pallas MoE FFN kernel vs pure-jnp oracle.

The kernel is the paper's compute hot-spot; this file is the CORE L1
correctness signal. Shapes, expert counts, top-k, tile sizes and seeds are
all swept; results must match the oracle to tight f32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import moe_ffn
from compile.kernels.ref import ref_gate, ref_moe_ffn

jax.config.update("jax_platform_name", "cpu")


def _mk(seed, t, d, f, e, top_k):
    key = jax.random.key(seed)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    w1 = jax.random.normal(ks[1], (e, d, f), jnp.float32) / np.sqrt(d)
    w3 = jax.random.normal(ks[2], (e, d, f), jnp.float32) / np.sqrt(d)
    w2 = jax.random.normal(ks[3], (e, f, d), jnp.float32) / np.sqrt(f)
    wg = jax.random.normal(ks[4], (d, e), jnp.float32)
    cw = ref_gate(x, wg, top_k)
    return x, w1, w3, w2, cw


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    t=st.integers(1, 33),
    d=st.sampled_from([8, 16, 24]),
    f=st.sampled_from([8, 16, 32]),
    e=st.sampled_from([1, 2, 4, 8]),
    tile=st.sampled_from([4, 8, 16]),
)
def test_moe_ffn_matches_ref(seed, t, d, f, e, tile):
    top_k = min(2, e)
    x, w1, w3, w2, cw = _mk(seed, t, d, f, e, top_k)
    out = moe_ffn(x, w1, w3, w2, cw, token_tile=tile)
    ref = ref_moe_ffn(x, w1, w3, w2, cw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), top_k=st.integers(1, 4))
def test_moe_ffn_topk_sweep(seed, top_k):
    x, w1, w3, w2, cw = _mk(seed, 17, 16, 16, 4, min(top_k, 4))
    out = moe_ffn(x, w1, w3, w2, cw, token_tile=8)
    ref = ref_moe_ffn(x, w1, w3, w2, cw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_moe_ffn_zero_combine_weights():
    """Tokens routed nowhere must produce exactly zero output."""
    x, w1, w3, w2, _ = _mk(0, 12, 16, 16, 4, 2)
    cw = jnp.zeros((12, 4), jnp.float32)
    out = moe_ffn(x, w1, w3, w2, cw, token_tile=4)
    assert float(jnp.abs(out).max()) == 0.0


def test_moe_ffn_single_expert_equals_dense():
    """E=1, top_k=1 degenerates to a plain SwiGLU MLP."""
    x, w1, w3, w2, cw = _mk(3, 16, 16, 32, 1, 1)
    np.testing.assert_allclose(np.asarray(cw), np.ones((16, 1)), atol=1e-6)
    out = moe_ffn(x, w1, w3, w2, cw, token_tile=8)
    dense = (jax.nn.silu(x @ w1[0]) * (x @ w3[0])) @ w2[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_moe_ffn_padding_path():
    """T not a multiple of the tile exercises the pad/unpad wrapper."""
    x, w1, w3, w2, cw = _mk(7, 13, 16, 16, 4, 2)
    out = moe_ffn(x, w1, w3, w2, cw, token_tile=8)
    ref = ref_moe_ffn(x, w1, w3, w2, cw)
    assert out.shape == (13, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_moe_ffn_e2e_shape():
    """The exact tile/shape configuration the AOT artifacts use."""
    from compile.config import E2E as cfg
    t = cfg.batch
    x, w1, w3, w2, cw = _mk(11, t, cfg.d_model, cfg.d_ff, cfg.n_experts,
                            cfg.top_k)
    out = moe_ffn(x, w1, w3, w2, cw, token_tile=min(128, t))
    ref = ref_moe_ffn(x, w1, w3, w2, cw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-5, atol=5e-5)


@pytest.mark.parametrize("t", [1, 2, 128, 129])
def test_moe_ffn_token_extremes(t):
    x, w1, w3, w2, cw = _mk(5, t, 16, 16, 4, 2)
    out = moe_ffn(x, w1, w3, w2, cw, token_tile=128)
    ref = ref_moe_ffn(x, w1, w3, w2, cw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_combine_weights_properties():
    """Gate output: rows sum to 1, exactly top_k nonzeros, all >= 0."""
    x, *_ , cw = _mk(9, 40, 16, 16, 8, 2)
    cw = np.asarray(cw)
    np.testing.assert_allclose(cw.sum(axis=1), np.ones(40), rtol=1e-5)
    assert ((cw > 0).sum(axis=1) == 2).all()
    assert (cw >= 0).all()
