//! Minimal command-line parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

/// Parsed command line: subcommand-style positionals + options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut iter = iter.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get_usize(name, default as usize) as u64
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects a number, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Option/flag names the user passed that are not in `accepted`
    /// (sorted, deduplicated). Subcommands use this to reject typos —
    /// a silently ignored `--sede 7` is worse than an error.
    pub fn unexpected(&self, accepted: &[&str]) -> Vec<String> {
        let mut out: Vec<String> = self
            .options
            .keys()
            .filter(|k| !accepted.contains(&k.as_str()))
            .cloned()
            .chain(
                self.flags
                    .iter()
                    .filter(|f| !accepted.contains(&f.as_str()))
                    .cloned(),
            )
            .collect();
        out.sort();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("exp fig7 --model dsv2lite --steps=3 --verbose --rps 2.5");
        assert_eq!(a.subcommand(), Some("exp"));
        assert_eq!(a.positional[1], "fig7");
        assert_eq!(a.get("model"), Some("dsv2lite"));
        assert_eq!(a.get_usize("steps", 0), 3);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_f64("rps", 1.0), 2.5);
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.get_or("addr", "127.0.0.1"), "127.0.0.1");
        assert_eq!(a.get_usize("devices", 4), 4);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --dry-run");
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn unexpected_reports_unknown_options_and_flags() {
        let a = parse("exp chaos --fast --sede 7 --bogus");
        assert_eq!(
            a.unexpected(&["fast", "seed"]),
            vec!["bogus".to_string(), "sede".to_string()]
        );
        assert!(a
            .unexpected(&["fast", "sede", "bogus"])
            .is_empty());
    }
}
