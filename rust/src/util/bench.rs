//! Micro/endtoend bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and call [`Bench::run`] /
//! [`time_fn`] directly. Reports mean/p50/p99 and optional throughput.

use std::time::Instant;

use super::stats::{mean, percentile, std};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            super::fmt_secs(self.mean_s),
            super::fmt_secs(self.p50_s),
            super::fmt_secs(self.p99_s),
        )
    }

    /// items/sec at the measured mean.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }
}

/// Bench runner with warmup.
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            iters: 20,
        }
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Bench {
            warmup_iters,
            iters,
        }
    }

    /// Quick-mode override: `BENCH_FAST=1` shrinks iteration counts so the
    /// full suite stays fast in CI.
    pub fn from_env(warmup: usize, iters: usize) -> Self {
        if std::env::var("BENCH_FAST").is_ok() {
            Bench::new(1, 3.min(iters))
        } else {
            Bench::new(warmup, iters)
        }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_s: mean(&samples),
            std_s: std(&samples),
            p50_s: percentile(&samples, 50.0),
            p99_s: percentile(&samples, 99.0),
            min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        };
        println!("{}", result.report());
        result
    }
}

/// One-shot timing of a closure, returning (elapsed seconds, value).
pub fn time_fn<T, F: FnOnce() -> T>(f: F) -> (f64, T) {
    let t0 = Instant::now();
    let v = f();
    (t0.elapsed().as_secs_f64(), v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let b = Bench::new(1, 5);
        let r = b.run("noop-spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0 && r.mean_s < 0.1);
        assert!(r.p99_s >= r.p50_s);
    }

    #[test]
    fn time_fn_returns_value() {
        let (t, v) = time_fn(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}
