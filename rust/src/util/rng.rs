//! Deterministic PRNG + distributions (the `rand` crate is unavailable).
//!
//! Xoshiro256** seeded via SplitMix64 — the standard pairing. Experiments
//! seed every run explicitly so all paper tables are reproducible bit-for-bit.

/// Xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box-Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng {
            s,
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-device / per-request rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponential inter-arrival sample with the given rate (events/sec).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let mut u = self.f64();
        if u < 1e-300 {
            u = 1e-300;
        }
        -u.ln() / rate
    }

    /// Poisson sample (Knuth for small lambda, normal approx for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let z = self.normal();
            return (lambda + z * lambda.sqrt()).round().max(0.0) as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Pick a random element index weighted by `weights`.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)`.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.range(3, 7);
            assert!((3..=7).contains(&n));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(4);
        for &lambda in &[0.5, 4.0, 80.0] {
            let n = 50_000;
            let total: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = total as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let total: f64 = (0..n).map(|_| r.exponential(4.0)).sum();
        let mean = total / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(7);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
