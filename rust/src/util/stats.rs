//! Summary statistics used by the metrics layer and the bench harness.

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample set (nearest-rank on a sorted copy). Callers
/// that query several percentiles of the same sample set should sort once
/// and use [`percentile_sorted`] instead.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Nearest-rank percentile over an **already ascending-sorted** sample
/// set. NaN when empty.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank =
        ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

pub fn std(samples: &[f64]) -> f64 {
    if samples.len() < 2 {
        return 0.0;
    }
    let m = mean(samples);
    (samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (samples.len() - 1) as f64)
        .sqrt()
}

/// Fixed-bucket histogram for latency distributions.
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// Exponential bucket boundaries from `lo` to `hi`.
    pub fn exponential(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / (n - 1) as f64);
        let bounds = (0..n).map(|i| lo * ratio.powi(i as i32)).collect();
        Histogram {
            bounds,
            counts: vec![0; n + 1],
            total: 0,
        }
    }

    pub fn record(&mut self, x: f64) {
        let idx = self.bounds.partition_point(|&b| b < x);
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Fraction of samples at or below `x`.
    pub fn cdf(&self, x: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let idx = self.bounds.partition_point(|&b| b <= x);
        let below: u64 = self.counts[..=idx.min(self.counts.len() - 1)]
            .iter()
            .sum();
        below as f64 / self.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 10.0);
        assert_eq!(r.count(), 5);
    }

    #[test]
    fn percentile_basics() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        let p50 = percentile(&xs, 50.0);
        assert!((50.0..=51.0).contains(&p50));
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_sorted_matches_percentile() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&xs, p), percentile_sorted(&sorted, p));
        }
        assert!(percentile_sorted(&[], 50.0).is_nan());
    }

    #[test]
    fn histogram_cdf() {
        let mut h = Histogram::exponential(0.001, 10.0, 20);
        for i in 1..=1000 {
            h.record(i as f64 / 100.0); // 0.01 .. 10.0
        }
        assert_eq!(h.total(), 1000);
        assert!(h.cdf(10.0) > 0.99);
        // Exponential buckets are coarse near the top of the range; the
        // bucket containing 5.0 spans ~3.8..6.2, so allow that slack.
        let half = h.cdf(5.0);
        assert!((0.38..0.65).contains(&half), "{half}");
    }
}
