//! Self-contained utilities replacing crates unavailable in the offline
//! environment (see DESIGN.md §1): JSON, CLI parsing, logging, PRNG,
//! statistics, a mini property-test harness, a bench harness, and table
//! rendering for experiment reports.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod proplite;
pub mod rng;
pub mod stats;
pub mod table;

/// Format a byte count using binary units (GiB shown as "GB" to match the
/// paper's tables).
pub fn fmt_bytes(bytes: u64) -> String {
    const KB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= KB * KB * KB {
        format!("{:.1} GB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.1} MB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.1} KB", b / KB)
    } else {
        format!("{bytes} B")
    }
}

/// Format a duration given in seconds with adaptive precision.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0} s")
    } else if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.1} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KB");
        assert_eq!(fmt_bytes(64 * 1024 * 1024 * 1024), "64.0 GB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(120.0), "120 s");
        assert_eq!(fmt_secs(2.43), "2.43 s");
        assert_eq!(fmt_secs(0.0042), "4.2 ms");
    }
}
