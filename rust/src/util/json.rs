//! Minimal JSON parser/serializer (serde_json is unavailable offline).
//!
//! Supports the full JSON grammar needed by the artifact manifest, golden
//! traces, and experiment reports: objects, arrays, strings with escapes,
//! numbers, booleans, null. Numbers are held as f64 (adequate: the manifest
//! carries shapes and checksum strings, not 64-bit ids).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error produced by [`parse`].
#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// Array index access; returns `Json::Null` out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(idx)).unwrap_or(&NULL)
    }
    /// Convenience: expected-usize vector from an array of numbers.
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        // Surrogate pairs.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let d = self
                                    .bump()
                                    .and_then(|c| (c as char).to_digit(16))
                                    .ok_or_else(|| {
                                        self.err("bad \\u escape")
                                    })?;
                                low = low * 16 + d;
                            }
                            code = 0x10000
                                + ((code - 0xD800) << 10)
                                + (low - 0xDC00);
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        self.pos = start + len;
                        if self.pos > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        s.push_str(
                            std::str::from_utf8(&self.bytes[start..self.pos])
                                .map_err(|_| self.err("bad utf-8"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": null,
                      "d": true, "e": {"nested": []}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").at(1).as_f64(), Some(2.5));
        assert_eq!(v.get("a").at(2).as_f64(), Some(-300.0));
        assert_eq!(v.get("b").as_str(), Some("hi\nthere"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("d").as_bool(), Some(true));
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""Aé😀snow☃""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé😀snow☃"));
        let raw = parse("\"caf\u{e9} \u{1F600}\"").unwrap();
        assert_eq!(raw.as_str(), Some("café 😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} extra").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn big_int_fidelity() {
        let v = parse("1234567890123").unwrap();
        assert_eq!(v.as_u64(), Some(1234567890123));
        assert_eq!(v.to_string(), "1234567890123");
    }

    #[test]
    fn accessor_defaults() {
        let v = parse(r#"{"x": 1}"#).unwrap();
        assert_eq!(v.get("missing"), &Json::Null);
        assert_eq!(v.get("x").at(3), &Json::Null);
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![
            ("name", Json::str("fig7")),
            ("rows", Json::arr([Json::num(1.0), Json::num(2.0)])),
        ]);
        assert_eq!(v.to_string(), r#"{"name":"fig7","rows":[1,2]}"#);
    }
}
