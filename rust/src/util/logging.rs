//! Stderr logger backend for the `log` facade (env_logger is unavailable).
//!
//! Level is controlled by the `ELASTIC_LOG` environment variable
//! (`error|warn|info|debug|trace`, default `info`).

use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static INSTALLED: AtomicBool = AtomicBool::new(false);
static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let target = record.target().rsplit("::").next().unwrap_or("");
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{tag} {target}] {}", record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Honors `ELASTIC_LOG`.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("ELASTIC_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
