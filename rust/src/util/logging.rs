//! Stderr logger backend for the `log` facade (env_logger is unavailable).
//!
//! Level is controlled by the `ELASTIC_LOG` environment variable
//! (`error|warn|info|debug|trace|off`, default `info`; `off` silences
//! the logger entirely). An unrecognized value falls back to `info` and
//! warns once, naming the bad value and the accepted set.

use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static INSTALLED: AtomicBool = AtomicBool::new(false);
static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let target = record.target().rsplit("::").next().unwrap_or("");
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{tag} {target}] {}", record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Honors `ELASTIC_LOG`.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let var = std::env::var("ELASTIC_LOG");
    let mut unknown: Option<&str> = None;
    let level = match var.as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("info") => LevelFilter::Info,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        Ok(other) => {
            unknown = Some(other);
            LevelFilter::Info
        }
        Err(_) => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
    if let Some(bad) = unknown {
        log::warn!(
            "unknown ELASTIC_LOG value '{bad}', using 'info' \
             (accepted: error|warn|info|debug|trace|off)"
        );
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
