//! Aligned plain-text table rendering for experiment reports (the paper's
//! tables and figure series are printed as rows).

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table {
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn header<S: Into<String>, I: IntoIterator<Item = S>>(
        mut self,
        cols: I,
    ) -> Self {
        self.header = cols.into_iter().map(Into::into).collect();
        self
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cols: I) {
        self.rows.push(cols.into_iter().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = cells.get(i).map(|s| s.as_str()).unwrap_or("");
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{cell:<w$}"));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            let total: usize =
                widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with fixed decimals, used across experiment rows.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Format "mean ± std".
pub fn pm(mean: f64, std: f64, decimals: usize) -> String {
    format!("{mean:.decimals$} ± {std:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment() {
        let mut t = Table::new("demo").header(["method", "time (s)"]);
        t.row(["ElasticMoE", "2.43"]);
        t.row(["Vertical (Cold Restart)", "67.40"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "== demo ==");
        assert!(lines[1].starts_with("method"));
        // Columns align: "2.43" and "67.40" start at the same offset.
        let off1 = lines[3].find("2.43").unwrap();
        let off2 = lines[4].find("67.40").unwrap();
        assert_eq!(off1, off2);
    }

    #[test]
    fn helpers() {
        assert_eq!(f(2.434, 2), "2.43");
        assert_eq!(pm(2.43, 0.1, 2), "2.43 ± 0.10");
    }
}
