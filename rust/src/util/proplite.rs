//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Runs a property over many deterministically-seeded random cases and, on
//! failure, reports the case seed so the exact input can be replayed:
//!
//! ```no_run
//! use elastic_moe::util::proplite::check;
//! check("sort is idempotent", 200, |rng| {
//!     let n = rng.range(0, 50);
//!     let mut v: Vec<u64> = (0..n).map(|_| rng.below(100)).collect();
//!     v.sort_unstable();
//!     let once = v.clone();
//!     v.sort_unstable();
//!     assert_eq!(once, v);
//! });
//! ```
//!
//! Set `PROPLITE_SEED=<n>` to replay one specific case of every property.

use super::rng::Rng;

/// Base seed; mixed with the case index per case.
const BASE_SEED: u64 = 0xE1A5_71C0_0E5E_ED42;

/// Run `cases` random cases of `prop`. Panics (with the failing seed) on the
/// first failure. Properties express failure by panicking (assert!).
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    if let Ok(s) = std::env::var("PROPLITE_SEED") {
        let seed: u64 = s.parse().expect("PROPLITE_SEED must be an integer");
        let mut rng = Rng::new(BASE_SEED ^ seed);
        prop(&mut rng);
        return;
    }
    for case in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                let mut rng = Rng::new(BASE_SEED ^ case);
                prop(&mut rng);
            },
        ));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case} \
                 (replay with PROPLITE_SEED={case}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("counts", 50, |_rng| {
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("fails on 7", 20, |rng| {
                // deterministic per-case value
                let x = rng.below(20);
                assert!(x != 13, "x was 13");
            });
        });
        // Some case will draw 13 with ~64% probability over 20 cases; to be
        // deterministic we just check the harness propagates panics when
        // they happen, and passes otherwise.
        if let Err(p) = result {
            let msg = p.downcast_ref::<String>().unwrap();
            assert!(msg.contains("PROPLITE_SEED="), "{msg}");
        }
    }
}
