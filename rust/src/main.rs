//! `repro` — ElasticMoE reproduction CLI (the L3 leader entrypoint).
//!
//! Subcommands:
//! - `exp <id>|all [--fast]` — regenerate a paper table/figure (reports/).
//! - `serve [--model M] [--devices N] [--rps R] [--duration S]
//!   [--method elastic|cold|extravagant|colocated] [--autoscale]` — run the
//!   serving simulator and print SLO/throughput stats.
//! - `bench [--json] [--fast]` — machine-readable perf trajectory
//!   (steady-state tok/s, TTFT p99, scale-up latency per method, event
//!   core vs windowed reference); `--json` writes `BENCH_serve.json` and
//!   `BENCH_hotpath.json` for CI to archive.
//! - `report <id>` — run an experiment fully instrumented and render
//!   the byte-deterministic postmortem markdown (attainment timelines,
//!   scaling-event cost split, decision ledger, replay bundles); or
//!   `report ingest --trace F` to render from exported artifacts
//!   (`docs/architecture/11-reporting.md`).
//! - `info` — models, artifact manifest, cluster defaults.
//!
//! Unknown `--options` are rejected with the accepted set — a typo'd
//! `--sede 7` silently running the default seed would poison replays.

use anyhow::{bail, Context, Result};

use elastic_moe::config::model;
use elastic_moe::config::SloConfig;
use elastic_moe::coordinator::{LoadEstimator, ServingSim, Trigger};
use elastic_moe::device::Timings;
use elastic_moe::engine::CostModel;
use elastic_moe::experiments::{self, ExpOptions};
use elastic_moe::util::cli::Args;
use elastic_moe::util::json::Json;
use elastic_moe::util::{fmt_bytes, logging};
use elastic_moe::workload::{RateProfile, WorkloadGen, WorkloadSpec};

fn main() {
    logging::init();
    let args = Args::from_env();
    let result = match args.subcommand() {
        Some("exp") => cmd_exp(&args),
        Some("serve") => cmd_serve(&args),
        Some("bench") => cmd_bench(&args),
        Some("report") => cmd_report(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "repro — ElasticMoE reproduction\n\
         \n\
         USAGE:\n\
         repro exp <id>|all|list [--fast] [--seed N]\n\
         \x20                                  regenerate paper tables/figures\n\
         repro serve [options]              run the serving simulator\n\
         repro bench [--json] [--fast]      perf trajectory (steady tok/s,\n\
         \x20                                  TTFT p99, scale-up latency per\n\
         \x20                                  method, event core vs windowed\n\
         \x20                                  reference); --json writes\n\
         \x20                                  BENCH_serve.json and\n\
         \x20                                  BENCH_hotpath.json\n\
         repro report <id> [options]        postmortem markdown for an\n\
         \x20                                  instrumented run: attainment\n\
         \x20                                  timelines + burn rate, scaling\n\
         \x20                                  cost split, decision ledger,\n\
         \x20                                  replay bundles (ids: chaos,\n\
         \x20                                  disagg, reconcile)\n\
         repro report ingest --trace F      same, from exported artifacts\n\
         repro info                         model and artifact inventory\n\
         \n\
         Unknown --options are errors; each subcommand prints its\n\
         accepted set.\n\
         \n\
         exp options (parsed once, shared by every experiment):\n\
         --fast          smaller scenario set / shorter horizons\n\
         --seed N        workload + fault-schedule seed (chaos/fleet/\n\
         \x20               tier/reconcile/disagg); a failing chaos,\n\
         \x20               reconcile or disagg cell prints the seed to\n\
         \x20               replay it\n\
         --trace-out F   write a Chrome trace-event JSON of the first\n\
         \x20               simulated run (experiments that run a serving\n\
         \x20               simulator; others ignore it)\n\
         --metrics-out F write Prometheus-style text exposition of the\n\
         \x20               first simulated run\n\
         \n\
         serve options:\n\
         --model dsv2lite|qwen30b|dsv3   (default dsv2lite)\n\
         --method elastic|cold|extravagant|colocated (default elastic)\n\
         --devices N     initial devices (default 4)\n\
         --cluster N     total cluster devices (default 2x devices)\n\
         --rps R         request rate (default 2.0)\n\
         --duration S    seconds of traffic (default 120)\n\
         --seed N        workload seed (default 42)\n\
         --scale-at S    manual scale-up (+2 devices) at time S\n\
         --autoscale     SLO-driven autoscaling instead of manual\n\
         --fast          short 30s run (CI smoke preset)\n\
         --trace-out F   write a Chrome trace-event JSON of the run\n\
         \x20               (load in Perfetto / chrome://tracing)\n\
         --metrics-out F write Prometheus-style text exposition\n\
         \n\
         report options:\n\
         --fast          run the experiment's fast matrix\n\
         --seed N        run seed (default 23, the canonical one)\n\
         --out F         write the markdown to F instead of stdout\n\
         --trace F       (ingest) a --trace-out artifact or raw trace JSON\n\
         --metrics F     (ingest) a --metrics-out Prometheus exposition"
    );
}

/// Reject option/flag names the subcommand does not accept.
fn reject_unknown(args: &Args, cmd: &str, accepted: &[&str]) -> Result<()> {
    let bad = args.unexpected(accepted);
    if bad.is_empty() {
        return Ok(());
    }
    let list = |names: &[String]| {
        names
            .iter()
            .map(|n| format!("--{n}"))
            .collect::<Vec<_>>()
            .join(", ")
    };
    let accepted: Vec<String> =
        accepted.iter().map(|a| a.to_string()).collect();
    bail!(
        "unknown option{} for `repro {cmd}`: {}; accepted: {}",
        if bad.len() == 1 { "" } else { "s" },
        list(&bad),
        if accepted.is_empty() {
            "(none)".to_string()
        } else {
            list(&accepted)
        }
    )
}

fn cmd_exp(args: &Args) -> Result<()> {
    reject_unknown(
        args,
        "exp",
        &["fast", "seed", "trace-out", "metrics-out"],
    )?;
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("list");
    let opts = ExpOptions::from_args(args)?;
    match id {
        "list" => {
            println!("experiments: {}", experiments::ALL.join(" "));
            Ok(())
        }
        "all" => {
            for id in experiments::ALL {
                println!("—— {id} ————————————————————————");
                println!("{}", experiments::run_with(id, &opts)?);
            }
            println!("reports written to reports/");
            Ok(())
        }
        id => {
            println!("{}", experiments::run_with(id, &opts)?);
            Ok(())
        }
    }
}

/// `repro bench [--json] [--fast]`: the machine-readable perf
/// trajectory future PRs regress against — steady-state decode
/// throughput and TTFT p99 on a fixed serving run, scale-up latency per
/// method on the canonical 4→6 transition, and the event core vs the
/// retained windowed reference. `--json` writes `BENCH_serve.json` and
/// `BENCH_hotpath.json` (CI archives both as artifacts).
fn cmd_bench(args: &Args) -> Result<()> {
    use elastic_moe::experiments::common::{make_method, par, par_on};
    use elastic_moe::scaling::ScalingMethod as _;

    reject_unknown(args, "bench", &["json", "fast"])?;
    let fast = args.flag("fast");
    let m = model::dsv2_lite();
    let slo = SloConfig::strict();

    // Steady-state serving: 4 devices, fixed 2 rps.
    let duration = if fast { 60.0 } else { 120.0 };
    let sim = ServingSim::new(
        CostModel::new(m.clone(), Timings::cloudmatrix()),
        slo,
    );
    let mut gen = WorkloadGen::new(WorkloadSpec {
        prompt_len: 2000,
        decode_min: 200,
        decode_max: 300,
        profile: RateProfile::Fixed(2.0),
        seed: 42,
    });
    let arrivals = gen.arrivals_until(duration);
    let mut method = make_method("elastic", &m, 4)?;
    let initial = par(&m, 4)?;
    let out = sim.run(
        method.as_mut(),
        &initial,
        arrivals,
        Trigger::Manual(vec![]),
        duration,
    )?;
    let w = out.recorder.window(0.0, out.end_time + 1e-6, &slo);
    println!(
        "steady (dsv2lite, 4 devices, 2 rps, {duration}s): \
         {:.0} tok/s, TTFT p99 {:.3}s, SLO {:.1}%",
        w.tokens_per_sec,
        w.p99_ttft,
        w.slo_attainment * 100.0
    );

    // Scale-up latency per method, canonical 4→6 transition (Horizontal
    // adds a same-size replica; Extravagant needs fresh devices).
    let mut scale_rows: Vec<(&str, f64)> = Vec::new();
    for name in ["elastic", "cold", "extravagant", "colocated", "horizontal"]
    {
        let mut meth = make_method(name, &m, 12)?;
        meth.boot(&par(&m, 4)?)?;
        let target = match name {
            // Fresh 6-device set (old 4 + new 6 both held at peak).
            "extravagant" => par_on(&m, 4..10)?,
            // Horizontal adds a whole replica of the base size.
            "horizontal" => par_on(&m, 4..8)?,
            _ => par(&m, 6)?,
        };
        let ev = meth.scale(&target)?;
        println!("scale-up {name:<12} {:.2}s", ev.ready_after);
        scale_rows.push((name, ev.ready_after));
    }

    // Event core vs the retained windowed reference on the same sparse
    // trace (events/sec; the event core must not lose).
    let cores = elastic_moe::coordinator::compare_cores(fast)?;
    println!(
        "core loop: event {:.0} ev/s vs windowed {:.0} ev/s \
         ({:.2}x, outputs match: {})",
        cores.event_events_per_sec(),
        cores.windowed_events_per_sec(),
        cores.speedup(),
        cores.outputs_match()
    );

    // Telemetry tax: the same serving run with the registry off vs on
    // (must be determinism-neutral; budget is < 5% events/sec).
    let overhead = elastic_moe::coordinator::telemetry_overhead(fast)?;
    println!(
        "telemetry: {:+.1}% wall overhead (neutral: {})",
        overhead.overhead_frac() * 100.0,
        overhead.neutral()
    );

    if args.flag("json") {
        let doc = Json::obj(vec![
            ("model", Json::str(m.name)),
            ("fast", Json::Bool(fast)),
            (
                "steady",
                Json::obj(vec![
                    ("devices", Json::num(4.0)),
                    ("rps", Json::num(2.0)),
                    ("duration_s", Json::num(duration)),
                    ("tokens_per_sec", Json::num(w.tokens_per_sec)),
                    ("ttft_p99_s", Json::num(w.p99_ttft)),
                    ("slo_attainment", Json::num(w.slo_attainment)),
                ]),
            ),
            (
                "scale_up_latency_s",
                Json::Obj(
                    scale_rows
                        .iter()
                        .map(|&(n, t)| (n.to_string(), Json::num(t)))
                        .collect(),
                ),
            ),
        ]);
        std::fs::write("BENCH_serve.json", format!("{doc}\n"))?;
        println!("wrote BENCH_serve.json");
        let mut hot = cores.to_json();
        if let Json::Obj(map) = &mut hot {
            map.insert("telemetry_overhead".to_string(), overhead.to_json());
        }
        std::fs::write("BENCH_hotpath.json", format!("{hot}\n"))?;
        println!("wrote BENCH_hotpath.json");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    reject_unknown(
        args,
        "serve",
        &[
            "model",
            "method",
            "devices",
            "cluster",
            "rps",
            "duration",
            "seed",
            "scale-at",
            "autoscale",
            "fast",
            "trace-out",
            "metrics-out",
        ],
    )?;
    let model_name = args.get_or("model", "dsv2lite");
    let m = model::by_name(model_name)
        .with_context(|| format!("unknown model '{model_name}'"))?;
    let method_name = args.get_or("method", "elastic");
    let devices = args.get_usize("devices", 4);
    let cluster_n = args.get_usize("cluster", devices * 2);
    let rps = args.get_f64("rps", 2.0);
    let fast = args.flag("fast");
    let duration =
        args.get_f64("duration", if fast { 30.0 } else { 120.0 });
    let seed = args.get_u64("seed", 42);
    let trace_out = args.get("trace-out").map(str::to_string);
    let metrics_out = args.get("metrics-out").map(str::to_string);

    if devices % m.tp != 0 {
        bail!("--devices must be a multiple of TP{}", m.tp);
    }
    let mut method =
        elastic_moe::experiments::common::make_method(method_name, &m, cluster_n)?;
    let slo = SloConfig::strict();
    let mut sim = ServingSim::new(
        CostModel::new(m.clone(), Timings::cloudmatrix()),
        slo,
    );
    sim.obs = trace_out.is_some() || metrics_out.is_some();
    let mut gen = WorkloadGen::new(WorkloadSpec {
        prompt_len: 2000,
        decode_min: 200,
        decode_max: 300,
        profile: RateProfile::Fixed(rps),
        seed,
    });
    let arrivals = gen.arrivals_until(duration);
    let n_arrived = arrivals.len();

    let tp = m.tp;
    let trigger = if args.flag("autoscale") {
        Trigger::Auto {
            estimator: LoadEstimator::new(slo),
            up: Box::new(move |p| {
                let n = p.n_devices() + tp;
                elastic_moe::config::ParallelConfig::standard(
                    n / tp,
                    tp,
                    (0..n).collect(),
                )
                .ok()
            }),
            down: Box::new(move |p| {
                let n = p.n_devices().checked_sub(tp)?;
                if n == 0 {
                    return None;
                }
                elastic_moe::config::ParallelConfig::standard(
                    n / tp,
                    tp,
                    (0..n).collect(),
                )
                .ok()
            }),
        }
    } else if let Some(at) = args.get("scale-at") {
        let at: f64 = at.parse().context("--scale-at")?;
        let target = elastic_moe::experiments::common::par(&m, devices + m.tp)?;
        Trigger::Manual(vec![(at, target)])
    } else {
        Trigger::Manual(vec![])
    };

    let initial = elastic_moe::experiments::common::par(&m, devices)?;
    println!(
        "serving {model_name} with {method_name}: {} devices, {rps} rps, {duration}s",
        devices
    );
    let out = sim.run(method.as_mut(), &initial, arrivals, trigger, duration)?;

    let w = out.recorder.window(0.0, out.end_time + 1e-6, &slo);
    println!("\n== results ==");
    println!("requests: {n_arrived} arrived, {} completed, {} dropped",
        w.completed, w.dropped);
    println!("throughput: {:.2} req/s  {:.0} tok/s",
        w.throughput_rps, w.tokens_per_sec);
    println!("SLO attainment: {:.1}%  (TTFT<=1s, TPOT<=1s)",
        w.slo_attainment * 100.0);
    println!("TTFT mean {:.3}s p99 {:.3}s  TPOT mean {:.4}s",
        w.mean_ttft, w.p99_ttft, w.mean_tpot);
    for ev in &out.scaling_events {
        println!(
            "scaling: {} in {:.2}s (downtime {:.2}s, peak {:.1} GB)",
            ev.metrics.label(),
            ev.ready_after,
            ev.metrics.downtime,
            ev.metrics.peak_gb()
        );
    }
    println!("device timeline: {:?}", out.device_timeline);
    if let Some(tel) = &out.telemetry {
        if let Some(path) = &trace_out {
            elastic_moe::obs::export::write_trace(tel, path)?;
            println!("wrote {path} (Chrome trace-event JSON)");
        }
        if let Some(path) = &metrics_out {
            elastic_moe::obs::export::write_metrics(tel, path)?;
            println!("wrote {path} (Prometheus exposition)");
        }
    }
    Ok(())
}

/// `repro report <id> [--fast] [--seed N] [--out F]`, or
/// `repro report ingest --trace F [--metrics F] [--out F]`: render the
/// postmortem markdown (see `docs/architecture/11-reporting.md`). The
/// output is byte-deterministic for a given seed — two runs diff clean.
fn cmd_report(args: &Args) -> Result<()> {
    reject_unknown(
        args,
        "report",
        &["fast", "seed", "out", "trace", "metrics"],
    )?;
    let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    let text = if id == "ingest" {
        let trace_path = args.get("trace").ok_or_else(|| {
            anyhow::anyhow!(
                "`repro report ingest` needs --trace <file> (a \
                 --trace-out artifact or a raw trace JSON)"
            )
        })?;
        let trace_text = std::fs::read_to_string(trace_path)
            .with_context(|| format!("reading {trace_path}"))?;
        let metrics_text = match args.get("metrics") {
            Some(p) => Some(
                std::fs::read_to_string(p)
                    .with_context(|| format!("reading {p}"))?,
            ),
            None => None,
        };
        let input = elastic_moe::report::ingest(
            trace_path,
            &trace_text,
            metrics_text.as_deref(),
        )?;
        elastic_moe::report::render(&input)
    } else if id.is_empty() {
        bail!(
            "usage: repro report <chaos|disagg|reconcile> [--fast] \
             [--seed N] [--out FILE]  |  repro report ingest --trace \
             FILE [--metrics FILE] [--out FILE]"
        );
    } else {
        let fast = args.flag("fast");
        let seed = args.get_u64("seed", 23);
        elastic_moe::report::generate(id, seed, fast)?
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)
                .with_context(|| format!("writing {path}"))?;
            println!("wrote {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    reject_unknown(args, "info", &[])?;
    println!("== models ==");
    for name in model::MODELS {
        if let Some(m) = model::by_name(name) {
            println!(
                "{:<10} {:>7.1}B params  {:>6} experts (top-{})  TP{} min {} devices  {}/device at EP{}",
                m.name,
                m.param_count() as f64 / 1e9,
                m.n_experts,
                m.top_k,
                m.tp,
                m.min_devices,
                fmt_bytes(m.device_weight_bytes(m.tp, m.min_devices)),
                m.min_devices,
            );
        }
    }
    let art = std::path::Path::new("artifacts/manifest.json");
    if art.exists() {
        let manifest = elastic_moe::runtime::Manifest::load("artifacts")?;
        println!("\n== artifacts ({}) ==", manifest.model.name);
        for a in &manifest.artifacts {
            println!(
                "{:<22} {} args -> {} outputs",
                a.name,
                a.args.len(),
                a.outputs.len()
            );
        }
        println!(
            "{} weight tensors, {} total",
            manifest.weights.len(),
            fmt_bytes(manifest.total_weight_bytes())
        );
    } else {
        println!("\n(artifacts not built — run `make artifacts`)");
    }
    Ok(())
}
