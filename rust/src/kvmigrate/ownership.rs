//! Block-granular KV ownership: which DP replica's devices hold each live
//! sequence's blocks.
//!
//! The serving engine pools the KV budget of all DP replicas into one
//! [`crate::engine::PagedKv`]; physically, a sequence's blocks live on the
//! `tp` devices of exactly one replica (attention is data-parallel — a
//! sequence never spans replicas). The ownership map recovers that
//! attribution deterministically: request `id` is homed on DP rank
//! `id % dp` (sticky for the request's lifetime, balanced in
//! expectation). A [`KvSnapshot`] captures the map plus per-sequence
//! block tables at the instant a scale command is issued; the planner
//! ([`super::planner`]) classifies each entry against the target
//! configuration.

use crate::config::ParallelConfig;
use crate::device::DeviceId;
use crate::engine::PagedKv;
use crate::workload::RequestId;

/// DP rank whose devices hold `id`'s KV blocks (sticky hash).
pub fn home_rank(id: RequestId, dp: usize) -> usize {
    (id % dp.max(1) as u64) as usize
}

/// The `tp` devices backing DP rank `rank` of `p` (rank-major layout:
/// replica `d` owns `devices[d*tp .. (d+1)*tp]`).
pub fn rank_devices(p: &ParallelConfig, rank: usize) -> &[DeviceId] {
    &p.devices[rank * p.tp..(rank + 1) * p.tp]
}

/// One live sequence's KV footprint at snapshot time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvSeq {
    pub id: RequestId,
    /// Current stored tokens (prompt + generated so far).
    pub len: usize,
    /// Blocks held in the paged pool.
    pub blocks: usize,
    /// DP rank of the owning replica in the *source* configuration.
    pub home_rank: usize,
}

/// Snapshot of every live sequence's KV ownership at a scale command.
#[derive(Debug, Clone)]
pub struct KvSnapshot {
    /// Tokens per block of the underlying pool.
    pub block_tokens: usize,
    /// Live sequences, sorted by request id (deterministic).
    pub seqs: Vec<KvSeq>,
    /// The configuration the blocks currently live on.
    pub from: ParallelConfig,
}

impl KvSnapshot {
    /// Capture the ownership map from a live pool.
    pub fn capture(kv: &PagedKv, from: &ParallelConfig) -> Self {
        let seqs = kv
            .sequences()
            .into_iter()
            .map(|(id, len, blocks)| KvSeq {
                id,
                len,
                blocks,
                home_rank: home_rank(id, from.dp),
            })
            .collect();
        KvSnapshot {
            block_tokens: kv.block_tokens(),
            seqs,
            from: from.clone(),
        }
    }

    /// An empty snapshot (no live sequences) on `from`.
    pub fn empty(from: &ParallelConfig) -> Self {
        KvSnapshot {
            block_tokens: 16,
            seqs: Vec::new(),
            from: from.clone(),
        }
    }

    /// Total blocks held by live sequences — the conservation baseline
    /// the migration plan must account for exactly.
    pub fn total_blocks(&self) -> usize {
        self.seqs.iter().map(|s| s.blocks).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn par(dp: usize, tp: usize) -> ParallelConfig {
        ParallelConfig::standard(dp, tp, (0..dp * tp).collect()).unwrap()
    }

    #[test]
    fn home_rank_is_sticky_and_balanced() {
        let dp = 4;
        let counts = (0..1000u64).fold(vec![0usize; dp], |mut c, id| {
            c[home_rank(id, dp)] += 1;
            c
        });
        assert!(counts.iter().all(|&c| c == 250), "{counts:?}");
        // Sticky: same id, same rank, every time.
        assert_eq!(home_rank(42, dp), home_rank(42, dp));
        // Degenerate dp never panics.
        assert_eq!(home_rank(7, 0), 0);
    }

    #[test]
    fn rank_devices_are_rank_major() {
        let p = par(3, 2);
        assert_eq!(rank_devices(&p, 0), &[0, 1]);
        assert_eq!(rank_devices(&p, 2), &[4, 5]);
    }

    #[test]
    fn capture_attributes_every_sequence() {
        let p = par(2, 2);
        let mut kv = PagedKv::new(100, 16);
        kv.admit(3, 100).unwrap(); // rank 1, 7 blocks
        kv.admit(4, 33).unwrap(); // rank 0, 3 blocks
        let snap = KvSnapshot::capture(&kv, &p);
        assert_eq!(snap.block_tokens, 16);
        assert_eq!(snap.seqs.len(), 2);
        assert_eq!(snap.total_blocks(), kv.used_blocks());
        assert_eq!(
            snap.seqs[0],
            KvSeq { id: 3, len: 100, blocks: 7, home_rank: 1 }
        );
        assert_eq!(
            snap.seqs[1],
            KvSeq { id: 4, len: 33, blocks: 3, home_rank: 0 }
        );
        assert!(KvSnapshot::empty(&p).is_empty());
    }
}
