//! Zero-recompute KV-cache migration across scaling events.
//!
//! ElasticMoE's zero-downtime claim rests on reusing not just weights but
//! the *KV caches of live sequences* across a reconfiguration: "an HBM
//! Management Module reuses weights and KV caches via zero-copy
//! remapping" while P2P transfers bring new devices online. Before this
//! subsystem, the switchover path drained every in-flight sequence and
//! re-prefilled it from scratch on the successor — correct, but it pays a
//! full recompute of every mid-stream context and inflates TTFT through
//! the scaling window.
//!
//! This module makes the per-request block tables of
//! [`crate::engine::PagedKv`] the migratable unit:
//!
//! 1. [`ownership`] — a block-granular ownership map layered on the paged
//!    pool: each live sequence is attributed to the DP replica (device
//!    group) that holds its blocks, captured as a [`KvSnapshot`] at the
//!    scale command.
//! 2. [`planner`] — classifies every sequence for the target
//!    configuration: **remap** (its device group survives → zero-copy via
//!    the same virtual-page machinery experts use), **p2p-copy** (its
//!    group departs → blocks move over the fabric, costed through
//!    [`crate::device::Interconnect`] and charged against the shared
//!    migration-byte budget), or **recompute** (only when re-prefill is
//!    cheaper than the transfer, per [`crate::engine::CostModel`], or the
//!    budget is exhausted). The plan conserves blocks exactly:
//!    `before = remapped + copied + freed`.
//! 3. [`handoff`] — the choreography contract the coordinator enacts:
//!    which sequences suspend decode during the copy window, and how each
//!    drained sequence is disposed of at switchover (adopt with progress
//!    vs. restart).
//!
//! The HMM folds the plan into its scaling plan
//! ([`crate::hmm::HmmControl::plan_scale_with_kv`]) so KV legs ride the
//! same op list, timing model, and byte budget as expert migrations;
//! [`crate::scaling::ElasticMoE`] carries the resulting [`KvHandoff`] in
//! its [`crate::scaling::ScalingOutcome`]. Baselines keep the legacy
//! drain-and-recompute path, so `repro exp kvmigrate` can measure the
//! delta.

pub mod handoff;
pub mod ownership;
pub mod planner;

pub use handoff::{
    HandoffDisposition, KvHandoff, KvHandoffPolicy, KvHandoffStats,
};
pub use ownership::{home_rank, rank_devices, KvSeq, KvSnapshot};
pub use planner::{plan_kv_migration, KvLeg, KvMigrationPlan, KvVerdict};
