//! The live-sequence handoff contract between a scaling method and the
//! serving loop.
//!
//! A [`KvHandoff`] rides in [`crate::scaling::ScalingOutcome`] and tells
//! the coordinator two things: which sequences to *suspend* when the
//! switchover window opens (their KV blocks are in flight and must stay
//! byte-stable), and how to dispose of every drained sequence at
//! switchover — adopt with decode progress intact (remap / copy) or
//! restart from scratch (recompute). Sequences admitted *after* the plan
//! was drawn are not in the per-id lists; they fall back to their home
//! rank's verdict (a surviving rank remaps, a departing one recomputes —
//! such sequences are young, so the recompute is cheap).

use crate::config::ParallelConfig;
use crate::workload::RequestId;

use super::ownership::{home_rank, rank_devices};
use super::planner::{KvMigrationPlan, KvVerdict};

/// How ElasticMoE carries live KV across a scaling event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvHandoffPolicy {
    /// Plan per-sequence remap / p2p-copy / recompute legs (the paper's
    /// zero-copy KV reuse, extended with costed transfers).
    #[default]
    Migrate,
    /// Legacy switchover: drop every in-flight sequence's KV and
    /// re-prefill it on the successor. Kept as the measurable baseline
    /// for `repro exp kvmigrate`.
    DrainRecompute,
}

/// Disposition of one drained sequence at switchover.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandoffDisposition {
    /// Blocks stayed put (device group survives): adopt, zero bytes moved.
    Remap,
    /// Blocks were P2P-copied to a new owner: adopt.
    CopyAdopt,
    /// KV dropped: restart the sequence from scratch.
    Recompute,
}

/// Per-sequence dispositions of one scaling event.
#[derive(Debug, Clone)]
pub struct KvHandoff {
    /// Sequences whose blocks remap in place (sorted by id).
    pub remap: Vec<RequestId>,
    /// Sequences whose blocks are copied over the fabric (sorted by id).
    pub copy: Vec<RequestId>,
    /// Sequences that re-prefill on the successor (sorted by id).
    pub recompute: Vec<RequestId>,
    /// Source-configuration DP degree (for the home-rank fallback).
    pub from_dp: usize,
    /// Per source rank: does its device group survive into the target?
    pub rank_survives: Vec<bool>,
}

impl KvHandoff {
    /// Build a handoff from disposition lists — the single place the
    /// rank-survival (device-group identity) rule is computed. Lists are
    /// sorted here; callers may pass them in any order.
    pub fn new(
        mut remap: Vec<RequestId>,
        mut copy: Vec<RequestId>,
        mut recompute: Vec<RequestId>,
        from: &ParallelConfig,
        to: &ParallelConfig,
    ) -> Self {
        remap.sort_unstable();
        copy.sort_unstable();
        recompute.sort_unstable();
        let rank_survives = (0..from.dp)
            .map(|r| {
                let group = rank_devices(from, r);
                (0..to.dp).any(|tr| rank_devices(to, tr) == group)
            })
            .collect();
        KvHandoff {
            remap,
            copy,
            recompute,
            from_dp: from.dp,
            rank_survives,
        }
    }

    /// Build the handoff from a migration plan.
    pub fn from_plan(plan: &KvMigrationPlan) -> Self {
        let (mut remap, mut copy, mut recompute) =
            (Vec::new(), Vec::new(), Vec::new());
        for leg in &plan.legs {
            match leg.verdict {
                KvVerdict::Remap { .. } => remap.push(leg.id),
                KvVerdict::Copy { .. } => copy.push(leg.id),
                KvVerdict::Recompute => recompute.push(leg.id),
            }
        }
        KvHandoff::new(remap, copy, recompute, &plan.from, &plan.to)
    }

    /// Disposition of one drained sequence. Ids missing from the plan
    /// (admitted after the snapshot) fall back to their home rank's
    /// survival verdict.
    pub fn disposition(&self, id: RequestId) -> HandoffDisposition {
        if self.remap.binary_search(&id).is_ok() {
            return HandoffDisposition::Remap;
        }
        if self.copy.binary_search(&id).is_ok() {
            return HandoffDisposition::CopyAdopt;
        }
        if self.recompute.binary_search(&id).is_ok() {
            return HandoffDisposition::Recompute;
        }
        let rank = home_rank(id, self.from_dp);
        if self.rank_survives.get(rank).copied().unwrap_or(false) {
            HandoffDisposition::Remap
        } else {
            HandoffDisposition::Recompute
        }
    }

    /// Sequences the serving loop must suspend when the switchover window
    /// opens: exactly the copy legs (their bytes are in flight; remapped
    /// sequences keep decoding in place, recompute sequences have nothing
    /// to keep stable).
    pub fn suspend_ids(&self) -> &[RequestId] {
        &self.copy
    }
}

/// What actually happened to in-flight sequences at a switchover —
/// accumulated by the serving simulators across every scaling event of a
/// run, and the quantity `repro exp kvmigrate` compares across methods.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KvHandoffStats {
    /// Sequences adopted with blocks in place under a per-sequence plan.
    pub remapped: usize,
    /// Sequences adopted after a P2P block copy.
    pub copied: usize,
    /// Sequences adopted under a blanket `preserves_inflight` with no
    /// per-sequence plan (methods that keep in-flight work alive without
    /// modelling KV movement — e.g. the Horizontal/Extravagant
    /// baselines). Kept separate from `remapped` so cross-method
    /// comparisons never read false zero-copy-remap activity.
    pub adopted_blanket: usize,
    /// Sequences restarted from scratch.
    pub recomputed: usize,
    /// Prompt tokens re-prefilled because of restarts (the recompute
    /// bill; 0 under a fully zero-recompute handoff).
    pub recompute_tokens: u64,
    /// Decode tokens discarded by restarts (regenerated afterwards).
    pub lost_decode_tokens: u64,
    /// Decode progress carried across events by adopted sequences.
    pub adopted_tokens: u64,
}

impl KvHandoffStats {
    /// Fold another event's stats into this accumulator.
    pub fn merge(&mut self, other: &KvHandoffStats) {
        self.remapped += other.remapped;
        self.copied += other.copied;
        self.adopted_blanket += other.adopted_blanket;
        self.recomputed += other.recomputed;
        self.recompute_tokens += other.recompute_tokens;
        self.lost_decode_tokens += other.lost_decode_tokens;
        self.adopted_tokens += other.adopted_tokens;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParallelConfig;
    use crate::kvmigrate::planner::KvLeg;

    fn par(dp: usize) -> ParallelConfig {
        ParallelConfig::standard(dp, 2, (0..dp * 2).collect()).unwrap()
    }

    fn plan() -> KvMigrationPlan {
        KvMigrationPlan {
            legs: vec![
                KvLeg {
                    id: 1,
                    len: 100,
                    blocks: 7,
                    verdict: KvVerdict::Remap { rank: 1 },
                },
                KvLeg {
                    id: 3,
                    len: 4000,
                    blocks: 250,
                    verdict: KvVerdict::Copy { src_rank: 3, dst_rank: 0 },
                },
                KvLeg {
                    id: 7,
                    len: 40,
                    blocks: 3,
                    verdict: KvVerdict::Recompute,
                },
            ],
            bytes_per_token: 1024,
            from: par(4),
            to: par(3),
        }
    }

    #[test]
    fn dispositions_follow_the_plan() {
        let h = KvHandoff::from_plan(&plan());
        assert_eq!(h.disposition(1), HandoffDisposition::Remap);
        assert_eq!(h.disposition(3), HandoffDisposition::CopyAdopt);
        assert_eq!(h.disposition(7), HandoffDisposition::Recompute);
        assert_eq!(h.suspend_ids(), &[3]);
    }

    #[test]
    fn unknown_ids_fall_back_to_rank_survival() {
        let h = KvHandoff::from_plan(&plan());
        // DP4 -> DP3 on a device prefix: ranks 0..2 survive, 3 departs.
        assert_eq!(h.rank_survives, vec![true, true, true, false]);
        // id 21 ≡ 1 (mod 4): surviving rank → remap.
        assert_eq!(h.disposition(21), HandoffDisposition::Remap);
        // id 23 ≡ 3 (mod 4): departing rank → recompute.
        assert_eq!(h.disposition(23), HandoffDisposition::Recompute);
    }

    #[test]
    fn stats_merge_adds_fields() {
        let mut a = KvHandoffStats {
            remapped: 1,
            copied: 2,
            adopted_blanket: 4,
            recomputed: 3,
            recompute_tokens: 100,
            lost_decode_tokens: 10,
            adopted_tokens: 50,
        };
        a.merge(&a.clone());
        assert_eq!(a.remapped, 2);
        assert_eq!(a.adopted_blanket, 8);
        assert_eq!(a.recompute_tokens, 200);
        assert_eq!(a.adopted_tokens, 100);
    }
}
