//! The KV-migration planner: classify every live sequence for the target
//! configuration as remap / p2p-copy / recompute, under the shared
//! migration-byte budget, conserving blocks exactly.

use crate::config::ParallelConfig;
use crate::device::DeviceId;
use crate::engine::CostModel;

use super::ownership::{rank_devices, KvSnapshot};
use crate::workload::RequestId;

/// How one sequence's KV crosses the scaling event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvVerdict {
    /// Its device group survives unchanged: blocks stay physically put
    /// and the successor adopts them via zero-copy remap (the same
    /// virtual-page mechanism experts use). Zero bytes moved, zero
    /// tokens recomputed.
    Remap {
        /// DP rank in the *target* configuration (same devices).
        rank: usize,
    },
    /// Its device group departs: blocks are P2P-copied, one leg per TP
    /// shard pair, to the least-loaded target replica. Bytes are charged
    /// against the shared migration budget.
    Copy { src_rank: usize, dst_rank: usize },
    /// KV is dropped and the sequence re-prefills on the successor —
    /// chosen only when recompute is cheaper than the transfer
    /// ([`CostModel::kv_prefer_copy`]) or the byte budget is exhausted.
    Recompute,
}

/// One sequence's leg of the migration plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvLeg {
    pub id: RequestId,
    /// Stored tokens at snapshot time.
    pub len: usize,
    /// Blocks held at snapshot time.
    pub blocks: usize,
    pub verdict: KvVerdict,
}

/// The full KV-migration plan for one scaling event.
#[derive(Debug, Clone)]
pub struct KvMigrationPlan {
    pub legs: Vec<KvLeg>,
    /// KV bytes per token of the model (for byte accounting).
    pub bytes_per_token: u64,
    pub from: ParallelConfig,
    pub to: ParallelConfig,
}

impl KvMigrationPlan {
    /// Blocks that stay put and remap (zero-copy).
    pub fn remapped_blocks(&self) -> usize {
        self.count(|v| matches!(v, KvVerdict::Remap { .. }))
    }

    /// Blocks that move over the fabric.
    pub fn copied_blocks(&self) -> usize {
        self.count(|v| matches!(v, KvVerdict::Copy { .. }))
    }

    /// Blocks freed for recompute (their sequences re-prefill).
    pub fn freed_blocks(&self) -> usize {
        self.count(|v| matches!(v, KvVerdict::Recompute))
    }

    fn count(&self, f: impl Fn(&KvVerdict) -> bool) -> usize {
        self.legs
            .iter()
            .filter(|l| f(&l.verdict))
            .map(|l| l.blocks)
            .sum()
    }

    /// Total bytes the copy legs move.
    pub fn copied_bytes(&self) -> u64 {
        self.legs
            .iter()
            .filter(|l| matches!(l.verdict, KvVerdict::Copy { .. }))
            .map(|l| l.len as u64 * self.bytes_per_token)
            .sum()
    }

    /// Tokens that will be re-prefilled from scratch.
    pub fn recompute_tokens(&self) -> usize {
        self.legs
            .iter()
            .filter(|l| matches!(l.verdict, KvVerdict::Recompute))
            .map(|l| l.len)
            .sum()
    }

    /// Per-device fabric legs `(src, dst, bytes)` of one copy verdict:
    /// each TP shard's KV slice moves between the paired shard devices
    /// of the old and new owner replicas. Empty for remap/recompute.
    /// Single source of truth for the shard-pair split — the HMM embeds
    /// these legs in its [`crate::hmm::PlanOp::KvBlockCopy`] ops.
    ///
    /// When the two configurations shard differently (`from.tp !=
    /// to.tp`) the copy reshards: one leg per shard of the *finer* side,
    /// fanned in/out against the coarser side's devices
    /// (`legs = max(from.tp, to.tp)`, shard `i` of the finer side pairs
    /// with shard `i * coarse/fine` of the coarser). The integer-division
    /// remainder of the byte split is charged to the last leg, so the
    /// legs always sum to exactly `len * bytes_per_token` — fabric
    /// accounting matches [`Self::copied_bytes`] byte-for-byte.
    pub fn fabric_legs(&self, leg: &KvLeg) -> Vec<(DeviceId, DeviceId, u64)> {
        let KvVerdict::Copy { src_rank, dst_rank } = leg.verdict else {
            return Vec::new();
        };
        let total = leg.len as u64 * self.bytes_per_token;
        let src = rank_devices(&self.from, src_rank);
        let dst = rank_devices(&self.to, dst_rank);
        let n = src.len().max(dst.len()).max(1);
        let per = total / n as u64;
        (0..n)
            .map(|i| {
                let s = src[i * src.len() / n];
                let d = dst[i * dst.len() / n];
                let bytes = if i == n - 1 {
                    total - per * (n as u64 - 1)
                } else {
                    per
                };
                (s, d, bytes)
            })
            .collect()
    }

    /// All copy verdicts' fabric legs, flattened.
    pub fn transfers(&self) -> Vec<(DeviceId, DeviceId, u64)> {
        self.legs
            .iter()
            .flat_map(|l| self.fabric_legs(l))
            .collect()
    }

    /// Conservation invariant: every block that existed at the snapshot
    /// is accounted for exactly once — remapped, copied, or freed.
    pub fn blocks_conserved(&self, snapshot_blocks: usize) -> bool {
        self.remapped_blocks() + self.copied_blocks() + self.freed_blocks()
            == snapshot_blocks
    }
}

/// Map each source DP rank to the target DP rank occupying the *same*
/// device group, if any. A rank "survives" only when its full TP group is
/// intact — a partially reused group would still have to move KV shards.
fn surviving_ranks(
    from: &ParallelConfig,
    to: &ParallelConfig,
) -> Vec<Option<usize>> {
    (0..from.dp)
        .map(|r| {
            let group = rank_devices(from, r);
            (0..to.dp).find(|&tr| rank_devices(to, tr) == group)
        })
        .collect()
}

/// Plan the KV migration for `snapshot` onto `to`. `budget_bytes` is the
/// migration-byte budget *remaining after expert migration* (the two
/// share one budget); copy legs consume it and fall back to recompute
/// once exhausted. Returns the plan and the bytes it consumed.
pub fn plan_kv_migration(
    snapshot: &KvSnapshot,
    to: &ParallelConfig,
    cost: &CostModel,
    budget_bytes: u64,
) -> (KvMigrationPlan, u64) {
    let from = &snapshot.from;
    let survive = surviving_ranks(from, to);
    let bytes_per_token = cost.model.kv_bytes_per_token();

    // Target-replica block load, seeded by the remapped sequences, so
    // copy destinations spread across the least-loaded replicas (new
    // devices start empty and naturally absorb movers).
    let mut load = vec![0usize; to.dp];
    let mut legs: Vec<KvLeg> = Vec::with_capacity(snapshot.seqs.len());
    let mut movers = Vec::new();
    for s in &snapshot.seqs {
        match survive.get(s.home_rank).copied().flatten() {
            Some(rank) => {
                load[rank] += s.blocks;
                legs.push(KvLeg {
                    id: s.id,
                    len: s.len,
                    blocks: s.blocks,
                    verdict: KvVerdict::Remap { rank },
                });
            }
            None => movers.push(*s),
        }
    }

    // Longest contexts first: they gain the most from avoiding
    // recompute, so they get first claim on the byte budget.
    movers.sort_unstable_by(|a, b| b.len.cmp(&a.len).then(a.id.cmp(&b.id)));
    let mut budget = budget_bytes;
    let mut used = 0u64;
    for s in movers {
        let bytes = s.len as u64 * bytes_per_token;
        let verdict = if cost.kv_prefer_copy(to, s.len) && bytes <= budget {
            let dst_rank = (0..to.dp)
                .min_by_key(|&r| (load[r], r))
                .expect("target has at least one replica");
            load[dst_rank] += s.blocks;
            budget -= bytes;
            used += bytes;
            KvVerdict::Copy { src_rank: s.home_rank, dst_rank }
        } else {
            KvVerdict::Recompute
        };
        legs.push(KvLeg {
            id: s.id,
            len: s.len,
            blocks: s.blocks,
            verdict,
        });
    }
    legs.sort_unstable_by_key(|l| l.id);

    (
        KvMigrationPlan {
            legs,
            bytes_per_token,
            from: from.clone(),
            to: to.clone(),
        },
        used,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;
    use crate::device::Timings;
    use crate::engine::PagedKv;
    use crate::kvmigrate::{home_rank, KvSnapshot};

    fn par(dp: usize) -> ParallelConfig {
        ParallelConfig::standard(dp, 2, (0..dp * 2).collect()).unwrap()
    }

    fn cost() -> CostModel {
        CostModel::new(dsv2_lite(), Timings::cloudmatrix())
    }

    /// A pool with one long sequence per id in `ids` (len 4000 + id).
    fn snapshot(ids: &[u64], from: &ParallelConfig) -> KvSnapshot {
        let mut kv = PagedKv::new(100_000, 16);
        for &id in ids {
            kv.admit(id, 4000 + id as usize).unwrap();
        }
        KvSnapshot::capture(&kv, from)
    }

    #[test]
    fn scale_up_remaps_everything() {
        let from = par(4);
        let snap = snapshot(&[1, 2, 3, 4, 5, 6, 7, 8], &from);
        let (plan, used) =
            plan_kv_migration(&snap, &par(6), &cost(), u64::MAX);
        assert_eq!(used, 0);
        assert_eq!(plan.copied_blocks(), 0);
        assert_eq!(plan.freed_blocks(), 0);
        assert_eq!(plan.recompute_tokens(), 0);
        assert_eq!(plan.remapped_blocks(), snap.total_blocks());
        assert!(plan.blocks_conserved(snap.total_blocks()));
        assert!(plan.transfers().is_empty());
        // Remap ranks keep the same device groups.
        for leg in &plan.legs {
            let KvVerdict::Remap { rank } = leg.verdict else {
                panic!("{leg:?}");
            };
            assert_eq!(
                rank_devices(&par(6), rank),
                rank_devices(&from, home_rank(leg.id, 4)),
            );
        }
    }

    #[test]
    fn scale_down_copies_long_contexts_off_departing_ranks() {
        let from = par(4);
        // Rank 3 (devices 6,7) departs under DP3. ids ≡ 3 (mod 4) live
        // there.
        let snap = snapshot(&[1, 2, 3, 4, 6, 7, 11, 15], &from);
        let to = par(3);
        let (plan, used) = plan_kv_migration(&snap, &to, &cost(), u64::MAX);
        assert!(plan.blocks_conserved(snap.total_blocks()));
        assert!(used > 0, "long contexts must copy, not recompute");
        assert_eq!(plan.freed_blocks(), 0);
        let movers: Vec<&KvLeg> = plan
            .legs
            .iter()
            .filter(|l| matches!(l.verdict, KvVerdict::Copy { .. }))
            .collect();
        // Exactly the rank-3 sequences move.
        let mover_ids: Vec<u64> = movers.iter().map(|l| l.id).collect();
        assert_eq!(mover_ids, vec![3, 7, 11, 15]);
        // Every fabric leg starts on a departing device (6 or 7).
        for (src, dst, bytes) in plan.transfers() {
            assert!(src >= 6, "src {src}");
            assert!(dst < 6, "dst {dst}");
            assert!(bytes > 0);
        }
        assert_eq!(used, plan.copied_bytes());
    }

    #[test]
    fn short_sequences_recompute_by_cost() {
        let from = par(2);
        let mut kv = PagedKv::new(100_000, 16);
        kv.admit(1, 50).unwrap(); // rank 1 (1 % 2), tiny context
        kv.admit(3, 6000).unwrap(); // rank 1, long context
        let snap = KvSnapshot::capture(&kv, &from);
        // Shrink to DP1: rank 1 departs.
        let to = ParallelConfig::standard(1, 2, vec![0, 1]).unwrap();
        let (plan, _) = plan_kv_migration(&snap, &to, &cost(), u64::MAX);
        let verdict = |id: u64| {
            plan.legs.iter().find(|l| l.id == id).unwrap().verdict
        };
        // 50 tokens: the 2 ms P2P setup dwarfs its re-prefill — recompute.
        assert_eq!(verdict(1), KvVerdict::Recompute);
        // 6000 tokens: transfer is far cheaper than re-prefill — copy.
        assert!(matches!(verdict(3), KvVerdict::Copy { .. }));
        assert!(plan.blocks_conserved(snap.total_blocks()));
        assert_eq!(plan.recompute_tokens(), 50);
    }

    #[test]
    fn exhausted_budget_forces_recompute() {
        let from = par(4);
        let snap = snapshot(&[3, 7, 11], &from); // all on departing rank 3
        let to = par(3);
        let c = cost();
        // Budget for exactly one sequence (the longest, id 11: 4011 tok).
        let budget = 4011 * c.model.kv_bytes_per_token();
        let (plan, used) = plan_kv_migration(&snap, &to, &c, budget);
        assert!(used <= budget);
        let copies = plan
            .legs
            .iter()
            .filter(|l| matches!(l.verdict, KvVerdict::Copy { .. }))
            .count();
        assert_eq!(copies, 1, "{plan:?}");
        // Longest-first: the budget goes to id 11.
        assert!(matches!(
            plan.legs.iter().find(|l| l.id == 11).unwrap().verdict,
            KvVerdict::Copy { .. }
        ));
        assert_eq!(plan.freed_blocks() + plan.copied_blocks(), snap.total_blocks());
        assert!(plan.blocks_conserved(snap.total_blocks()));
    }

    /// Hand-built single-copy plan between arbitrary configs, with a
    /// bytes-per-token chosen by the test (so byte splits can be made
    /// deliberately indivisible).
    fn copy_plan(
        from: ParallelConfig,
        to: ParallelConfig,
        len: usize,
        bytes_per_token: u64,
    ) -> (KvMigrationPlan, KvLeg) {
        let leg = KvLeg {
            id: 1,
            len,
            blocks: 1,
            verdict: KvVerdict::Copy { src_rank: 0, dst_rank: 0 },
        };
        let plan = KvMigrationPlan {
            legs: vec![leg],
            bytes_per_token,
            from,
            to,
        };
        (plan, leg)
    }

    #[test]
    fn fabric_leg_remainder_goes_to_the_last_leg() {
        // 3 tokens x 7 B/token = 21 bytes over tp=2: 10 + 11, never
        // 10 + 10 (the old integer split lost the remainder byte).
        let (plan, leg) = copy_plan(par(1), par(1), 3, 7);
        let legs = plan.fabric_legs(&leg);
        assert_eq!(legs.len(), 2);
        assert_eq!(legs[0].2, 10);
        assert_eq!(legs[1].2, 11);
        let total: u64 = legs.iter().map(|l| l.2).sum();
        assert_eq!(total, plan.copied_bytes());
    }

    #[test]
    fn resharding_fan_in_pairs_shards_without_panic() {
        // tp 4 -> tp 2: one leg per *source* shard, fanned into the
        // coarser destination pairwise (the old code indexed dst[t] for
        // t in 0..from.tp and panicked out of bounds here).
        let from = ParallelConfig::standard(1, 4, vec![0, 1, 2, 3]).unwrap();
        let to = ParallelConfig::standard(1, 2, vec![10, 11]).unwrap();
        let (plan, leg) = copy_plan(from, to, 5, 9); // 45 B, indivisible
        let legs = plan.fabric_legs(&leg);
        assert_eq!(
            legs,
            vec![(0, 10, 11), (1, 10, 11), (2, 11, 11), (3, 11, 12)]
        );
        let total: u64 = legs.iter().map(|l| l.2).sum();
        assert_eq!(total, 45);
        assert_eq!(total, plan.copied_bytes());
    }

    #[test]
    fn resharding_fan_out_pairs_shards_without_mispair() {
        // tp 2 -> tp 4: one leg per *destination* shard, each sourced
        // from the coarser shard that owns its slice (the old code
        // emitted only from.tp legs and mispaired the rest).
        let from = ParallelConfig::standard(1, 2, vec![0, 1]).unwrap();
        let to = ParallelConfig::standard(1, 4, vec![4, 5, 6, 7]).unwrap();
        let (plan, leg) = copy_plan(from, to, 5, 9);
        let legs = plan.fabric_legs(&leg);
        assert_eq!(
            legs,
            vec![(0, 4, 11), (0, 5, 11), (1, 6, 11), (1, 7, 12)]
        );
        let total: u64 = legs.iter().map(|l| l.2).sum();
        assert_eq!(total, 45);
    }

    #[test]
    fn transfers_bytes_sum_matches_copied_bytes_exactly() {
        // Planner-produced copies (departing rank under DP shrink):
        // fabric accounting must equal the plan's charged bytes exactly,
        // not just approximately.
        let from = par(4);
        let snap = snapshot(&[1, 2, 3, 4, 6, 7, 11, 15], &from);
        let (plan, used) =
            plan_kv_migration(&snap, &par(3), &cost(), u64::MAX);
        let fabric: u64 = plan.transfers().iter().map(|l| l.2).sum();
        assert_eq!(fabric, plan.copied_bytes());
        assert_eq!(fabric, used);
    }

    #[test]
    fn copy_destinations_balance_block_load() {
        let from = par(4);
        // Eight long movers on rank 3; survivors 0..2 carry one seq each.
        let ids: Vec<u64> =
            vec![3, 7, 11, 15, 19, 23, 27, 31, 0, 1, 2];
        let snap = snapshot(&ids, &from);
        let (plan, _) = plan_kv_migration(&snap, &par(3), &cost(), u64::MAX);
        let mut per_rank = vec![0usize; 3];
        for l in &plan.legs {
            match l.verdict {
                KvVerdict::Copy { dst_rank, .. } => per_rank[dst_rank] += 1,
                KvVerdict::Remap { .. } => {}
                v => panic!("unexpected {v:?}"),
            }
        }
        let (min, max) = (
            per_rank.iter().min().unwrap(),
            per_rank.iter().max().unwrap(),
        );
        assert!(max - min <= 1, "skewed destinations: {per_rank:?}");
    }
}
