//! Multi-tenant workloads: several tenants, each with its own IO shape,
//! arrival profile and SLO, merged into one fleet-level request stream.
//! The fleet router can pin tenants to replicas (session affinity) and the
//! metrics recorder reports attainment per tenant.

use crate::config::SloConfig;

use super::generator::{WorkloadGen, WorkloadSpec};
use super::request::Request;

/// One tenant's traffic contract: a workload shape plus the SLO it bought.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    pub spec: WorkloadSpec,
    pub slo: SloConfig,
}

impl TenantSpec {
    pub fn new(name: &str, spec: WorkloadSpec, slo: SloConfig) -> Self {
        TenantSpec {
            name: name.to_string(),
            spec,
            slo,
        }
    }
}

/// Generates the merged arrival stream of several tenants. Each tenant's
/// sub-stream is drawn from its own seeded generator (deterministic), then
/// the streams are interleaved by arrival time and re-numbered so request
/// ids stay globally unique. Tenant index `i` tags every request it emits.
#[derive(Debug)]
pub struct MultiTenantGen {
    pub tenants: Vec<TenantSpec>,
}

impl MultiTenantGen {
    pub fn new(tenants: Vec<TenantSpec>) -> Self {
        MultiTenantGen { tenants }
    }

    /// All arrivals up to `horizon`, merged and sorted by arrival time.
    pub fn arrivals_until(&self, horizon: f64) -> Vec<Request> {
        let mut merged: Vec<Request> = Vec::new();
        for (i, t) in self.tenants.iter().enumerate() {
            let mut g = WorkloadGen::new(t.spec.clone());
            for r in g.arrivals_until(horizon) {
                merged.push(r.with_tenant(i as u32));
            }
        }
        merged.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        // Re-number: per-tenant generators all start ids at 1.
        for (n, r) in merged.iter_mut().enumerate() {
            r.id = n as u64 + 1;
        }
        merged
    }

    /// The aggregate rate profile (for capacity planning / plots).
    pub fn aggregate_profile(&self) -> super::generator::RateProfile {
        super::generator::RateProfile::Sum(
            self.tenants
                .iter()
                .map(|t| t.spec.profile.clone())
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::RateProfile;

    fn spec(rps: f64, seed: u64) -> WorkloadSpec {
        WorkloadSpec {
            prompt_len: 500,
            decode_min: 50,
            decode_max: 100,
            profile: RateProfile::Fixed(rps),
            seed,
        }
    }

    #[test]
    fn merged_stream_is_sorted_unique_and_tagged() {
        let gen = MultiTenantGen::new(vec![
            TenantSpec::new("chat", spec(2.0, 1), SloConfig::strict()),
            TenantSpec::new("batch", spec(1.0, 2), SloConfig::new(10.0, 5.0)),
        ]);
        let arr = gen.arrivals_until(100.0);
        assert!(!arr.is_empty());
        for w in arr.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
            assert!(w[0].id < w[1].id);
        }
        assert!(arr.iter().any(|r| r.tenant == 0));
        assert!(arr.iter().any(|r| r.tenant == 1));
        // Roughly 2:1 traffic split.
        let t0 = arr.iter().filter(|r| r.tenant == 0).count() as f64;
        let t1 = arr.iter().filter(|r| r.tenant == 1).count() as f64;
        assert!(t0 > t1, "tenant 0 ({t0}) should dominate tenant 1 ({t1})");
    }

    #[test]
    fn aggregate_profile_sums_tenant_rates() {
        let gen = MultiTenantGen::new(vec![
            TenantSpec::new("a", spec(2.0, 1), SloConfig::strict()),
            TenantSpec::new("b", spec(3.0, 2), SloConfig::strict()),
        ]);
        assert_eq!(gen.aggregate_profile().rate(7.0), 5.0);
    }

    #[test]
    fn deterministic_given_seeds() {
        let gen = MultiTenantGen::new(vec![TenantSpec::new(
            "a",
            spec(2.0, 3),
            SloConfig::strict(),
        )]);
        let a: Vec<f64> =
            gen.arrivals_until(50.0).iter().map(|r| r.arrival).collect();
        let b: Vec<f64> =
            gen.arrivals_until(50.0).iter().map(|r| r.arrival).collect();
        assert_eq!(a, b);
    }
}
