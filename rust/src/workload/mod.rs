//! Synthetic workload generation (§7.1): fixed-length IO request streams
//! with fixed, ramping, bursty and patterned arrival-rate profiles, drawn
//! from seeded PRNGs for deterministic experiments.

pub mod generator;
pub mod request;

pub use generator::{RateProfile, WorkloadGen, WorkloadSpec};
pub use request::{Request, RequestId, RequestState};
