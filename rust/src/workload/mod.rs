//! Synthetic workload generation (§7.1): fixed-length IO request streams
//! with fixed, ramping, bursty and patterned arrival-rate profiles, drawn
//! from seeded PRNGs for deterministic experiments. [`MultiTenantGen`]
//! merges several tenants' streams (each with its own profile and SLO)
//! into the fleet-level workloads of `experiments::fleet`;
//! [`ZipfRouting`] generates the skewed expert-routing traces of
//! `experiments::placement`.

pub mod generator;
pub mod request;
pub mod tenant;
pub mod zipf;

pub use generator::{RateProfile, WorkloadGen, WorkloadSpec};
pub use request::{Request, RequestId, RequestState};
pub use tenant::{MultiTenantGen, TenantSpec};
pub use zipf::ZipfRouting;
