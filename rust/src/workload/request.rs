//! Request state: one generation request moving through the serving stack.

/// Unique request id.
pub type RequestId = u64;

/// Lifecycle of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// In the coordinator queue.
    Queued,
    /// Admitted, waiting for / undergoing prefill.
    Prefilling,
    /// Generating tokens.
    Decoding,
    /// Decode paused with KV held resident: the sequence is mid-handoff
    /// across a scaling event (its blocks are being copied to the new
    /// owner) and resumes decoding on the successor instance.
    Suspended,
    /// All tokens produced.
    Finished,
    /// Dropped (baseline downtime only — ElasticMoE never drops).
    Dropped,
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub arrival: f64,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    /// Owning tenant (0 = the default single-tenant stream). Used by the
    /// fleet router for session affinity and by per-tenant SLO accounting.
    pub tenant: u32,
    pub state: RequestState,
    /// Decode progress.
    pub generated: usize,
    /// Time the first token was emitted.
    pub first_token_at: Option<f64>,
    /// Time the request finished.
    pub finished_at: Option<f64>,
    /// Live-path payload: prompt token ids (empty in simulation).
    pub prompt_ids: Vec<i32>,
    /// Live-path payload: generated token ids.
    pub output_ids: Vec<i32>,
}

impl Request {
    pub fn new(
        id: RequestId,
        arrival: f64,
        prompt_len: usize,
        max_new_tokens: usize,
    ) -> Self {
        Request {
            id,
            arrival,
            prompt_len,
            max_new_tokens,
            tenant: 0,
            state: RequestState::Queued,
            generated: 0,
            first_token_at: None,
            finished_at: None,
            prompt_ids: Vec::new(),
            output_ids: Vec::new(),
        }
    }

    /// Tag the request with its owning tenant.
    pub fn with_tenant(mut self, tenant: u32) -> Self {
        self.tenant = tenant;
        self
    }

    /// Total KV footprint in tokens at completion.
    pub fn total_tokens(&self) -> usize {
        self.prompt_len + self.max_new_tokens
    }

    /// Current sequence length (prompt + generated so far).
    pub fn current_len(&self) -> usize {
        self.prompt_len + self.generated
    }

    pub fn is_done(&self) -> bool {
        matches!(self.state, RequestState::Finished | RequestState::Dropped)
    }

    /// TTFT if the first token has been emitted.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.arrival)
    }

    /// Mean TPOT over the decode phase (excluding the first token).
    pub fn tpot(&self) -> Option<f64> {
        let (first, done) = (self.first_token_at?, self.finished_at?);
        if self.generated <= 1 {
            return Some(0.0);
        }
        Some((done - first) / (self.generated - 1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_accounting() {
        let mut r = Request::new(1, 10.0, 100, 50);
        assert_eq!(r.total_tokens(), 150);
        assert_eq!(r.current_len(), 100);
        r.first_token_at = Some(12.0);
        r.generated = 50;
        r.finished_at = Some(61.0);
        r.state = RequestState::Finished;
        assert_eq!(r.ttft(), Some(2.0));
        assert!((r.tpot().unwrap() - 1.0).abs() < 1e-9);
        assert!(r.is_done());
    }

    #[test]
    fn single_token_tpot_is_zero() {
        let mut r = Request::new(1, 0.0, 10, 1);
        r.first_token_at = Some(1.0);
        r.finished_at = Some(1.0);
        r.generated = 1;
        assert_eq!(r.tpot(), Some(0.0));
    }
}
