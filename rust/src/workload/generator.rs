//! Request-stream generation with the paper's load profiles (§7.1: fixed,
//! variable and patterned request rates; §7.6: rps(t) = f(t) ramps).

use crate::util::rng::Rng;

use super::request::Request;

/// Arrival-rate profile, requests/second as a function of time.
#[derive(Debug, Clone)]
pub enum RateProfile {
    /// Constant rate.
    Fixed(f64),
    /// Linear ramp from `from` to `to` over `duration` seconds.
    Ramp { from: f64, to: f64, duration: f64 },
    /// Base rate with a multiplicative burst in `[start, start+len)`
    /// (the "10x within minutes" pattern of §2.2).
    Burst {
        base: f64,
        factor: f64,
        start: f64,
        len: f64,
    },
    /// Step change at `at` (used to trigger scaling events, §7.5).
    Step { before: f64, after: f64, at: f64 },
    /// Repeating day/night-style sinusoid: `base * (1 + amp*sin)`.
    Diurnal { base: f64, amp: f64, period: f64 },
    /// Superposition of independent profiles (multi-tenant aggregate
    /// traffic: each tenant contributes its own shape and the instantaneous
    /// fleet rate is the sum).
    Sum(Vec<RateProfile>),
}

impl RateProfile {
    /// Rate at time `t`. Never negative: each variant clamps at zero so a
    /// composed profile cannot cancel below an empty stream.
    pub fn rate(&self, t: f64) -> f64 {
        let r = match *self {
            RateProfile::Fixed(r) => r,
            RateProfile::Ramp { from, to, duration } => {
                if duration <= 0.0 {
                    return to.max(0.0);
                }
                let f = (t / duration).clamp(0.0, 1.0);
                from + (to - from) * f
            }
            RateProfile::Burst {
                base,
                factor,
                start,
                len,
            } => {
                if t >= start && t < start + len {
                    base * factor
                } else {
                    base
                }
            }
            RateProfile::Step { before, after, at } => {
                if t < at {
                    before
                } else {
                    after
                }
            }
            RateProfile::Diurnal { base, amp, period } => {
                base * (1.0
                    + amp * (2.0 * std::f64::consts::PI * t / period).sin())
                .max(0.0)
            }
            RateProfile::Sum(ref parts) => {
                parts.iter().map(|p| p.rate(t)).sum()
            }
        };
        r.max(0.0)
    }
}

/// IO-shape spec: fixed-length prompts and bounded random decode lengths
/// (the paper's synthetic workload, e.g. §7.6: 2000-token prompts, 500-750
/// decode).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub prompt_len: usize,
    pub decode_min: usize,
    pub decode_max: usize,
    pub profile: RateProfile,
    pub seed: u64,
}

impl WorkloadSpec {
    /// §7.6's workload.
    pub fn slo_sweep(rps: f64) -> Self {
        WorkloadSpec {
            prompt_len: 2000,
            decode_min: 500,
            decode_max: 750,
            profile: RateProfile::Fixed(rps),
            seed: 7,
        }
    }

    /// Appendix A.1's offline throughput workload.
    pub fn offline_batch() -> Self {
        WorkloadSpec {
            prompt_len: 500,
            decode_min: 250,
            decode_max: 500,
            profile: RateProfile::Fixed(f64::INFINITY),
            seed: 11,
        }
    }
}

/// Deterministic Poisson-arrival request generator.
#[derive(Debug)]
pub struct WorkloadGen {
    spec: WorkloadSpec,
    rng: Rng,
    next_id: u64,
    t: f64,
}

impl WorkloadGen {
    pub fn new(spec: WorkloadSpec) -> Self {
        let rng = Rng::new(spec.seed);
        WorkloadGen {
            spec,
            rng,
            next_id: 1,
            t: 0.0,
        }
    }

    fn decode_len(&mut self) -> usize {
        if self.spec.decode_max <= self.spec.decode_min {
            return self.spec.decode_min;
        }
        self.rng
            .range(self.spec.decode_min as u64, self.spec.decode_max as u64)
            as usize
    }

    /// Next arrival (None when the profile's rate is 0 for good). Advances
    /// internal time by exponential inter-arrival draws against the
    /// instantaneous rate (thinning-free approximation: fine for the
    /// piecewise-constant profiles used in the experiments).
    pub fn next_arrival(&mut self) -> Option<Request> {
        let rate = self.spec.profile.rate(self.t);
        if rate <= 0.0 {
            // Jump forward looking for a nonzero rate (bounded scan).
            for _ in 0..10_000 {
                self.t += 1.0;
                if self.spec.profile.rate(self.t) > 0.0 {
                    return self.next_arrival();
                }
            }
            return None;
        }
        if rate.is_infinite() {
            // Offline mode: all requests arrive at t=0.
            let d = self.decode_len();
            let id = self.next_id;
            self.next_id += 1;
            return Some(Request::new(id, 0.0, self.spec.prompt_len, d));
        }
        self.t += self.rng.exponential(rate);
        let d = self.decode_len();
        let id = self.next_id;
        self.next_id += 1;
        Some(Request::new(id, self.t, self.spec.prompt_len, d))
    }

    /// Generate all arrivals up to `horizon` seconds.
    pub fn arrivals_until(&mut self, horizon: f64) -> Vec<Request> {
        let mut out = Vec::new();
        loop {
            match self.next_arrival() {
                Some(r) if r.arrival <= horizon => out.push(r),
                _ => break,
            }
        }
        out
    }

    /// A fixed-size offline batch (all arrive at t=0).
    pub fn offline_batch(&mut self, n: usize) -> Vec<Request> {
        (0..n)
            .map(|_| {
                let d = self.decode_len();
                let id = self.next_id;
                self.next_id += 1;
                Request::new(id, 0.0, self.spec.prompt_len, d)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_shape() {
        let ramp = RateProfile::Ramp {
            from: 1.0,
            to: 5.0,
            duration: 100.0,
        };
        assert_eq!(ramp.rate(0.0), 1.0);
        assert_eq!(ramp.rate(50.0), 3.0);
        assert_eq!(ramp.rate(200.0), 5.0);

        let burst = RateProfile::Burst {
            base: 2.0,
            factor: 10.0,
            start: 60.0,
            len: 30.0,
        };
        assert_eq!(burst.rate(0.0), 2.0);
        assert_eq!(burst.rate(75.0), 20.0);
        assert_eq!(burst.rate(90.0), 2.0);

        let step = RateProfile::Step {
            before: 1.0,
            after: 4.0,
            at: 10.0,
        };
        assert_eq!(step.rate(9.9), 1.0);
        assert_eq!(step.rate(10.0), 4.0);
    }

    #[test]
    fn rates_never_negative() {
        // Diurnal with amp > 1 dips below zero mid-period without the
        // clamp; every other variant must clamp too.
        let profiles = [
            RateProfile::Diurnal {
                base: 2.0,
                amp: 3.0,
                period: 100.0,
            },
            RateProfile::Fixed(-1.0),
            RateProfile::Ramp {
                from: 5.0,
                to: -5.0,
                duration: 10.0,
            },
            RateProfile::Step {
                before: 1.0,
                after: -2.0,
                at: 5.0,
            },
            RateProfile::Sum(vec![
                RateProfile::Fixed(1.0),
                RateProfile::Ramp {
                    from: -10.0,
                    to: -10.0,
                    duration: 1.0,
                },
            ]),
        ];
        for p in &profiles {
            for i in 0..1000 {
                let t = i as f64 * 0.25;
                assert!(p.rate(t) >= 0.0, "{p:?} at t={t}: {}", p.rate(t));
            }
        }
    }

    #[test]
    fn burst_boundary_is_exclusive() {
        let burst = RateProfile::Burst {
            base: 2.0,
            factor: 10.0,
            start: 60.0,
            len: 30.0,
        };
        assert_eq!(burst.rate(60.0), 20.0, "start is inclusive");
        assert_eq!(burst.rate(89.999), 20.0);
        assert_eq!(burst.rate(90.0), 2.0, "start+len is exclusive");
    }

    #[test]
    fn ramp_with_nonpositive_duration_is_a_step_to_target() {
        for duration in [0.0, -5.0] {
            let ramp = RateProfile::Ramp {
                from: 1.0,
                to: 4.0,
                duration,
            };
            assert_eq!(ramp.rate(0.0), 4.0);
            assert_eq!(ramp.rate(100.0), 4.0);
        }
    }

    #[test]
    fn sum_superposes_component_rates() {
        let p = RateProfile::Sum(vec![
            RateProfile::Fixed(1.0),
            RateProfile::Burst {
                base: 0.5,
                factor: 10.0,
                start: 10.0,
                len: 5.0,
            },
        ]);
        assert_eq!(p.rate(0.0), 1.5);
        assert_eq!(p.rate(12.0), 6.0);
        assert_eq!(p.rate(15.0), 1.5);
        // A Sum profile drives the generator like any other.
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 100,
            decode_min: 10,
            decode_max: 20,
            profile: p,
            seed: 9,
        });
        let arr = g.arrivals_until(100.0);
        assert!(!arr.is_empty());
        for w in arr.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn poisson_rate_is_respected() {
        let spec = WorkloadSpec {
            prompt_len: 100,
            decode_min: 10,
            decode_max: 20,
            profile: RateProfile::Fixed(5.0),
            seed: 3,
        };
        let mut g = WorkloadGen::new(spec);
        let arr = g.arrivals_until(200.0);
        let rate = arr.len() as f64 / 200.0;
        assert!((rate - 5.0).abs() < 0.5, "empirical rate {rate}");
        // Arrivals are sorted and ids unique.
        for w in arr.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
            assert!(w[0].id < w[1].id);
        }
    }

    #[test]
    fn decode_lengths_in_range() {
        let mut g = WorkloadGen::new(WorkloadSpec::slo_sweep(1.0));
        for _ in 0..100 {
            let r = g.next_arrival().unwrap();
            assert_eq!(r.prompt_len, 2000);
            assert!((500..=750).contains(&r.max_new_tokens));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a: Vec<f64> = WorkloadGen::new(WorkloadSpec::slo_sweep(2.0))
            .arrivals_until(50.0)
            .iter()
            .map(|r| r.arrival)
            .collect();
        let b: Vec<f64> = WorkloadGen::new(WorkloadSpec::slo_sweep(2.0))
            .arrivals_until(50.0)
            .iter()
            .map(|r| r.arrival)
            .collect();
        assert_eq!(a, b);
    }

    #[test]
    fn offline_batch_all_at_zero() {
        let mut g = WorkloadGen::new(WorkloadSpec::offline_batch());
        let batch = g.offline_batch(100);
        assert_eq!(batch.len(), 100);
        assert!(batch.iter().all(|r| r.arrival == 0.0));
    }
}
