//! Zipf-skewed expert routing traces: synthetic gate decisions with the
//! heavy-tailed expert popularity observed in production MoE serving
//! (Huang et al., *Towards MoE Deployment*, arXiv:2303.06182 — a handful
//! of hot experts receive most tokens). Each token picks `top_k` distinct
//! experts whose popularity ranks follow `P(rank r) ∝ 1/(r+1)^s`; the
//! rank → expert mapping is shuffled so hot experts are scattered across
//! expert ids, as a trained gate would scatter them. Drives the placement
//! experiments (`repro exp placement`).

use crate::engine::moe::Routing;
use crate::util::rng::Rng;

/// Deterministic Zipf-skewed gate.
#[derive(Debug, Clone)]
pub struct ZipfRouting {
    pub n_experts: usize,
    pub top_k: usize,
    /// Zipf exponent: 0 = uniform, 1.0 = classic heavy skew.
    pub s: f64,
    rng: Rng,
    /// CDF over popularity ranks.
    cdf: Vec<f64>,
    /// Popularity rank -> expert id.
    rank_to_expert: Vec<usize>,
}

impl ZipfRouting {
    pub fn new(n_experts: usize, top_k: usize, s: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut rank_to_expert: Vec<usize> = (0..n_experts).collect();
        rng.shuffle(&mut rank_to_expert);
        Self::with_rank_mapping(n_experts, top_k, s, seed, rank_to_expert)
    }

    /// Like [`Self::new`], but with an explicit popularity-rank → expert-id
    /// mapping (must be a permutation of `0..n_experts`). Lets experiments
    /// pin *where* the hot experts sit relative to the round-robin
    /// placement instead of rolling the dice with a shuffle.
    pub fn with_rank_mapping(
        n_experts: usize,
        top_k: usize,
        s: f64,
        seed: u64,
        rank_to_expert: Vec<usize>,
    ) -> Self {
        assert!(
            top_k >= 1 && top_k <= n_experts,
            "top_k {top_k} out of range for {n_experts} experts"
        );
        let mut seen = vec![false; n_experts];
        for &e in &rank_to_expert {
            assert!(e < n_experts && !seen[e], "mapping must be a permutation");
            seen[e] = true;
        }
        assert_eq!(rank_to_expert.len(), n_experts);
        let rng = Rng::new(seed.wrapping_add(1));
        let weights: Vec<f64> = (0..n_experts)
            .map(|r| 1.0 / ((r + 1) as f64).powf(s))
            .collect();
        let total: f64 = weights.iter().sum();
        let mut cdf = Vec::with_capacity(n_experts);
        let mut acc = 0.0;
        for w in &weights {
            acc += w / total;
            cdf.push(acc);
        }
        *cdf.last_mut().unwrap() = 1.0; // guard fp drift
        ZipfRouting {
            n_experts,
            top_k,
            s,
            rng,
            cdf,
            rank_to_expert,
        }
    }

    fn sample_rank(&mut self) -> usize {
        let u = self.rng.f64();
        self.cdf
            .partition_point(|&c| c < u)
            .min(self.n_experts - 1)
    }

    /// One gate step: `n_tokens` tokens, each routed to `top_k` distinct
    /// experts drawn from the popularity law (rejection on duplicates,
    /// falling back to the coldest unchosen experts if rejection stalls).
    pub fn step(&mut self, n_tokens: usize) -> Routing {
        let mut tokens_per_expert = vec![Vec::new(); self.n_experts];
        for t in 0..n_tokens {
            let mut chosen: Vec<usize> = Vec::with_capacity(self.top_k);
            let mut stalls = 0usize;
            while chosen.len() < self.top_k {
                let rank = self.sample_rank();
                let e = self.rank_to_expert[rank];
                if chosen.contains(&e) {
                    stalls += 1;
                    if stalls > 64 * self.top_k {
                        // Pathological skew: deterministically complete
                        // with the coldest unchosen experts.
                        for &e in self.rank_to_expert.iter().rev() {
                            if chosen.len() == self.top_k {
                                break;
                            }
                            if !chosen.contains(&e) {
                                chosen.push(e);
                                tokens_per_expert[e].push(t);
                            }
                        }
                        break;
                    }
                    continue;
                }
                chosen.push(e);
                tokens_per_expert[e].push(t);
            }
        }
        Routing {
            n_tokens,
            n_experts: self.n_experts,
            tokens_per_expert,
        }
    }

    /// The popularity law as per-expert single-draw probabilities (rank
    /// probabilities mapped through the shuffle).
    pub fn probabilities(&self) -> Vec<f64> {
        let mut p = vec![0.0; self.n_experts];
        let mut prev = 0.0;
        for (r, &c) in self.cdf.iter().enumerate() {
            p[self.rank_to_expert[r]] = c - prev;
            prev = c;
        }
        p
    }

    /// The expert at popularity rank `r` (0 = hottest).
    pub fn expert_at_rank(&self, r: usize) -> usize {
        self.rank_to_expert[r]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probabilities_form_a_distribution() {
        let g = ZipfRouting::new(16, 2, 1.0, 7);
        let p = g.probabilities();
        let total: f64 = p.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "{total}");
        assert!(p.iter().all(|&x| x > 0.0));
        // The rank-0 expert carries the largest single-draw probability.
        let hot = g.expert_at_rank(0);
        assert!(p.iter().all(|&x| x <= p[hot] + 1e-12));
    }

    #[test]
    fn steps_route_top_k_distinct_experts_per_token() {
        let mut g = ZipfRouting::new(32, 4, 1.0, 3);
        let r = g.step(50);
        assert_eq!(r.n_tokens, 50);
        // Every token appears in exactly top_k expert lists.
        let mut per_token = vec![0usize; 50];
        for toks in &r.tokens_per_expert {
            for &t in toks {
                per_token[t] += 1;
            }
            // Distinctness: an expert lists a token at most once.
            let mut sorted = toks.clone();
            sorted.dedup();
            assert_eq!(sorted.len(), toks.len());
        }
        assert!(per_token.iter().all(|&c| c == 4), "{per_token:?}");
    }

    #[test]
    fn zipf_skew_concentrates_tokens_on_hot_experts() {
        let mut g = ZipfRouting::new(64, 6, 1.0, 11);
        let hot = g.expert_at_rank(0);
        let cold = g.expert_at_rank(63);
        let mut hot_count = 0usize;
        let mut cold_count = 0usize;
        for _ in 0..50 {
            let r = g.step(64);
            hot_count += r.tokens_per_expert[hot].len();
            cold_count += r.tokens_per_expert[cold].len();
        }
        assert!(
            hot_count > cold_count * 5,
            "hot {hot_count} vs cold {cold_count}"
        );
    }

    #[test]
    fn uniform_exponent_is_roughly_flat() {
        let mut g = ZipfRouting::new(8, 2, 0.0, 5);
        let mut counts = vec![0usize; 8];
        for _ in 0..400 {
            let r = g.step(8);
            for (e, toks) in r.tokens_per_expert.iter().enumerate() {
                counts[e] += toks.len();
            }
        }
        let total: usize = counts.iter().sum();
        let mean = total as f64 / 8.0;
        for &c in &counts {
            assert!(
                (c as f64) > mean * 0.7 && (c as f64) < mean * 1.3,
                "{counts:?}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = ZipfRouting::new(32, 4, 1.0, 9);
        let mut b = ZipfRouting::new(32, 4, 1.0, 9);
        for _ in 0..5 {
            let ra = a.step(16);
            let rb = b.step(16);
            assert_eq!(ra.tokens_per_expert, rb.tokens_per_expert);
        }
    }

    #[test]
    fn explicit_rank_mapping_pins_the_hot_expert() {
        let mapping: Vec<usize> = (0..8).rev().collect();
        let mut g = ZipfRouting::with_rank_mapping(8, 2, 1.0, 3, mapping);
        assert_eq!(g.expert_at_rank(0), 7);
        let mut counts = vec![0usize; 8];
        for _ in 0..100 {
            let r = g.step(8);
            for (e, toks) in r.tokens_per_expert.iter().enumerate() {
                counts[e] += toks.len();
            }
        }
        let max = *counts.iter().max().unwrap();
        assert_eq!(counts[7], max, "{counts:?}");
    }

    #[test]
    #[should_panic(expected = "permutation")]
    fn non_permutation_mapping_rejected() {
        ZipfRouting::with_rank_mapping(4, 1, 1.0, 0, vec![0, 0, 1, 2]);
    }

    #[test]
    fn top_k_equal_to_experts_routes_everywhere() {
        let mut g = ZipfRouting::new(4, 4, 1.5, 2);
        let r = g.step(3);
        for toks in &r.tokens_per_expert {
            assert_eq!(toks.len(), 3);
        }
    }
}
