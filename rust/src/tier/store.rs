//! The residency map: which weight units are staged in host DRAM, plus
//! the journal of every cross-tier move.
//!
//! The store owns the *DRAM* tier's bookkeeping (keyed by weight tag,
//! per-expert granularity for demoted experts); HBM residency stays where
//! it always was — the HMM workers' region maps and vpage tables — and
//! disk is the unbounded backstop. Every byte that crosses a tier
//! boundary is journalled as a [`TierShift`]; the chaos checker
//! ([`crate::chaos::invariants::check_tier_conservation`]) replays the
//! journal against independent [`crate::device::HostMem`] audits, so a
//! demote that forgets its journal entry (or a journal entry that forgets
//! its bytes) is a machine-caught violation, not a silent leak.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::device::hostmem::HostRegionId;
use crate::device::{Cluster, DeviceId};

use super::TierLevel;

/// One cross-tier move of one weight unit.
#[derive(Debug, Clone, PartialEq)]
pub struct TierShift {
    /// Weight-unit tag (e.g. `layer3.expert5`, `layer0.attn.tp1`).
    pub tag: String,
    pub bytes: u64,
    pub from: TierLevel,
    pub to: TierLevel,
}

/// The tiered weight store: DRAM residency map + journal.
#[derive(Debug, Default)]
pub struct TieredWeightStore {
    /// tag -> (host region, bytes) of units staged in host DRAM.
    dram: BTreeMap<String, (HostRegionId, u64)>,
    /// Demoted cold experts: `(layer, expert) -> (logical owner device,
    /// host region, bytes)`. A demoted expert stays logically placed on
    /// its owner (DRAM-backed serving; see
    /// `docs/architecture/06-tiered-memory.md`) until the next scaling
    /// event promotes it back.
    dram_experts: BTreeMap<(usize, usize), (DeviceId, HostRegionId, u64)>,
    journal: Vec<TierShift>,
}

impl TieredWeightStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// ---- generic tagged units ------------------------------------------

    /// Stage `tag` from disk into host DRAM (background prefetch path).
    /// Returns the disk read time charged.
    pub fn stage_from_disk(
        &mut self,
        cluster: &mut Cluster,
        tag: &str,
        bytes: u64,
    ) -> Result<f64> {
        if self.dram.contains_key(tag) {
            anyhow::bail!("stage: '{tag}' is already DRAM-staged");
        }
        let region = cluster.host.alloc(bytes, tag)?;
        self.dram.insert(tag.to_string(), (region, bytes));
        self.journal.push(TierShift {
            tag: tag.to_string(),
            bytes,
            from: TierLevel::Disk,
            to: TierLevel::HostDram,
        });
        Ok(cluster.disk.read(bytes))
    }

    /// Demote `tag` out of HBM into host DRAM (the caller releases the
    /// HBM region). Returns the host region and the d2h time charged.
    pub fn demote(
        &mut self,
        cluster: &mut Cluster,
        tag: &str,
        bytes: u64,
    ) -> Result<(HostRegionId, f64)> {
        if self.dram.contains_key(tag) {
            // Double-staging would leak the first host region and break
            // the conservation audit: a programming error, not a state.
            anyhow::bail!("demote: '{tag}' is already DRAM-staged");
        }
        let region = cluster.host.alloc(bytes, tag)?;
        self.dram.insert(tag.to_string(), (region, bytes));
        self.journal.push(TierShift {
            tag: tag.to_string(),
            bytes,
            from: TierLevel::Hbm,
            to: TierLevel::HostDram,
        });
        Ok((region, cluster.timings.d2h(bytes)))
    }

    /// Promote `tag` out of host DRAM (the caller allocates the HBM side).
    /// The DRAM copy is freed — tier transitions are moves, which is what
    /// keeps the byte-conservation invariant checkable. Returns the unit's
    /// bytes and the h2d time charged; `None` when `tag` is not staged.
    pub fn promote(
        &mut self,
        cluster: &mut Cluster,
        tag: &str,
    ) -> Result<Option<(u64, f64)>> {
        let Some((region, bytes)) = self.dram.remove(tag) else {
            return Ok(None);
        };
        cluster.host.release(region).context("promote: host region")?;
        self.journal.push(TierShift {
            tag: tag.to_string(),
            bytes,
            from: TierLevel::HostDram,
            to: TierLevel::Hbm,
        });
        Ok(Some((bytes, cluster.timings.h2d(bytes))))
    }

    /// Drop `tag` from host DRAM back to disk-only (staging-cache
    /// eviction / warmth expiry).
    pub fn drop_to_disk(&mut self, cluster: &mut Cluster, tag: &str) -> Result<bool> {
        let Some((region, bytes)) = self.dram.remove(tag) else {
            return Ok(false);
        };
        cluster.host.release(region)?;
        self.journal.push(TierShift {
            tag: tag.to_string(),
            bytes,
            from: TierLevel::HostDram,
            to: TierLevel::Disk,
        });
        Ok(true)
    }

    /// Bytes of `tag` staged in DRAM, if any.
    pub fn dram_resident(&self, tag: &str) -> Option<u64> {
        self.dram.get(tag).map(|&(_, b)| b)
    }

    /// ---- demoted experts ------------------------------------------------

    /// Record a demoted cold expert (tag bookkeeping is the caller's —
    /// use [`Self::demote`] with the expert tag first).
    pub fn note_demoted_expert(
        &mut self,
        layer: usize,
        expert: usize,
        owner: DeviceId,
        region: HostRegionId,
        bytes: u64,
    ) {
        self.dram_experts.insert((layer, expert), (owner, region, bytes));
    }

    /// Demoted experts awaiting promotion, in (layer, expert) order.
    pub fn demoted_experts(&self) -> Vec<(usize, usize, DeviceId, u64)> {
        self.dram_experts
            .iter()
            .map(|(&(l, e), &(d, _, b))| (l, e, d, b))
            .collect()
    }

    pub fn forget_demoted_expert(&mut self, layer: usize, expert: usize) {
        self.dram_experts.remove(&(layer, expert));
    }

    pub fn demoted_expert_count(&self) -> usize {
        self.dram_experts.len()
    }

    /// ---- accounting -----------------------------------------------------

    /// Total bytes the residency map believes are staged in DRAM. The
    /// conservation invariant cross-checks this derived figure against
    /// the [`crate::device::HostMem`] allocator's `used()`.
    pub fn dram_bytes(&self) -> u64 {
        self.dram.values().map(|&(_, b)| b).sum()
    }

    pub fn dram_unit_count(&self) -> usize {
        self.dram.len()
    }

    /// Drain the journal (the simulators feed it into the run trace).
    pub fn drain_journal(&mut self) -> Vec<TierShift> {
        std::mem::take(&mut self.journal)
    }

    pub fn journal_len(&self) -> usize {
        self.journal.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> Cluster {
        Cluster::cloudmatrix(2)
    }

    #[test]
    fn stage_promote_cycle_moves_bytes_and_journals() {
        let mut c = cluster();
        let mut t = TieredWeightStore::new();
        let stage_t = t.stage_from_disk(&mut c, "w", 1 << 30).unwrap();
        assert!(stage_t > 0.5, "disk staging is disk-speed: {stage_t}");
        assert_eq!(t.dram_resident("w"), Some(1 << 30));
        assert_eq!(c.host.used(), 1 << 30);
        assert_eq!(t.dram_bytes(), c.host.used());

        let (bytes, h2d_t) = t.promote(&mut c, "w").unwrap().unwrap();
        assert_eq!(bytes, 1 << 30);
        assert!(h2d_t < stage_t / 10.0, "h2d must be 10x disk: {h2d_t}");
        assert_eq!(c.host.used(), 0, "promotion is a move, not a copy");
        assert!(t.dram_resident("w").is_none());
        assert!(t.promote(&mut c, "w").unwrap().is_none());

        let journal = t.drain_journal();
        assert_eq!(journal.len(), 2);
        assert_eq!(journal[0].from, TierLevel::Disk);
        assert_eq!(journal[0].to, TierLevel::HostDram);
        assert_eq!(journal[1].from, TierLevel::HostDram);
        assert_eq!(journal[1].to, TierLevel::Hbm);
        assert_eq!(t.journal_len(), 0);
    }

    #[test]
    fn demote_and_drop_account_dram() {
        let mut c = cluster();
        let mut t = TieredWeightStore::new();
        let (region, d2h_t) = t.demote(&mut c, "layer0.expert3", 64 << 20).unwrap();
        assert!(d2h_t > 0.0);
        t.note_demoted_expert(0, 3, 1, region, 64 << 20);
        assert_eq!(t.demoted_expert_count(), 1);
        assert_eq!(t.demoted_experts(), vec![(0, 3, 1, 64 << 20)]);
        assert_eq!(c.host.used(), 64 << 20);

        assert!(t.drop_to_disk(&mut c, "layer0.expert3").unwrap());
        t.forget_demoted_expert(0, 3);
        assert_eq!(c.host.used(), 0);
        assert_eq!(t.demoted_expert_count(), 0);
        assert!(!t.drop_to_disk(&mut c, "layer0.expert3").unwrap());
        let journal = t.drain_journal();
        assert_eq!(journal.len(), 2);
        assert_eq!(journal[1].to, TierLevel::Disk);
    }
}
