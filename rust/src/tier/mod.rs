//! Tiered weight residency (beyond the paper): HBM → host DRAM → shared
//! disk.
//!
//! ElasticMoE's fast scaling rests on weights already being resident
//! somewhere cheap to reach (HBM reuse, P2P, dedup'd disk reads — §4.5,
//! Appendix D.2), but the base memory model is two-level: a weight is
//! either in HBM or a full disk cold read away. Serverless MoE serving
//! (MoEless, arXiv 2603.06350) and the MoE inference survey
//! (arXiv 2412.14219) both identify a **host-memory tier with per-expert
//! granularity** as the lever that closes the gap: standby capacity can
//! be parked an h2d copy (~25 GB/s) away from serving instead of a disk
//! boot (~1.5 GB/s) away, and cold experts can be demoted out of HBM
//! without losing their warmth.
//!
//! The subsystem in three parts:
//!
//! 1. **Residency map + journal** — [`TieredWeightStore`]: which weight
//!    units are staged in host DRAM (per tag, per-expert granularity),
//!    every cross-tier move recorded as a [`TierShift`]. The journal is
//!    what the chaos invariant
//!    [`crate::chaos::invariants::check_tier_conservation`] replays:
//!    DRAM bytes must reconcile exactly against the
//!    [`crate::device::HostMem`] allocator at every audit point.
//! 2. **Prefetch pipeline** — [`prefetch`]: a bandwidth-modeled two-stage
//!    pipeline (disk→DRAM staging in the background, DRAM→HBM on the
//!    critical path) for pre-warming a configuration concurrently with
//!    serving, per the paper's concurrent-with-serving principle.
//! 3. **Stack integration** — [`crate::hmm::HmmControl`] consults the
//!    residency map when planning scale-up legs (HBM P2P > DRAM h2d >
//!    disk), demotes cold experts under HBM pressure instead of failing
//!    the migration budget, and implements park/unpark (scale-to-zero
//!    with DRAM-resident weights); [`crate::imm::InstanceManager`] keeps
//!    a DRAM-warm second standby level; [`crate::coordinator::FleetPolicy`]
//!    chooses park over teardown when a re-burst is forecast within a
//!    TTL. `repro exp tier` measures the whole loop on a serverless-style
//!    on/off trace.
//!
//! See `docs/architecture/06-tiered-memory.md` for the tier diagram,
//! residency state machine, and park/unpark choreography.

pub mod prefetch;
pub mod store;

pub use prefetch::{
    pipelined_promote_time, sequential_stage_time, warm_promote_time,
};
pub use store::{TierShift, TieredWeightStore};

/// Where a weight unit currently lives. `Disk` is the backstop: every
/// unit is always reconstructible from the shared store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierLevel {
    /// Resident in device HBM (servable now).
    Hbm,
    /// Staged in host DRAM (an h2d copy away).
    HostDram,
    /// Only on shared disk (a cold read away).
    Disk,
}

impl TierLevel {
    pub fn label(self) -> &'static str {
        match self {
            TierLevel::Hbm => "hbm",
            TierLevel::HostDram => "dram",
            TierLevel::Disk => "disk",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(TierLevel::Hbm.label(), "hbm");
        assert_eq!(TierLevel::HostDram.label(), "dram");
        assert_eq!(TierLevel::Disk.label(), "disk");
    }
}
