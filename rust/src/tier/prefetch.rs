//! Bandwidth-modeled prefetch pipeline: disk→DRAM staging in the
//! background, DRAM→HBM promotion on the critical path.
//!
//! Warming a configuration is a two-stage pipeline over its weight
//! units: stage 1 reads a unit from the shared store into host DRAM
//! (disk bandwidth, runs in the background concurrently with serving —
//! the paper's concurrent-with-serving principle), stage 2 copies it
//! into HBM (h2d bandwidth, the only part a waiting boot actually
//! blocks on). The functions here compute the schedule's completion
//! times so boot paths and experiments can price {cold, pipelined,
//! DRAM-warm} consistently:
//!
//! - fully cold, no overlap: `sequential_stage_time` (Σ disk + Σ h2d);
//! - cold but pipelined: [`pipelined_promote_time`] — unit *i*'s
//!   promotion starts once it is staged and the h2d lane is free;
//! - DRAM-warm (already staged): only the Σ h2d term remains, which is
//!   what the park/unpark fast path pays.

use crate::device::Timings;

/// Completion time of the two-stage pipeline over `unit_bytes`, with
/// units staged in order on the disk lane and promoted in order on the
/// h2d lane. Classic pipeline recurrence: a unit's promotion starts at
/// `max(staged(i), h2d lane free)`.
pub fn pipelined_promote_time(unit_bytes: &[u64], t: &Timings) -> f64 {
    let mut staged = 0.0f64; // disk lane frontier
    let mut promoted = 0.0f64; // h2d lane frontier
    for &b in unit_bytes {
        staged += t.disk_load(b);
        promoted = staged.max(promoted) + t.h2d(b);
    }
    promoted
}

/// The no-overlap reference: stage everything, then promote everything.
pub fn sequential_stage_time(unit_bytes: &[u64], t: &Timings) -> f64 {
    let disk: f64 = unit_bytes.iter().map(|&b| t.disk_load(b)).sum();
    let h2d: f64 = unit_bytes.iter().map(|&b| t.h2d(b)).sum();
    disk + h2d
}

/// The DRAM-warm critical path: everything already staged, only the h2d
/// promotions remain.
pub fn warm_promote_time(unit_bytes: &[u64], t: &Timings) -> f64 {
    unit_bytes.iter().map(|&b| t.h2d(b)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Timings {
        Timings::cloudmatrix()
    }

    #[test]
    fn pipeline_beats_sequential_and_respects_bounds() {
        let units = vec![512 << 20; 24];
        let seq = sequential_stage_time(&units, &t());
        let pipe = pipelined_promote_time(&units, &t());
        let warm = warm_promote_time(&units, &t());
        let disk_only: f64 = units.iter().map(|&b| t().disk_load(b)).sum();
        assert!(pipe < seq, "overlap must help: {pipe} vs {seq}");
        // Lower bounds: the pipeline can never beat either lane alone.
        assert!(pipe >= disk_only, "{pipe} vs disk {disk_only}");
        assert!(pipe >= warm);
        // With disk >> h2d, the pipeline is disk-bound: within one h2d
        // unit of the disk lane.
        assert!(pipe <= disk_only + t().h2d(units[0]) + 1e-9);
        // And the warm path is an order of magnitude under both.
        assert!(warm * 10.0 < pipe);
    }

    #[test]
    fn empty_and_single_unit_degenerate_cleanly() {
        assert_eq!(pipelined_promote_time(&[], &t()), 0.0);
        assert_eq!(sequential_stage_time(&[], &t()), 0.0);
        let one = vec![1u64 << 30];
        let p = pipelined_promote_time(&one, &t());
        let s = sequential_stage_time(&one, &t());
        assert!((p - s).abs() < 1e-12, "one unit cannot overlap");
    }
}
