//! # ElasticMoE — fine-grained, zero-downtime autoscaling for MoE serving
//!
//! Reproduction of *ElasticMoE: An Efficient Auto Scaling Method for
//! Mixture-of-Experts Models* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the paper's system contribution: the
//!   [`coordinator`] (request routing, SLO-aware autoscaling, switchover),
//!   the [`hmm`] HBM Management Module (zero-copy weight/KV sharing, P2P
//!   transfers, virtual-page expert remapping), the [`imm`] Inference
//!   Management Module (pre-initialised standby instances), the serving
//!   [`engine`] (continuous batching, paged KV cache, EP token routing),
//!   plus four scaling baselines in [`scaling`].
//! - **Layer 2** — a JAX MoE transformer, AOT-lowered to HLO text
//!   (`python/compile/`), loaded and executed by [`runtime`] via PJRT.
//! - **Layer 1** — Pallas kernels for the MoE FFN and decode attention
//!   (`python/compile/kernels/`), on the hot path of the monolithic step.
//!
//! The Ascend CloudMatrix384 substrate the paper runs on is reproduced as a
//! byte-accurate simulated NPU cluster in [`device`]; see DESIGN.md §1 for
//! the substitution argument. Serving experiments run under a discrete-event
//! clock ([`sim`]); the end-to-end example runs the same system under wall
//! time with real PJRT compute.
//!
//! Above the single instance, [`coordinator::FleetSim`] composes N
//! elastically resizable replicas behind a pluggable [`coordinator::Router`]
//! with a [`coordinator::FleetPolicy`] deciding per window between vertical
//! steps, whole-replica add/drain, and hold — the hybrid deployment the
//! paper's fine-grained scaling enables. Multi-tenant traffic comes from
//! [`workload::MultiTenantGen`].
//!
//! Start with the narrative docs:
//!
//! - `docs/architecture/01-system-overview.md` — module map and data flow
//!   (config → device → hmm/imm → scaling → coordinator → experiments).
//! - `docs/architecture/02-scaling-choreography.md` — the §5.2/Fig-6
//!   scaling pipeline and exactly when `downtime` / `intake_pause` are set.
//! - `docs/architecture/04-kv-cache-lifecycle.md` — KV block lifecycle and
//!   the live-sequence handoff (remap / p2p-copy / recompute) across
//!   scaling events ([`kvmigrate`]).
//! - `docs/architecture/05-failure-model.md` — the fault taxonomy,
//!   abort/rollback protocol, and trace-invariant catalog enforced by the
//!   [`chaos`] harness (`repro exp chaos`).
//! - `docs/architecture/06-tiered-memory.md` — the tiered weight store
//!   ([`tier`]): host-DRAM staging, cold-expert offload, DRAM-warm
//!   standby instances, and park/unpark scale-to-zero
//!   (`repro exp tier`).
//! - `docs/architecture/08-observability.md` — the [`obs`] telemetry
//!   subsystem: metric catalog, scaling-event span taxonomy, Chrome
//!   trace / Prometheus exporters, and the determinism-neutrality
//!   contract (`--trace-out` / `--metrics-out`).
//! - `docs/architecture/11-reporting.md` — SLO attainment accounting
//!   ([`obs::attain`]), the scaling-decision ledger, and the
//!   `repro report` postmortem generator ([`report`]).
//! - `README.md` — quickstart, experiment and bench commands, and the
//!   repro matrix mapping `repro exp` ids to paper artifacts.

pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod engine;
pub mod experiments;
pub mod hmm;
pub mod imm;
pub mod kvmigrate;
pub mod metrics;
pub mod obs;
pub mod placement;
pub mod report;
pub mod runtime;
pub mod scaling;
pub mod sim;
pub mod tier;
pub mod util;
pub mod workload;
