//! Data-plane payload store for the live (PJRT) path.
//!
//! In the paper, weight bytes live in device HBM; in this reproduction the
//! simulated devices track *byte accounting* while the actual tensor
//! payloads (for the e2e model) live here, keyed by (device, region). A
//! payload is the ordered tensor group of one weight unit (e.g. an expert's
//! `[w1, w3, w2]`). The P2P primitive moves payloads between devices so
//! numerics genuinely travel with migrations; simulation-only experiments
//! run with an empty store.

use std::collections::HashMap;
use std::rc::Rc;

use crate::device::{DeviceId, RegionId};
use crate::runtime::HostTensor;

/// Ordered tensors of one weight unit.
pub type Payload = Rc<Vec<HostTensor>>;

/// Payloads by (device, region). `Rc` because zero-copy sharing hands the
/// same physical bytes to multiple readers.
#[derive(Debug, Default)]
pub struct TensorStore {
    payloads: HashMap<(DeviceId, RegionId), Payload>,
}

impl TensorStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&mut self, dev: DeviceId, region: RegionId, t: Payload) {
        self.payloads.insert((dev, region), t);
    }

    pub fn get(&self, dev: DeviceId, region: RegionId) -> Option<Payload> {
        self.payloads.get(&(dev, region)).cloned()
    }

    pub fn remove(&mut self, dev: DeviceId, region: RegionId) {
        self.payloads.remove(&(dev, region));
    }

    /// Copy a payload between devices (the data plane of `p2p_copy`).
    /// Returns whether a payload existed at the source.
    pub fn copy(
        &mut self,
        src: (DeviceId, RegionId),
        dst: (DeviceId, RegionId),
    ) -> bool {
        if let Some(t) = self.payloads.get(&src).cloned() {
            // Physical copy on the destination device: new allocation.
            self.payloads.insert(dst, Rc::new((*t).clone()));
            true
        } else {
            false
        }
    }

    pub fn len(&self) -> usize {
        self.payloads.len()
    }
    pub fn is_empty(&self) -> bool {
        self.payloads.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_copy_remove() {
        let mut s = TensorStore::new();
        let t: Payload = Rc::new(vec![
            HostTensor::f32(vec![2], vec![1.0, 2.0]),
            HostTensor::f32(vec![1], vec![3.0]),
        ]);
        s.put(0, 10, t.clone());
        assert!(s.get(0, 10).is_some());
        assert!(s.get(1, 10).is_none());

        assert!(s.copy((0, 10), (1, 20)));
        let moved = s.get(1, 20).unwrap();
        assert_eq!(moved.len(), 2);
        assert_eq!(
            moved[0].as_f32().unwrap(),
            t[0].as_f32().unwrap()
        );
        // Deep copy: distinct allocation.
        assert!(!Rc::ptr_eq(&moved, &t));

        assert!(!s.copy((5, 5), (6, 6)));
        s.remove(0, 10);
        assert!(s.get(0, 10).is_none());
        assert_eq!(s.len(), 1);
    }
}
