//! Low-level HMM primitives (§4.6, Appendix D): `disk-copy`, `p2p-copy`,
//! `zero-copy`. Each operates on the simulated cluster (byte accounting),
//! optionally moves real payloads in the [`TensorStore`] (live path), and
//! returns the simulated time cost it charges.

use anyhow::Result;

use crate::device::hbm::RegionKind;
use crate::device::ipc::ProcId;
use crate::device::{Cluster, DeviceId, RegionId};

use super::store::{Payload, TensorStore};

/// `disk-copy` (D.2): read one weight unit from the shared store into a
/// device. Deduplicated: only the first read of a tag pays disk time —
/// later replicas should come from P2P instead.
pub fn disk_copy(
    cluster: &mut Cluster,
    store: &mut TensorStore,
    dev: DeviceId,
    tag: &str,
    bytes: u64,
    kind: RegionKind,
    ipc_safe: bool,
    payload: Option<Payload>,
) -> Result<(RegionId, f64)> {
    let region = cluster.devices[dev].hbm.alloc(bytes, kind, ipc_safe, tag)?;
    let t = cluster.disk.read_dedup(tag, bytes)
        + cluster.timings.alloc_per_region;
    if let Some(p) = payload {
        store.put(dev, region, p);
    }
    Ok((region, t))
}

/// `p2p-copy` (D.3): allocate on the destination and transfer directly from
/// the source device over the UB fabric, bypassing host memory. Returns the
/// destination region and the *single-transfer* time; callers aggregate
/// concurrent transfers through [`crate::device::Interconnect`].
pub fn p2p_copy(
    cluster: &mut Cluster,
    store: &mut TensorStore,
    src: DeviceId,
    src_region: RegionId,
    dst: DeviceId,
    tag: &str,
    kind: RegionKind,
    ipc_safe: bool,
) -> Result<(RegionId, f64)> {
    let bytes = cluster.devices[src]
        .hbm
        .region(src_region)
        .ok_or_else(|| anyhow::anyhow!("p2p source region {src_region} missing on dev {src}"))?
        .bytes;
    let dst_region =
        cluster.devices[dst].hbm.alloc(bytes, kind, ipc_safe, tag)?;
    store.copy((src, src_region), (dst, dst_region));
    let t = cluster.timings.p2p(bytes) + cluster.timings.alloc_per_region;
    Ok((dst_region, t))
}

/// `zero-copy` (D.4): share a resident region with another process. Export
/// the handle, whitelist the destination process, open it there, and bump
/// the region refcount. No data moves; cost is the control-plane handle
/// round-trip (plus a staging penalty when the region was not allocated
/// IPC-safe — the `-IPCAlloc` ablation).
pub fn zero_copy(
    cluster: &mut Cluster,
    dev: DeviceId,
    region: RegionId,
    owner: ProcId,
    to_proc: ProcId,
) -> Result<f64> {
    let (ipc_safe, tag) = {
        let r = cluster.devices[dev]
            .hbm
            .region(region)
            .ok_or_else(|| anyhow::anyhow!("zero-copy region {region} missing on dev {dev}"))?;
        (r.ipc_safe, r.tag.clone())
    };
    let mut t = cluster.timings.zero_copy_per_handle;
    if ipc_safe {
        let name = format!("ipc:{dev}:{region}:{tag}:{to_proc}");
        cluster.ipc.export(&name, dev, region, owner)?;
        cluster.ipc.whitelist(&name, to_proc)?;
        cluster.ipc.open(&name, to_proc)?;
        cluster.devices[dev].hbm.share(region)?;
    } else {
        // Non-IPC-safe allocations cannot be shared directly: the runtime
        // stages a private re-registration, slower and without physical
        // sharing (the caller duplicates the region for true isolation).
        t += cluster.timings.non_ipc_share_penalty;
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::rc::Rc;

    use crate::runtime::HostTensor;

    fn setup() -> (Cluster, TensorStore) {
        (Cluster::cloudmatrix(4), TensorStore::new())
    }

    #[test]
    fn disk_copy_dedups_and_allocates() {
        let (mut c, mut s) = setup();
        let (r0, t0) =
            disk_copy(&mut c, &mut s, 0, "w", 1 << 30, RegionKind::AttnWeights, true, None)
                .unwrap();
        assert!(t0 > 0.5); // ~0.67 s at 1.5 GB/s
        let (_r1, t1) =
            disk_copy(&mut c, &mut s, 1, "w", 1 << 30, RegionKind::AttnWeights, true, None)
                .unwrap();
        assert!(t1 < 0.01, "second read of same tag must be ~free: {t1}");
        assert!(c.devices[0].hbm.region(r0).is_some());
        assert_eq!(c.devices[1].hbm.used(), 1 << 30);
    }

    #[test]
    fn p2p_copy_moves_bytes_and_payload() {
        let (mut c, mut s) = setup();
        let payload: Payload =
            Rc::new(vec![HostTensor::f32(vec![2], vec![5.0, 6.0])]);
        let (r_src, _) = disk_copy(
            &mut c, &mut s, 0, "e", 100 << 20, RegionKind::ExpertWeights,
            true, Some(payload),
        )
        .unwrap();
        let (r_dst, t) = p2p_copy(
            &mut c, &mut s, 0, r_src, 3, "e", RegionKind::ExpertWeights, true,
        )
        .unwrap();
        assert!(t < 0.01, "p2p of 100 MB should be ms-scale: {t}");
        assert_eq!(c.devices[3].hbm.used(), c.devices[0].hbm.used());
        let moved = s.get(3, r_dst).unwrap();
        assert_eq!(moved[0].as_f32().unwrap(), &[5.0, 6.0]);
    }

    #[test]
    fn p2p_is_much_faster_than_disk() {
        let (mut c, mut s) = setup();
        let bytes = 4u64 << 30;
        let (r, t_disk) = disk_copy(
            &mut c, &mut s, 0, "big", bytes, RegionKind::AttnWeights, true, None,
        )
        .unwrap();
        let (_, t_p2p) =
            p2p_copy(&mut c, &mut s, 0, r, 1, "big", RegionKind::AttnWeights, true)
                .unwrap();
        assert!(t_disk / t_p2p > 10.0, "disk {t_disk} vs p2p {t_p2p}");
    }

    #[test]
    fn zero_copy_shares_without_allocating() {
        let (mut c, mut s) = setup();
        let (r, _) = disk_copy(
            &mut c, &mut s, 0, "w", 1 << 30, RegionKind::AttnWeights, true, None,
        )
        .unwrap();
        let used_before = c.devices[0].hbm.used();
        let t = zero_copy(&mut c, 0, r, 0, 42).unwrap();
        assert!(t < 0.005);
        assert_eq!(c.devices[0].hbm.used(), used_before);
        assert_eq!(c.devices[0].hbm.region(r).unwrap().refcount, 2);
        assert_eq!(c.ipc.len(), 1);
    }

    #[test]
    fn non_ipc_zero_copy_pays_penalty_and_does_not_share() {
        let (mut c, mut s) = setup();
        let (r, _) = disk_copy(
            &mut c, &mut s, 0, "w", 1 << 30, RegionKind::AttnWeights, false, None,
        )
        .unwrap();
        let t_safe_baseline = c.timings.zero_copy_per_handle;
        let t = zero_copy(&mut c, 0, r, 0, 42).unwrap();
        assert!(t > t_safe_baseline);
        assert_eq!(c.devices[0].hbm.region(r).unwrap().refcount, 1);
    }
}
