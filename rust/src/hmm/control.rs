//! HMM control plane (§4.4): cluster-wide state, scaling-plan computation
//! and execution, and zero-copy distribution of weight/KV references to
//! inference instances.
//!
//! In the paper this is a Ray-based daemon coordinating per-device workers;
//! here it is a single-owner struct driving the simulated cluster (and, on
//! the live path, the real tensor payloads) through the primitives.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::chaos::{FaultInjector, FaultKind};
use crate::config::{ModelConfig, ParallelConfig};
use crate::device::hbm::RegionKind;
use crate::device::ipc::ProcId;
use crate::device::{Cluster, DeviceId, RegionId};
use crate::engine::moe::Routing;
use crate::engine::CostModel;
use crate::kvmigrate::{plan_kv_migration, KvSnapshot, KvVerdict};
use crate::placement::{
    solve_layer, ExpertLoadStats, LayerPlacementInput, PlacementConfig,
    PlacementMode,
};
use crate::tier::TieredWeightStore;

use super::plan::{PlanOp, ScalePlan};
use super::primitives::{disk_copy, p2p_copy, zero_copy};
use super::store::{Payload, TensorStore};
use super::weights::{UnitKind, WeightLayout, WeightUnit};
use super::worker::Worker;

/// Feature flags for the ablation study (Table 1/3). Flags are cumulative
/// in the paper's table but independent here; the experiment disables them
/// progressively.
#[derive(Debug, Clone, Copy)]
pub struct HmmOptions {
    /// IpcSafeAllocator: allocations are IPC-sharable (D.1).
    pub ipc_safe_alloc: bool,
    /// HCCL P2P transfers; when false, new devices reload from disk (D.3).
    pub use_p2p: bool,
    /// Virtual-page expert remap; when false, expert reshaping reallocates
    /// and copies contiguous buffers (D.5).
    pub use_vpage: bool,
    /// Zero-copy sharing; when false, every instance duplicates weights and
    /// KV — which also forces downtime (old instance must stop first).
    pub use_zero_copy: bool,
}

impl Default for HmmOptions {
    fn default() -> Self {
        HmmOptions {
            ipc_safe_alloc: true,
            use_p2p: true,
            use_vpage: true,
            use_zero_copy: true,
        }
    }
}

/// Loader for live payloads: (unit, tp_rank) -> tensors. `None` for
/// simulation-only models.
pub type PayloadLoader = Box<dyn Fn(&WeightUnit, usize) -> Option<Payload>>;

/// Stage-level timing of an executed scaling plan (drives Fig 11).
#[derive(Debug, Clone, Default)]
pub struct ScaleStats {
    pub attn_p2p_time: f64,
    pub expert_p2p_time: f64,
    pub remap_time: f64,
    pub kv_init_time: f64,
    /// Host-DRAM → HBM promotion time (tier legs: staged shard loads,
    /// cold-expert promotions). Max over devices — h2d lanes run in
    /// parallel. Included in [`Self::total`].
    pub h2d_time: f64,
    /// HBM → host-DRAM demotion time (cold-expert offload under HBM
    /// pressure). Max over devices; included in [`Self::total`].
    pub d2h_time: f64,
    /// Non-vpage realloc penalty (ablation only).
    pub realloc_time: f64,
    /// Time spent undoing applied ops after a fault aborted the plan
    /// (modelled as one O(1) page-table/control op per undone op). Zero
    /// on successful executions; included in [`Self::total`].
    pub rollback_time: f64,
    /// Live-sequence KV handoff: fabric time of the block copies plus the
    /// per-sequence page-table handovers. NOT included in [`Self::total`]:
    /// the weight work runs in the serving-concurrent phase, while KV
    /// copies run inside the switchover window (the owning sequences are
    /// suspended so their blocks stay byte-stable) — the scaling method
    /// adds this to the switchover stage instead.
    pub kv_migrate_time: f64,
    /// Sum of the serving-concurrent stages (excludes
    /// [`Self::kv_migrate_time`]).
    pub total: f64,
    /// Stage placement for the span timeline
    /// (`docs/architecture/08-observability.md`): `(name, start, end)`
    /// offsets in seconds relative to the transfer start, laid in
    /// execution order over the components of [`Self::total`].
    /// Zero-duration stages are omitted, so the marks sum to `total`.
    pub stage_marks: Vec<(&'static str, f64, f64)>,
}

impl ScaleStats {
    /// Rebuild [`Self::stage_marks`] from the component times, in the
    /// order `execute_plan` runs them. Called at both exits (success and
    /// abort) once the component times are final.
    fn mark_stages(&mut self) {
        let chain = [
            ("hmm_attn_p2p", self.attn_p2p_time),
            ("hmm_expert_migration", self.expert_p2p_time),
            ("hmm_vpage_remap", self.remap_time),
            ("tier_h2d", self.h2d_time),
            ("tier_d2h", self.d2h_time),
            ("hmm_realloc", self.realloc_time),
            ("kv_init", self.kv_init_time),
            ("rollback", self.rollback_time),
        ];
        let mut t = 0.0;
        self.stage_marks.clear();
        for (name, dur) in chain {
            if dur > 0.0 {
                self.stage_marks.push((name, t, t + dur));
                t += dur;
            }
        }
    }
}

/// Per-op outcome of a plan execution (see
/// [`HmmControl::execute_plan`]). A successful execution is all
/// [`StepOutcome::Applied`]; an aborted one has exactly one
/// [`StepOutcome::Faulted`] op, [`StepOutcome::RolledBack`] before it and
/// [`StepOutcome::Skipped`] after it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepOutcome {
    /// The op was applied and is in effect.
    Applied,
    /// The op was applied, then undone when a later op faulted.
    RolledBack,
    /// The op hit an injected fault; the plan aborted here.
    Faulted(FaultKind),
    /// The op was never reached (the plan aborted earlier).
    Skipped,
}

/// Why and where a plan execution aborted.
#[derive(Debug, Clone, PartialEq)]
pub struct AbortReport {
    /// The injected fault that fired.
    pub fault: FaultKind,
    /// Index into the plan's ops where it fired.
    pub op_index: usize,
    /// Rollback completed: the cluster, virtual-page tables and deferred
    /// frees are back in their exact pre-plan state.
    pub rolled_back: bool,
    /// Human-readable summary for logs and the event trace.
    pub reason: String,
}

/// Result of [`HmmControl::execute_plan`]: stage timings, one
/// [`StepOutcome`] per plan op, and the abort report when an injected
/// fault cut the plan short (in which case every applied op has been
/// rolled back and the pre-plan configuration is still current).
#[derive(Debug, Clone)]
pub struct PlanExecution {
    pub stats: ScaleStats,
    /// One outcome per plan op, in op order.
    pub steps: Vec<StepOutcome>,
    /// `Some` when a fault aborted the event.
    pub aborted: Option<AbortReport>,
}

/// Undo record for one applied plan op (rollback bookkeeping).
enum UndoOp {
    /// A non-expert shard was copied to `dev` and registered under `tag`.
    AttnRegion {
        dev: DeviceId,
        tag: String,
        region: RegionId,
    },
    /// An expert was copied to `dev` and bound into its vpage table.
    ExpertBound {
        layer: usize,
        expert: usize,
        dev: DeviceId,
        region: RegionId,
    },
    /// An expert was unbound from `dev` (its region queued for deferred
    /// free).
    ExpertEvicted {
        layer: usize,
        expert: usize,
        dev: DeviceId,
        region: RegionId,
    },
    /// A departing device's shards and KV were queued for deferred free.
    ShardReleased {
        dev: DeviceId,
        regions: Vec<(String, RegionId)>,
        kv: Option<RegionId>,
    },
    /// A fresh KV cache was allocated on `dev`.
    KvAllocated {
        dev: DeviceId,
        region: RegionId,
        prev: Option<RegionId>,
    },
    /// A staged shard was promoted from host DRAM into HBM on `dev`.
    HostLoaded {
        dev: DeviceId,
        tag: String,
        region: RegionId,
        bytes: u64,
    },
    /// A cold expert was demoted from `dev` into host DRAM (its HBM
    /// pages queued for deferred free).
    ExpertDemoted {
        layer: usize,
        expert: usize,
        dev: DeviceId,
        region: RegionId,
        bytes: u64,
    },
    /// A demoted expert was promoted from host DRAM back onto `dev`.
    ExpertPromoted {
        layer: usize,
        expert: usize,
        dev: DeviceId,
        region: RegionId,
        bytes: u64,
    },
}

/// The weight/KV references handed to one inference instance: its private
/// snapshot of the memory layout. Old instances keep serving from their
/// snapshot while the control plane prepares the next one — this is what
/// makes scale-while-serve safe.
#[derive(Debug, Clone)]
pub struct InstanceBinding {
    pub proc: ProcId,
    pub parallel: ParallelConfig,
    /// Per device: (tag, region) of non-expert units.
    pub attn_regions: BTreeMap<DeviceId, Vec<(String, RegionId)>>,
    /// `[layer][expert] -> (device, region)`.
    pub expert_map: Vec<BTreeMap<usize, (DeviceId, RegionId)>>,
    /// Per device KV-cache region.
    pub kv_regions: BTreeMap<DeviceId, RegionId>,
}

/// The HMM control plane.
pub struct HmmControl {
    pub cluster: Rc<RefCell<Cluster>>,
    pub model: ModelConfig,
    pub opts: HmmOptions,
    /// Expert-placement policy (load-aware solver, migration budget).
    pub placement: PlacementConfig,
    /// Tiered weight residency: which units are staged in host DRAM
    /// (plan source selection consults it; park/unpark and cold-expert
    /// demotion feed it), plus the cross-tier journal the chaos
    /// conservation invariant replays.
    pub tier: TieredWeightStore,
    pub store: TensorStore,
    workers: BTreeMap<DeviceId, Worker>,
    loader: Option<PayloadLoader>,
    /// Current (target) configuration and its layout.
    layout: Option<(ParallelConfig, WeightLayout)>,
    /// Source of truth for expert ownership: `[layer][expert] -> device`.
    /// Updated by plan execution (layout recomputation would lose the
    /// minimal-movement placement history).
    expert_owner: Vec<Vec<DeviceId>>,
    /// Zero-copy references held by each attached instance.
    attachments: HashMap<ProcId, Vec<(DeviceId, RegionId)>>,
    /// Regions owned by duplicated (non-zero-copy) instances.
    private_regions: HashMap<ProcId, Vec<(DeviceId, RegionId)>>,
    /// Orphaned expert pages freed at switchover.
    deferred_frees: Vec<(DeviceId, RegionId)>,
    /// EWMA expert popularity, fed via [`Self::record_routing`].
    load_stats: Option<ExpertLoadStats>,
    /// Chaos hook: consulted at plan time (budget pressure) and at every
    /// fabric leg / device touch of [`Self::execute_plan`].
    injector: Option<Rc<RefCell<FaultInjector>>>,
    kv_bytes_per_device: u64,
    next_proc: ProcId,
}

impl HmmControl {
    pub fn new(
        cluster: Rc<RefCell<Cluster>>,
        model: ModelConfig,
        opts: HmmOptions,
    ) -> Self {
        HmmControl {
            cluster,
            model,
            opts,
            placement: PlacementConfig::default(),
            tier: TieredWeightStore::new(),
            store: TensorStore::new(),
            workers: BTreeMap::new(),
            loader: None,
            layout: None,
            expert_owner: Vec::new(),
            attachments: HashMap::new(),
            private_regions: HashMap::new(),
            deferred_frees: Vec::new(),
            load_stats: None,
            injector: None,
            kv_bytes_per_device: 0,
            next_proc: 1,
        }
    }

    pub fn set_loader(&mut self, loader: PayloadLoader) {
        self.loader = Some(loader);
    }

    /// Install a chaos fault injector (shared with the serving simulator,
    /// which drains its fired-fault records into the event trace).
    pub fn set_fault_injector(&mut self, inj: Rc<RefCell<FaultInjector>>) {
        self.injector = Some(inj);
    }

    pub fn alloc_proc(&mut self) -> ProcId {
        let p = self.next_proc;
        self.next_proc += 1;
        p
    }

    pub fn current_parallel(&self) -> Option<&ParallelConfig> {
        self.layout.as_ref().map(|(p, _)| p)
    }

    pub fn worker(&self, dev: DeviceId) -> Option<&Worker> {
        self.workers.get(&dev)
    }

    fn load_payload(&self, unit: &WeightUnit, tp_rank: usize) -> Option<Payload> {
        self.loader.as_ref().and_then(|f| f(unit, tp_rank))
    }

    /// ---- initial boot ----------------------------------------------------

    /// Load the initial configuration from disk: every unit is read once
    /// (disk-copy dedup) and replicas come over P2P. Also allocates KV
    /// caches. Returns the memory-operation time (max over devices, which
    /// load in parallel).
    pub fn load_initial(
        &mut self,
        parallel: &ParallelConfig,
        kv_bytes_per_device: u64,
    ) -> Result<f64> {
        parallel.check_model(&self.model)?;
        let layout = WeightLayout::compute(&self.model, parallel);
        let mut cluster = self.cluster.borrow_mut();
        let ipc = self.opts.ipc_safe_alloc;
        // tag -> (device, region) of the first resident copy.
        let mut first_copy: HashMap<String, (DeviceId, RegionId)> = HashMap::new();
        let mut busy: BTreeMap<DeviceId, f64> = BTreeMap::new();

        for &dev in &parallel.devices {
            self.workers.entry(dev).or_insert_with(|| Worker::new(dev));
        }
        for &dev in &parallel.devices {
            let rank = layout.tp_rank[&dev];
            for unit in layout.units(dev) {
                let tag = unit.tag(rank);
                let kind = if unit.is_expert() {
                    RegionKind::ExpertWeights
                } else {
                    RegionKind::AttnWeights
                };
                let payload = self.load_payload(unit, rank);
                let (region, t) = if let Some(&(src_dev, src_region)) =
                    first_copy.get(&tag)
                {
                    if self.opts.use_p2p {
                        let (r, t) = p2p_copy(
                            &mut cluster, &mut self.store, src_dev,
                            src_region, dev, &tag, kind, ipc,
                        )?;
                        *busy.entry(src_dev).or_default() += t;
                        (r, t)
                    } else {
                        disk_copy(
                            &mut cluster, &mut self.store, dev,
                            &format!("{tag}#{dev}"), unit.bytes, kind, ipc,
                            payload,
                        )?
                    }
                } else {
                    let (r, t) = disk_copy(
                        &mut cluster, &mut self.store, dev, &tag, unit.bytes,
                        kind, ipc, payload,
                    )?;
                    first_copy.insert(tag.clone(), (dev, r));
                    (r, t)
                };
                *busy.entry(dev).or_default() += t;
                let worker = self.workers.get_mut(&dev).unwrap();
                match unit.kind {
                    UnitKind::Expert { layer, expert } => {
                        worker.vpages.bind(layer, expert, region)?;
                    }
                    _ => {
                        worker.regions.insert(tag, region);
                    }
                }
            }
            // KV cache allocation.
            let kv = cluster.devices[dev].hbm.alloc(
                kv_bytes_per_device,
                RegionKind::KvCache,
                ipc,
                "kv",
            )?;
            *busy.entry(dev).or_default() +=
                cluster.timings.kv_alloc(kv_bytes_per_device);
            self.workers.get_mut(&dev).unwrap().kv_region = Some(kv);
        }
        self.kv_bytes_per_device = kv_bytes_per_device;
        self.expert_owner = layout.expert_owner.clone();
        self.layout = Some((parallel.clone(), layout));
        Ok(busy.values().cloned().fold(0.0, f64::max))
    }

    /// Minimal-movement balanced expert placement: keep every expert on its
    /// current device where possible (subject to balanced per-rank target
    /// counts), moving only the overflow and the experts on departing
    /// devices ("global remapping ... while minimizing data transfer", §5.2).
    fn rebalance_experts(
        current: &[DeviceId],
        to: &ParallelConfig,
    ) -> Vec<DeviceId> {
        let n = current.len();
        let ep = to.ep;
        // Balanced targets: first (n % ep) ranks take one extra.
        let base = n / ep;
        let extra = n % ep;
        let mut target: BTreeMap<DeviceId, usize> = BTreeMap::new();
        for (rank, &dev) in to.devices.iter().enumerate() {
            target.insert(dev, base + usize::from(rank < extra));
        }
        let mut count: BTreeMap<DeviceId, usize> = BTreeMap::new();
        let mut owner = vec![DeviceId::MAX; n];
        let mut pending = Vec::new();
        for (e, &cur) in current.iter().enumerate() {
            let keep = target
                .get(&cur)
                .map(|&t| count.get(&cur).copied().unwrap_or(0) < t)
                .unwrap_or(false);
            if keep {
                owner[e] = cur;
                *count.entry(cur).or_default() += 1;
            } else {
                pending.push(e);
            }
        }
        // Fill under-target devices in rank order (deterministic).
        let mut fill = to.devices.iter().copied().cycle();
        for e in pending {
            loop {
                let dev = fill.next().unwrap();
                let c = count.entry(dev).or_default();
                if *c < target[&dev] {
                    owner[e] = dev;
                    *c += 1;
                    break;
                }
            }
        }
        owner
    }

    /// ---- load-aware placement ---------------------------------------------

    /// Fold one step's routing decision for `layer` into the expert
    /// popularity stats (created lazily from the model dimensions).
    pub fn record_routing(&mut self, layer: usize, routing: &Routing) {
        let (n_layers, n_experts, alpha) = (
            self.model.n_layers as usize,
            self.model.n_experts as usize,
            self.placement.ewma_alpha,
        );
        let stats = self.load_stats.get_or_insert_with(|| {
            ExpertLoadStats::new(n_layers, n_experts, alpha)
        });
        stats.observe(layer, routing);
    }

    pub fn load_stats(&self) -> Option<&ExpertLoadStats> {
        self.load_stats.as_ref()
    }

    /// Current owner map of `layer` (`[expert] -> device`).
    pub fn expert_owners(&self, layer: usize) -> Option<&[DeviceId]> {
        self.expert_owner.get(layer).map(|v| v.as_slice())
    }

    /// Predicted max/mean per-device expert token load of the current
    /// placement, aggregated over all layers (1.0 when no stats or no
    /// layout — balanced as far as anyone knows).
    pub fn placement_imbalance(&self) -> f64 {
        let (Some(stats), Some((parallel, _))) =
            (&self.load_stats, &self.layout)
        else {
            return 1.0;
        };
        let mut dload: BTreeMap<DeviceId, f64> =
            parallel.devices.iter().map(|&d| (d, 0.0)).collect();
        for (layer, owners) in self.expert_owner.iter().enumerate() {
            let load = stats.predicted(layer);
            for (e, &dev) in owners.iter().enumerate() {
                if let Some(v) = dload.get_mut(&dev) {
                    *v += load[e];
                }
            }
        }
        let loads: Vec<f64> = dload.into_values().collect();
        crate::placement::imbalance(&loads)
    }

    /// Owner map for one layer of the target configuration: load-aware
    /// (solver) when enabled and the layer has observations, else
    /// count-balanced minimal movement. Returns the owners and the
    /// discretionary migration bytes consumed from `budget_bytes`.
    fn plan_layer_owners(
        &self,
        layer: usize,
        to: &ParallelConfig,
        budget_bytes: u64,
    ) -> (Vec<DeviceId>, u64) {
        if self.placement.mode == PlacementMode::LoadAware {
            if let Some(stats) =
                self.load_stats.as_ref().filter(|s| s.steps(layer) > 0)
            {
                let n = self.model.n_experts as usize;
                let capacity = n.div_ceil(to.devices.len())
                    + self.placement.capacity_slack;
                let out = solve_layer(&LayerPlacementInput {
                    devices: &to.devices,
                    current: &self.expert_owner[layer],
                    load: stats.predicted(layer),
                    bytes_per_expert: self.model.expert_bytes(),
                    capacity,
                    budget_bytes,
                    uniform_prior: self.placement.uniform_prior,
                });
                return (out.owner, out.discretionary_bytes);
            }
        }
        (Self::rebalance_experts(&self.expert_owner[layer], to), 0)
    }

    /// ---- scaling ----------------------------------------------------------

    /// Redistribution-only plan: same configuration, new expert placement.
    /// Triggered when popularity skew has degraded token balance rather
    /// than by a capacity change; under the default
    /// [`PlacementMode::MinMove`] it plans zero migrations.
    pub fn plan_rebalance(&self) -> Result<ScalePlan> {
        let to = self
            .current_parallel()
            .context("HMM not initialised (call load_initial)")?
            .clone();
        self.plan_scale(&to)
    }

    /// Compute the minimal-cost redistribution plan from the current
    /// configuration to `to` (§5.2 "HMM Reconfigures Memory Layout").
    /// Expert owners come from the load-aware solver when
    /// [`PlacementMode::LoadAware`] is active and routing stats exist;
    /// otherwise from count-balanced minimal movement. Plans weights only;
    /// use [`Self::plan_scale_with_kv`] to also carry live sequences.
    pub fn plan_scale(&self, to: &ParallelConfig) -> Result<ScalePlan> {
        self.plan_scale_with_kv(to, None)
    }

    /// Like [`Self::plan_scale`], but additionally plans the handoff of
    /// every live sequence's KV blocks (`kv` is the ownership snapshot
    /// taken at the scale command): remap legs for sequences whose device
    /// group survives, P2P copy legs for movers (sharing the expert
    /// migration's byte budget — experts are planned first, KV copies
    /// consume the leftover), and drop-recompute legs only where
    /// re-prefill is cheaper than the transfer or the budget ran out.
    pub fn plan_scale_with_kv(
        &self,
        to: &ParallelConfig,
        kv: Option<&KvSnapshot>,
    ) -> Result<ScalePlan> {
        let (from, from_layout) = self
            .layout
            .as_ref()
            .context("HMM not initialised (call load_initial)")?;
        to.check_model(&self.model)?;
        if to.tp != from.tp {
            bail!(
                "TP must stay fixed during scaling (paper §4.1): {} -> {}",
                from.tp,
                to.tp
            );
        }
        let to_layout = WeightLayout::compute(&self.model, to);
        let mut ops = Vec::new();

        let survivors: Vec<DeviceId> = to
            .devices
            .iter()
            .copied()
            .filter(|d| from.devices.contains(d))
            .collect();
        let newcomers: Vec<DeviceId> = to
            .devices
            .iter()
            .copied()
            .filter(|d| !from.devices.contains(d))
            .collect();

        // Non-expert units: reuse on survivors, P2P to newcomers from the
        // TP-rank-matched survivor.
        for &dev in &survivors {
            let rank = to_layout.tp_rank[&dev];
            for unit in to_layout.units(dev) {
                if !unit.is_expert() {
                    ops.push(PlanOp::ZeroCopyReuse {
                        dev,
                        tag: unit.tag(rank),
                        bytes: unit.bytes,
                    });
                }
            }
            ops.push(PlanOp::KvReuse { dev });
        }
        // Newcomer shards source from the cheapest reachable tier:
        // HBM P2P from a rank-matched survivor, else host-DRAM h2d when
        // the unit is staged, else disk (the P2pAttn op degrades to a
        // disk reload when the HCCL ablation disables the fabric). A
        // tag's first HostLoad consumes its staging copy, so same-rank
        // replicas chain off the freshly loaded device over P2P —
        // exactly the dedup'd-read discipline of Appendix D.2.
        let mut host_loaded: HashMap<String, DeviceId> = HashMap::new();
        for &dev in &newcomers {
            let rank = to_layout.tp_rank[&dev];
            // Source: a current device with the same TP rank.
            let src = from
                .devices
                .iter()
                .copied()
                .find(|d| from_layout.tp_rank[d] == rank);
            for unit in to_layout.units(dev) {
                if unit.is_expert() {
                    continue;
                }
                let tag = unit.tag(rank);
                if let (Some(src), true) = (src, self.opts.use_p2p) {
                    ops.push(PlanOp::P2pAttn {
                        src,
                        dst: dev,
                        tag,
                        bytes: unit.bytes,
                    });
                } else if let Some(&staged_on) = host_loaded.get(&tag) {
                    ops.push(PlanOp::P2pAttn {
                        src: staged_on,
                        dst: dev,
                        tag,
                        bytes: unit.bytes,
                    });
                } else if self.tier.dram_resident(&tag).is_some() {
                    host_loaded.insert(tag.clone(), dev);
                    ops.push(PlanOp::HostLoad {
                        dev,
                        tag,
                        bytes: unit.bytes,
                    });
                } else if let Some(src) = src {
                    ops.push(PlanOp::P2pAttn {
                        src,
                        dst: dev,
                        tag,
                        bytes: unit.bytes,
                    });
                } else {
                    bail!(
                        "no TP-rank-matched source for new device and \
                         '{tag}' is not DRAM-staged"
                    );
                }
            }
            ops.push(PlanOp::KvInit {
                dev,
                bytes: self.kv_bytes_per_device,
            });
        }

        // Departing devices release their attention shards and KV (their
        // experts are migrated below; the frees are deferred to drain).
        for &dev in &from.devices {
            if !to.devices.contains(&dev) {
                ops.push(PlanOp::ReleaseShard { dev });
            }
        }

        // Experts: migrate only owner changes. The migration-byte budget
        // is split evenly across layers, leftovers carrying forward.
        // Chaos hook: drawing a plan opens the injector's event scope, and
        // an armed HBM-pressure fault shrinks the budget for this event
        // (the KV planner then falls back to recompute verdicts earlier).
        let budget_factor = self
            .injector
            .as_ref()
            .map(|inj| {
                let mut inj = inj.borrow_mut();
                inj.begin_event();
                inj.budget_factor()
            })
            .unwrap_or(1.0);
        let effective_budget = if budget_factor >= 1.0 {
            self.placement.migration_budget_bytes
        } else {
            (self.placement.migration_budget_bytes as f64 * budget_factor)
                as u64
        };
        let n_layers = self.model.n_layers as usize;
        let mut budget = effective_budget;
        let mut effective_budget = effective_budget;
        let under_pressure = budget_factor < 1.0;
        // Experts currently offloaded to host DRAM: not HBM-resident, so
        // they can neither P2P-migrate nor zero-copy-reuse. A
        // pressure-free event promotes them back onto their (possibly
        // new) owner; while pressure persists they stay DRAM-backed
        // unless their owner departs the device set.
        let demoted: std::collections::HashSet<(usize, usize)> = self
            .tier
            .demoted_experts()
            .into_iter()
            .map(|(l, e, _, _)| (l, e))
            .collect();
        // Stay-put survivor experts eligible for cold demotion this event
        // (collected while walking the placement; ranked below).
        let mut demotable: Vec<(usize, usize, DeviceId)> = Vec::new();
        for layer in 0..n_layers {
            let layer_budget = budget / (n_layers - layer) as u64;
            let (new_owners, used) =
                self.plan_layer_owners(layer, to, layer_budget);
            budget = budget.saturating_sub(used);
            for e in 0..self.model.n_experts as usize {
                let old_owner = self.expert_owner[layer][e];
                let new_owner = new_owners[e];
                if demoted.contains(&(layer, e)) {
                    // DRAM-backed: promote when pressure is off, or when
                    // the logical owner leaves the target set (the expert
                    // must land somewhere servable).
                    if !under_pressure || !to.devices.contains(&old_owner) {
                        ops.push(PlanOp::PromoteExpert {
                            layer,
                            expert: e,
                            dev: new_owner,
                            bytes: self.model.expert_bytes(),
                        });
                    }
                } else if old_owner == new_owner {
                    ops.push(PlanOp::ZeroCopyReuse {
                        dev: new_owner,
                        tag: format!("layer{layer}.expert{e}"),
                        bytes: self.model.expert_bytes(),
                    });
                    if under_pressure
                        && self.placement.demote_on_pressure
                        && survivors.contains(&new_owner)
                    {
                        demotable.push((layer, e, new_owner));
                    }
                } else {
                    ops.push(PlanOp::MigrateExpert {
                        layer,
                        expert: e,
                        src: old_owner,
                        dst: new_owner,
                        bytes: self.model.expert_bytes(),
                    });
                    ops.push(PlanOp::EvictExpert {
                        layer,
                        expert: e,
                        dev: old_owner,
                    });
                }
            }
        }

        // Cold-expert offload under HBM pressure: instead of letting the
        // shrunk budget fail (forcing live-KV recompute), demote the
        // coldest stay-put experts to host DRAM and credit their bytes
        // back — up to the configured budget, never beyond it.
        if under_pressure
            && self.placement.demote_on_pressure
            && !demotable.is_empty()
        {
            let deficit = self
                .placement
                .migration_budget_bytes
                .saturating_sub(effective_budget);
            if deficit > 0 {
                demotable.sort_by(|&(la, ea, _), &(lb, eb, _)| {
                    let load = |l: usize, e: usize| {
                        self.load_stats
                            .as_ref()
                            .map(|s| s.predicted(l)[e])
                            .unwrap_or(0.0)
                    };
                    load(la, ea)
                        .total_cmp(&load(lb, eb))
                        .then((la, ea).cmp(&(lb, eb)))
                });
                let mut credited = 0u64;
                for &(layer, e, dev) in demotable
                    .iter()
                    .take(self.placement.max_demotions)
                {
                    if credited >= deficit {
                        break;
                    }
                    let bytes = self.model.expert_bytes();
                    ops.push(PlanOp::DemoteExpert {
                        layer,
                        expert: e,
                        dev,
                        bytes,
                    });
                    // The demotion replaces this expert's reuse op.
                    let tag = format!("layer{layer}.expert{e}");
                    if let Some(pos) = ops.iter().position(|op| {
                        matches!(op, PlanOp::ZeroCopyReuse { tag: t, .. } if *t == tag)
                    }) {
                        ops.remove(pos);
                    }
                    credited += bytes;
                }
                let credited = credited.min(deficit);
                budget += credited;
                effective_budget += credited;
            }
        }

        // Live-sequence KV legs: planned after experts so the copy legs
        // see only the leftover migration budget.
        if let Some(snapshot) = kv.filter(|s| !s.is_empty()) {
            let cost = CostModel::new(
                self.model.clone(),
                self.cluster.borrow().timings.clone(),
            );
            let (kv_plan, _used) =
                plan_kv_migration(snapshot, to, &cost, budget);
            for leg in &kv_plan.legs {
                match leg.verdict {
                    KvVerdict::Remap { rank } => {
                        ops.push(PlanOp::KvBlockRemap {
                            request: leg.id,
                            // Lead device of the surviving group (KV is
                            // TP-sharded; the group moves as one).
                            dev: to.devices[rank * to.tp],
                            blocks: leg.blocks,
                        });
                    }
                    KvVerdict::Copy { .. } => {
                        ops.push(PlanOp::KvBlockCopy {
                            request: leg.id,
                            blocks: leg.blocks,
                            bytes: leg.len as u64
                                * kv_plan.bytes_per_token,
                            legs: kv_plan.fabric_legs(leg),
                        });
                    }
                    KvVerdict::Recompute => {
                        ops.push(PlanOp::KvDropRecompute {
                            request: leg.id,
                            tokens: leg.len,
                            blocks: leg.blocks,
                        });
                    }
                }
            }
        }

        Ok(ScalePlan {
            from_label: from.label(),
            to_label: to.label(),
            ops,
            migration_budget_bytes: effective_budget,
        })
    }

    /// Execute a scaling plan: perform the transfers/allocations against the
    /// cluster, bind migrated experts into destination vpage tables, and
    /// queue evicted pages for deferred free. The old configuration stays
    /// fully usable until [`Self::apply_deferred_frees`].
    ///
    /// Returns a [`PlanExecution`]: the stage timings plus one
    /// [`StepOutcome`] per plan op. When a chaos [`FaultInjector`] is
    /// installed (see [`Self::set_fault_injector`]) and a fault fires
    /// mid-plan, the event **aborts**: every applied op is undone in
    /// reverse order — copied regions released, committed vpage remaps
    /// reverted through the per-device tables, partially copied KV legs
    /// discarded, deferred frees drained — so the pre-plan configuration
    /// stays current and byte-identical, and the abort rides back in
    /// [`PlanExecution::aborted`]. A hard `Err` is reserved for internal
    /// inconsistencies (missing regions, allocation failures outside
    /// fault injection): those are bugs, not injected chaos.
    pub fn execute_plan(
        &mut self,
        plan: &ScalePlan,
        to: &ParallelConfig,
    ) -> Result<PlanExecution> {
        let mut stats = ScaleStats::default();
        let ipc = self.opts.ipc_safe_alloc;
        let to_layout = WeightLayout::compute(&self.model, to);
        for &dev in &to.devices {
            self.workers.entry(dev).or_insert_with(|| Worker::new(dev));
        }
        let injector = self.injector.clone();

        let mut steps: Vec<StepOutcome> = Vec::with_capacity(plan.ops.len());
        let mut undo: Vec<UndoOp> = Vec::new();
        let mut abort: Option<AbortReport> = None;
        let deferred_base = self.deferred_frees.len();

        let mut owner_updates: Vec<(usize, usize, DeviceId)> = Vec::new();
        let mut attn_transfers: Vec<(DeviceId, DeviceId, u64)> = Vec::new();
        let mut expert_transfers: Vec<(DeviceId, DeviceId, u64)> = Vec::new();
        let mut disk_time: BTreeMap<DeviceId, f64> = BTreeMap::new();
        let mut h2d_time: BTreeMap<DeviceId, f64> = BTreeMap::new();
        let mut d2h_time: BTreeMap<DeviceId, f64> = BTreeMap::new();
        let mut remap_ops: BTreeMap<DeviceId, u64> = BTreeMap::new();
        let mut kv_inits: Vec<(DeviceId, u64)> = Vec::new();
        // Live-sequence KV handoff legs (timed into the switchover
        // window, not the concurrent phase).
        let mut kv_legs: Vec<(DeviceId, DeviceId, u64)> = Vec::new();
        let mut kv_seq_handovers: u64 = 0;

        {
            let mut cluster = self.cluster.borrow_mut();
            for op in &plan.ops {
                // Chaos hook: consult the injector before touching state,
                // so a faulted op leaves nothing of its own to undo.
                let fault = match (&injector, op) {
                    (Some(inj), PlanOp::P2pAttn { src, dst, .. })
                        if self.opts.use_p2p =>
                    {
                        inj.borrow_mut().on_leg(*src, *dst)
                    }
                    (Some(inj), PlanOp::MigrateExpert { src, dst, .. })
                        if self.opts.use_p2p =>
                    {
                        inj.borrow_mut().on_leg(*src, *dst)
                    }
                    (Some(inj), PlanOp::KvBlockCopy { legs, .. }) => {
                        let mut inj = inj.borrow_mut();
                        legs.iter().find_map(|&(s, d, _)| inj.on_kv_leg(s, d))
                    }
                    (Some(inj), PlanOp::KvInit { dev, .. }) => {
                        inj.borrow_mut().on_device(*dev)
                    }
                    _ => None,
                };
                if let Some(fault) = fault {
                    abort = Some(AbortReport {
                        fault,
                        op_index: steps.len(),
                        rolled_back: false,
                        reason: format!(
                            "{} at plan op {}",
                            fault.label(),
                            steps.len()
                        ),
                    });
                    steps.push(StepOutcome::Faulted(fault));
                    break;
                }

                match op {
                    PlanOp::ZeroCopyReuse { .. } | PlanOp::KvReuse { .. } => {}
                    PlanOp::P2pAttn {
                        src,
                        dst,
                        tag,
                        bytes,
                    } => {
                        let rank = to_layout.tp_rank[dst];
                        if self.opts.use_p2p {
                            let src_region = self
                                .workers
                                .get(src)
                                .and_then(|w| w.regions.get(tag).copied())
                                .with_context(|| {
                                    format!("source region for '{tag}' missing on dev {src}")
                                })?;
                            let (r, _) = p2p_copy(
                                &mut cluster, &mut self.store, *src,
                                src_region, *dst, tag,
                                RegionKind::AttnWeights, ipc,
                            )?;
                            attn_transfers.push((*src, *dst, *bytes));
                            self.workers
                                .get_mut(dst)
                                .unwrap()
                                .regions
                                .insert(tag.clone(), r);
                            undo.push(UndoOp::AttnRegion {
                                dev: *dst,
                                tag: tag.clone(),
                                region: r,
                            });
                        } else {
                            // -HCCL ablation: reload from disk.
                            let unit = to_layout
                                .units(*dst)
                                .iter()
                                .find(|u| u.tag(rank) == *tag)
                                .cloned()
                                .context("unit for tag")?;
                            let payload = self.load_payload(&unit, rank);
                            let (r, t) = disk_copy(
                                &mut cluster, &mut self.store, *dst,
                                &format!("{tag}#scale{dst}"), *bytes,
                                RegionKind::AttnWeights, ipc, payload,
                            )?;
                            *disk_time.entry(*dst).or_default() += t;
                            self.workers
                                .get_mut(dst)
                                .unwrap()
                                .regions
                                .insert(tag.clone(), r);
                            undo.push(UndoOp::AttnRegion {
                                dev: *dst,
                                tag: tag.clone(),
                                region: r,
                            });
                        }
                    }
                    PlanOp::MigrateExpert {
                        layer,
                        expert,
                        src,
                        dst,
                        bytes,
                    } => {
                        let tag = format!("layer{layer}.expert{expert}");
                        let src_region = self
                            .workers
                            .get(src)
                            .and_then(|w| w.vpages.lookup(*layer, *expert))
                            .with_context(|| {
                                format!("expert {tag} not resident on dev {src}")
                            })?;
                        let (r, t) = if self.opts.use_p2p {
                            let (r, _) = p2p_copy(
                                &mut cluster, &mut self.store, *src,
                                src_region, *dst, &tag,
                                RegionKind::ExpertWeights, ipc,
                            )?;
                            expert_transfers.push((*src, *dst, *bytes));
                            (r, 0.0)
                        } else {
                            let unit = WeightUnit {
                                kind: UnitKind::Expert {
                                    layer: *layer,
                                    expert: *expert,
                                },
                                bytes: *bytes,
                            };
                            let payload = self.load_payload(&unit, 0);
                            disk_copy(
                                &mut cluster, &mut self.store, *dst,
                                &format!("{tag}#scale{dst}"), *bytes,
                                RegionKind::ExpertWeights, ipc, payload,
                            )?
                        };
                        *disk_time.entry(*dst).or_default() += t;
                        self.workers
                            .get_mut(dst)
                            .unwrap()
                            .vpages
                            .bind(*layer, *expert, r)?;
                        *remap_ops.entry(*dst).or_default() += 1;
                        owner_updates.push((*layer, *expert, *dst));
                        undo.push(UndoOp::ExpertBound {
                            layer: *layer,
                            expert: *expert,
                            dev: *dst,
                            region: r,
                        });
                    }
                    PlanOp::EvictExpert { layer, expert, dev } => {
                        let region = self
                            .workers
                            .get_mut(dev)
                            .and_then(|w| w.vpages.unbind(*layer, *expert).ok())
                            .with_context(|| {
                                format!("evict: expert missing on dev {dev}")
                            })?;
                        // Pages stay mapped for the old instance until
                        // switchover (deferred free).
                        self.deferred_frees.push((*dev, region));
                        *remap_ops.entry(*dev).or_default() += 1;
                        undo.push(UndoOp::ExpertEvicted {
                            layer: *layer,
                            expert: *expert,
                            dev: *dev,
                            region,
                        });
                    }
                    PlanOp::ReleaseShard { dev } => {
                        if let Some(w) = self.workers.get_mut(dev) {
                            let regions: Vec<(String, RegionId)> =
                                std::mem::take(&mut w.regions)
                                    .into_iter()
                                    .collect();
                            for &(_, region) in &regions {
                                self.deferred_frees.push((*dev, region));
                            }
                            let kv = w.kv_region.take();
                            if let Some(kv) = kv {
                                self.deferred_frees.push((*dev, kv));
                            }
                            undo.push(UndoOp::ShardReleased {
                                dev: *dev,
                                regions,
                                kv,
                            });
                        }
                    }
                    PlanOp::KvBlockRemap { .. } => {
                        // Blocks stay physically put; the successor's
                        // block table adopts them — one O(1) page-table
                        // handover per sequence.
                        kv_seq_handovers += 1;
                    }
                    PlanOp::KvBlockCopy { legs, .. } => {
                        kv_legs.extend(legs.iter().copied());
                        // Destination block-table bind after the copy.
                        kv_seq_handovers += 1;
                    }
                    PlanOp::KvDropRecompute { .. } => {
                        // Blocks are released when the old engine drains;
                        // nothing moves and nothing is charged here — the
                        // recompute bill lands on the successor's prefill
                        // path (and in the sequence's TTFT).
                    }
                    PlanOp::HostLoad { dev, tag, bytes } => {
                        let (staged, t) = self
                            .tier
                            .promote(&mut cluster, tag)?
                            .with_context(|| {
                                format!("host-load: '{tag}' not DRAM-staged")
                            })?;
                        let r = cluster.devices[*dev].hbm.alloc(
                            staged.max(*bytes),
                            RegionKind::AttnWeights,
                            ipc,
                            tag,
                        )?;
                        // Live path: materialise the tensor payload like
                        // every other weight-loading leg.
                        let rank = to_layout.tp_rank[dev];
                        if let Some(unit) = to_layout
                            .units(*dev)
                            .iter()
                            .find(|u| u.tag(rank) == *tag)
                        {
                            if let Some(p) = self.load_payload(unit, rank) {
                                self.store.put(*dev, r, p);
                            }
                        }
                        *h2d_time.entry(*dev).or_default() += t;
                        self.workers
                            .get_mut(dev)
                            .unwrap()
                            .regions
                            .insert(tag.clone(), r);
                        undo.push(UndoOp::HostLoaded {
                            dev: *dev,
                            tag: tag.clone(),
                            region: r,
                            bytes: *bytes,
                        });
                    }
                    PlanOp::DemoteExpert {
                        layer,
                        expert,
                        dev,
                        bytes,
                    } => {
                        let tag = format!("layer{layer}.expert{expert}");
                        let region = self
                            .workers
                            .get_mut(dev)
                            .and_then(|w| w.vpages.unbind(*layer, *expert).ok())
                            .with_context(|| {
                                format!("demote: {tag} not resident on dev {dev}")
                            })?;
                        let (host_region, t) =
                            self.tier.demote(&mut cluster, &tag, *bytes)?;
                        self.tier.note_demoted_expert(
                            *layer,
                            *expert,
                            *dev,
                            host_region,
                            *bytes,
                        );
                        // The old instance serves this expert until
                        // switchover: free its HBM pages at drain.
                        self.deferred_frees.push((*dev, region));
                        *d2h_time.entry(*dev).or_default() += t;
                        *remap_ops.entry(*dev).or_default() += 1;
                        undo.push(UndoOp::ExpertDemoted {
                            layer: *layer,
                            expert: *expert,
                            dev: *dev,
                            region,
                            bytes: *bytes,
                        });
                    }
                    PlanOp::PromoteExpert {
                        layer,
                        expert,
                        dev,
                        bytes,
                    } => {
                        let tag = format!("layer{layer}.expert{expert}");
                        let (staged, t) = self
                            .tier
                            .promote(&mut cluster, &tag)?
                            .with_context(|| {
                                format!("promote: {tag} not DRAM-staged")
                            })?;
                        self.tier.forget_demoted_expert(*layer, *expert);
                        let r = cluster.devices[*dev].hbm.alloc(
                            staged.max(*bytes),
                            RegionKind::ExpertWeights,
                            ipc,
                            &tag,
                        )?;
                        let unit = WeightUnit {
                            kind: UnitKind::Expert {
                                layer: *layer,
                                expert: *expert,
                            },
                            bytes: *bytes,
                        };
                        if let Some(p) = self.load_payload(&unit, 0) {
                            self.store.put(*dev, r, p);
                        }
                        self.workers
                            .get_mut(dev)
                            .unwrap()
                            .vpages
                            .bind(*layer, *expert, r)?;
                        *h2d_time.entry(*dev).or_default() += t;
                        *remap_ops.entry(*dev).or_default() += 1;
                        owner_updates.push((*layer, *expert, *dev));
                        undo.push(UndoOp::ExpertPromoted {
                            layer: *layer,
                            expert: *expert,
                            dev: *dev,
                            region: r,
                            bytes: *bytes,
                        });
                    }
                    PlanOp::KvInit { dev, bytes } => {
                        let kv = cluster.devices[*dev].hbm.alloc(
                            *bytes,
                            RegionKind::KvCache,
                            ipc,
                            "kv",
                        )?;
                        let prev = self
                            .workers
                            .get_mut(dev)
                            .unwrap()
                            .kv_region
                            .replace(kv);
                        kv_inits.push((*dev, *bytes));
                        undo.push(UndoOp::KvAllocated {
                            dev: *dev,
                            region: kv,
                            prev,
                        });
                    }
                }
                steps.push(StepOutcome::Applied);
            }

            // Fault rollback: undo every applied op in reverse order so
            // the cluster returns to its exact pre-plan state (the old
            // instance keeps serving from it).
            if abort.is_some() {
                let rollback_ops = undo.len();
                for u in undo.drain(..).rev() {
                    match u {
                        UndoOp::AttnRegion { dev, tag, region } => {
                            if let Some(w) = self.workers.get_mut(&dev) {
                                w.regions.remove(&tag);
                            }
                            cluster.devices[dev].hbm.release(region)?;
                            self.store.remove(dev, region);
                        }
                        UndoOp::ExpertBound {
                            layer,
                            expert,
                            dev,
                            region,
                        } => {
                            self.workers
                                .get_mut(&dev)
                                .context("rollback: dst worker missing")?
                                .vpages
                                .unbind(layer, expert)?;
                            cluster.devices[dev].hbm.release(region)?;
                            self.store.remove(dev, region);
                        }
                        UndoOp::ExpertEvicted {
                            layer,
                            expert,
                            dev,
                            region,
                        } => {
                            self.workers
                                .get_mut(&dev)
                                .context("rollback: src worker missing")?
                                .vpages
                                .bind(layer, expert, region)?;
                        }
                        UndoOp::ShardReleased { dev, regions, kv } => {
                            if let Some(w) = self.workers.get_mut(&dev) {
                                w.kv_region = kv;
                                w.regions = regions.into_iter().collect();
                            }
                        }
                        UndoOp::KvAllocated { dev, region, prev } => {
                            if let Some(w) = self.workers.get_mut(&dev) {
                                w.kv_region = prev;
                            }
                            cluster.devices[dev].hbm.release(region)?;
                        }
                        UndoOp::HostLoaded {
                            dev,
                            tag,
                            region,
                            bytes,
                        } => {
                            // Re-stage the shard: the HBM copy dies, the
                            // DRAM copy returns (a journalled reverse
                            // shift, so conservation still replays).
                            if let Some(w) = self.workers.get_mut(&dev) {
                                w.regions.remove(&tag);
                            }
                            cluster.devices[dev].hbm.release(region)?;
                            self.store.remove(dev, region);
                            self.tier.demote(&mut cluster, &tag, bytes)?;
                        }
                        UndoOp::ExpertDemoted {
                            layer,
                            expert,
                            dev,
                            region,
                            bytes: _,
                        } => {
                            // Promote the DRAM copy back out of existence
                            // (reverse-journalled) and rebind the still-
                            // deferred HBM pages; the global deferred-free
                            // truncation below drops the queued entry.
                            let tag = format!("layer{layer}.expert{expert}");
                            self.tier
                                .promote(&mut cluster, &tag)?
                                .context("rollback: demoted copy missing")?;
                            self.tier.forget_demoted_expert(layer, expert);
                            self.workers
                                .get_mut(&dev)
                                .context("rollback: demote worker missing")?
                                .vpages
                                .bind(layer, expert, region)?;
                        }
                        UndoOp::ExpertPromoted {
                            layer,
                            expert,
                            dev,
                            region,
                            bytes,
                        } => {
                            let tag = format!("layer{layer}.expert{expert}");
                            self.workers
                                .get_mut(&dev)
                                .context("rollback: promote worker missing")?
                                .vpages
                                .unbind(layer, expert)?;
                            cluster.devices[dev].hbm.release(region)?;
                            self.store.remove(dev, region);
                            let (host_region, _) =
                                self.tier.demote(&mut cluster, &tag, bytes)?;
                            self.tier.note_demoted_expert(
                                layer,
                                expert,
                                dev,
                                host_region,
                                bytes,
                            );
                        }
                    }
                }
                // Evictions and shard releases queued deferred frees; the
                // bindings are restored above, so drop the queued entries.
                self.deferred_frees.truncate(deferred_base);
                owner_updates.clear();
                stats.rollback_time = rollback_ops as f64
                    * cluster.timings.vpage_remap_per_expert;
            }

            // Stage timing over what actually ran. A chaos straggler
            // stretches every fabric leg touching it (modelled as extra
            // effective bytes on the slow link).
            let stretched = |legs: &[(DeviceId, DeviceId, u64)]| -> Vec<(DeviceId, DeviceId, u64)> {
                match &injector {
                    Some(inj) => {
                        let mut inj = inj.borrow_mut();
                        legs.iter()
                            .map(|&(s, d, b)| {
                                (s, d, (b as f64 * inj.stretch(s, d)) as u64)
                            })
                            .collect()
                    }
                    None => legs.to_vec(),
                }
            };
            stats.attn_p2p_time = cluster
                .interconnect
                .parallel_transfers(&stretched(&attn_transfers));
            stats.expert_p2p_time = cluster
                .interconnect
                .parallel_transfers(&stretched(&expert_transfers));
            let disk_max = disk_time.values().cloned().fold(0.0, f64::max);
            stats.attn_p2p_time += disk_max;
            stats.h2d_time = h2d_time.values().cloned().fold(0.0, f64::max);
            stats.d2h_time = d2h_time.values().cloned().fold(0.0, f64::max);
            stats.remap_time = remap_ops
                .values()
                .map(|&n| n as f64 * cluster.timings.vpage_remap_per_expert)
                .fold(0.0, f64::max);
            if !self.opts.use_vpage && abort.is_none() {
                // Realloc path: every device whose expert set changed must
                // rebuild its contiguous expert buffer (alloc + copy), with
                // a transient double allocation.
                let mut realloc = 0.0f64;
                for (&dev, _) in remap_ops.iter() {
                    let local_bytes: u64 = self
                        .workers
                        .get(&dev)
                        .map(|w| {
                            w.vpages.bound_count() as u64
                                * self.model.expert_bytes()
                        })
                        .unwrap_or(0);
                    let scratch = cluster.devices[dev].hbm.alloc(
                        local_bytes,
                        RegionKind::Scratch,
                        false,
                        "realloc-scratch",
                    )?;
                    cluster.devices[dev].hbm.release(scratch)?;
                    realloc =
                        realloc.max(cluster.timings.realloc_copy(local_bytes));
                }
                stats.realloc_time = realloc;
            }
            stats.kv_init_time = kv_inits
                .iter()
                .map(|&(_, b)| cluster.timings.kv_alloc(b))
                .fold(0.0, f64::max);
            stats.kv_migrate_time = cluster
                .interconnect
                .parallel_transfers(&stretched(&kv_legs))
                + kv_seq_handovers as f64
                    * cluster.timings.vpage_remap_per_expert;
        }

        if let Some(mut report) = abort {
            report.rolled_back = true;
            report.reason = format!(
                "{} ({} applied ops rolled back, configuration stays {})",
                report.reason, report.op_index, plan.from_label
            );
            for s in steps.iter_mut() {
                if *s == StepOutcome::Applied {
                    *s = StepOutcome::RolledBack;
                }
            }
            while steps.len() < plan.ops.len() {
                steps.push(StepOutcome::Skipped);
            }
            stats.total = stats.attn_p2p_time
                + stats.expert_p2p_time
                + stats.remap_time
                + stats.realloc_time
                + stats.kv_init_time
                + stats.h2d_time
                + stats.d2h_time
                + stats.rollback_time;
            stats.mark_stages();
            return Ok(PlanExecution {
                stats,
                steps,
                aborted: Some(report),
            });
        }

        // New configuration becomes current; old instance bindings keep
        // their snapshots. The layout's expert placement is overridden with
        // the actual (minimal-movement) ownership.
        for (layer, expert, dev) in owner_updates {
            self.expert_owner[layer][expert] = dev;
        }
        let mut new_layout = WeightLayout::compute(&self.model, to);
        new_layout.expert_owner = self.expert_owner.clone();
        self.layout = Some((to.clone(), new_layout));
        stats.total = stats.attn_p2p_time
            + stats.expert_p2p_time
            + stats.remap_time
            + stats.realloc_time
            + stats.kv_init_time
            + stats.h2d_time
            + stats.d2h_time;
        stats.mark_stages();
        Ok(PlanExecution {
            stats,
            steps,
            aborted: None,
        })
    }

    /// Free pages orphaned by the last scaling event (called after the old
    /// instance has drained and detached — §5.2 switchover).
    pub fn apply_deferred_frees(&mut self) -> Result<usize> {
        let mut cluster = self.cluster.borrow_mut();
        let n = self.deferred_frees.len();
        for (dev, region) in self.deferred_frees.drain(..) {
            cluster.devices[dev].hbm.release(region)?;
        }
        Ok(n)
    }

    pub fn deferred_free_count(&self) -> usize {
        self.deferred_frees.len()
    }

    /// ---- instance attach/detach -------------------------------------------

    /// Hand the current configuration's weights and KV to an instance via
    /// zero-copy handles. Returns the binding snapshot and the time charged.
    /// Without zero-copy (ablation) the instance receives private duplicates
    /// of every region — slow and memory-doubling.
    pub fn attach_instance(&mut self, proc: ProcId) -> Result<(InstanceBinding, f64)> {
        let (parallel, _layout) = self
            .layout
            .as_ref()
            .context("HMM not initialised")?
            .clone();
        let mut time = 0.0;
        let mut shares: Vec<(DeviceId, RegionId)> = Vec::new();
        let mut privates: Vec<(DeviceId, RegionId)> = Vec::new();
        let mut attn_regions: BTreeMap<DeviceId, Vec<(String, RegionId)>> =
            BTreeMap::new();
        let mut expert_map: Vec<BTreeMap<usize, (DeviceId, RegionId)>> =
            vec![BTreeMap::new(); self.model.n_layers as usize];
        let mut kv_regions = BTreeMap::new();
        let mut cluster = self.cluster.borrow_mut();

        for &dev in &parallel.devices {
            let worker = self
                .workers
                .get(&dev)
                .with_context(|| format!("no worker on dev {dev}"))?
                .clone();
            // Non-expert units + KV + experts.
            let mut all: Vec<(String, RegionId, RegionKind)> = worker
                .regions
                .iter()
                .map(|(t, &r)| (t.clone(), r, RegionKind::AttnWeights))
                .collect();
            if let Some(kv) = worker.kv_region {
                all.push(("kv".into(), kv, RegionKind::KvCache));
            }
            for (layer, expert, region) in worker.vpages.all_bindings() {
                all.push((
                    format!("layer{layer}.expert{expert}"),
                    region,
                    RegionKind::ExpertWeights,
                ));
            }
            // True zero-copy sharing needs both the feature and IPC-safe
            // allocations; without the IpcSafeAllocator sharing degrades to
            // device-local staging copies (Table 1 `-IPCAlloc`: small
            // latency bump, large peak-memory bump, still no downtime).
            let can_share =
                self.opts.use_zero_copy && self.opts.ipc_safe_alloc;
            for (tag, region, kind) in all {
                if can_share {
                    time += zero_copy(&mut cluster, dev, region, 0, proc)?;
                    shares.push((dev, region));
                    Self::record_binding(
                        &mut attn_regions, &mut expert_map, &mut kv_regions,
                        dev, &tag, region, kind,
                    );
                } else {
                    // Duplicate the region privately (memcpy on device).
                    let bytes = cluster.devices[dev]
                        .hbm
                        .region(region)
                        .context("region")?
                        .bytes;
                    let dup = cluster.devices[dev].hbm.alloc(
                        bytes,
                        kind,
                        false,
                        format!("{tag}#dup{proc}"),
                    )?;
                    time += cluster.timings.realloc_copy(bytes);
                    if let Some(p) = self.store.get(dev, region) {
                        self.store.put(dev, dup, p);
                    }
                    privates.push((dev, dup));
                    Self::record_binding(
                        &mut attn_regions, &mut expert_map, &mut kv_regions,
                        dev, &tag, dup, kind,
                    );
                }
            }
        }
        drop(cluster);
        self.attachments.insert(proc, shares);
        if !privates.is_empty() {
            self.private_regions.insert(proc, privates);
        }
        Ok((
            InstanceBinding {
                proc,
                parallel,
                attn_regions,
                expert_map,
                kv_regions,
            },
            time,
        ))
    }

    #[allow(clippy::too_many_arguments)]
    fn record_binding(
        attn_regions: &mut BTreeMap<DeviceId, Vec<(String, RegionId)>>,
        expert_map: &mut [BTreeMap<usize, (DeviceId, RegionId)>],
        kv_regions: &mut BTreeMap<DeviceId, RegionId>,
        dev: DeviceId,
        tag: &str,
        region: RegionId,
        kind: RegionKind,
    ) {
        match kind {
            RegionKind::KvCache => {
                kv_regions.insert(dev, region);
            }
            RegionKind::ExpertWeights => {
                // tag = "layer{L}.expert{E}"
                if let Some((l, e)) = parse_expert_tag(tag) {
                    expert_map[l].insert(e, (dev, region));
                }
            }
            _ => {
                attn_regions
                    .entry(dev)
                    .or_default()
                    .push((tag.to_string(), region));
            }
        }
    }

    /// Release an instance's references (switchover completion / teardown).
    pub fn detach_instance(&mut self, proc: ProcId) -> Result<()> {
        let mut cluster = self.cluster.borrow_mut();
        if let Some(shares) = self.attachments.remove(&proc) {
            for (dev, region) in shares {
                cluster.devices[dev].hbm.release(region)?;
            }
        }
        if let Some(privates) = self.private_regions.remove(&proc) {
            for (dev, region) in privates {
                cluster.devices[dev].hbm.release(region)?;
                self.store.remove(dev, region);
            }
        }
        Ok(())
    }

    /// Tear down everything the HMM holds (cold-restart baselines).
    pub fn teardown_all(&mut self) -> Result<()> {
        let mut cluster = self.cluster.borrow_mut();
        for (_, worker) in std::mem::take(&mut self.workers) {
            for region in worker.all_regions() {
                // Regions may hold extra refs from live attachments; release
                // the HMM's own reference.
                let _ = cluster.devices[worker.dev].hbm.release(region);
                self.store.remove(worker.dev, region);
            }
        }
        drop(cluster);
        self.deferred_frees.clear();
        self.layout = None;
        Ok(())
    }

    /// ---- park / unpark (scale-to-zero) ------------------------------------

    /// Park the current configuration: demote every weight unit into
    /// host DRAM (one staged copy per tag — TP-shard replicas dedup,
    /// Appendix D.2), release all HBM (weights and KV), and forget the
    /// layout. The caller must have detached every instance first; KV
    /// is dropped rather than staged (a parked replica has no live
    /// sequences). The d2h staging runs after the replica left the
    /// serving rotation, so the returned time is background cost, not
    /// serving-visible latency.
    pub fn park_to_host(&mut self) -> Result<ParkStats> {
        self.layout
            .take()
            .context("HMM not initialised (nothing to park)")?;
        let mut cluster = self.cluster.borrow_mut();
        // Orphaned pages from the last event die with the parked
        // instance.
        for (dev, region) in self.deferred_frees.drain(..) {
            cluster.devices[dev].hbm.release(region)?;
        }
        let mut stats = ParkStats::default();
        let mut per_dev: BTreeMap<DeviceId, f64> = BTreeMap::new();
        let mut staged: std::collections::HashSet<String> =
            std::collections::HashSet::new();
        for (dev, worker) in std::mem::take(&mut self.workers) {
            for (tag, &region) in &worker.regions {
                let bytes = cluster.devices[dev]
                    .hbm
                    .region(region)
                    .with_context(|| format!("park: region '{tag}' missing"))?
                    .bytes;
                if staged.insert(tag.clone()) {
                    let (_, t) = self.tier.demote(&mut cluster, tag, bytes)?;
                    *per_dev.entry(dev).or_default() += t;
                    stats.dram_bytes += bytes;
                }
                cluster.devices[dev].hbm.release(region)?;
                self.store.remove(dev, region);
                stats.hbm_freed += bytes;
            }
            for (layer, expert, region) in worker.vpages.all_bindings() {
                let tag = format!("layer{layer}.expert{expert}");
                let bytes = cluster.devices[dev]
                    .hbm
                    .region(region)
                    .with_context(|| format!("park: region '{tag}' missing"))?
                    .bytes;
                let (_, t) = self.tier.demote(&mut cluster, &tag, bytes)?;
                *per_dev.entry(dev).or_default() += t;
                stats.dram_bytes += bytes;
                cluster.devices[dev].hbm.release(region)?;
                self.store.remove(dev, region);
                stats.hbm_freed += bytes;
            }
            if let Some(kv) = worker.kv_region {
                cluster.devices[dev].hbm.release(kv)?;
            }
        }
        stats.d2h_time = per_dev.values().cloned().fold(0.0, f64::max);
        Ok(stats)
    }

    /// Unpark into `parallel`: rebuild the worker state by promoting
    /// each staged unit's first copy over h2d and fanning TP-shard
    /// replicas out over P2P, falling back to disk for anything not
    /// staged. Allocates fresh KV caches. Returns the weight-path time
    /// (max over devices — the h2d lanes run in parallel), i.e. the
    /// DRAM-warm counterpart of [`Self::load_initial`].
    pub fn unpark_from_host(
        &mut self,
        parallel: &ParallelConfig,
        kv_bytes_per_device: u64,
    ) -> Result<f64> {
        if self.layout.is_some() {
            bail!("unpark: a configuration is already loaded");
        }
        parallel.check_model(&self.model)?;
        let layout = WeightLayout::compute(&self.model, parallel);
        let mut cluster = self.cluster.borrow_mut();
        let ipc = self.opts.ipc_safe_alloc;
        let mut first_copy: HashMap<String, (DeviceId, RegionId)> =
            HashMap::new();
        let mut busy: BTreeMap<DeviceId, f64> = BTreeMap::new();
        for &dev in &parallel.devices {
            self.workers.entry(dev).or_insert_with(|| Worker::new(dev));
        }
        for &dev in &parallel.devices {
            let rank = layout.tp_rank[&dev];
            for unit in layout.units(dev) {
                let tag = unit.tag(rank);
                let kind = if unit.is_expert() {
                    RegionKind::ExpertWeights
                } else {
                    RegionKind::AttnWeights
                };
                let (region, t) = if let Some(&(src_dev, src_region)) =
                    first_copy.get(&tag)
                {
                    if self.opts.use_p2p {
                        let (r, t) = p2p_copy(
                            &mut cluster, &mut self.store, src_dev,
                            src_region, dev, &tag, kind, ipc,
                        )?;
                        *busy.entry(src_dev).or_default() += t;
                        (r, t)
                    } else {
                        let payload = self.load_payload(unit, rank);
                        disk_copy(
                            &mut cluster, &mut self.store, dev,
                            &format!("{tag}#{dev}"), unit.bytes, kind, ipc,
                            payload,
                        )?
                    }
                } else if let Some((bytes, t)) =
                    self.tier.promote(&mut cluster, &tag)?
                {
                    let r = cluster.devices[dev]
                        .hbm
                        .alloc(bytes, kind, ipc, &tag)?;
                    if let UnitKind::Expert { layer, expert } = unit.kind {
                        self.tier.forget_demoted_expert(layer, expert);
                    }
                    let payload = self.load_payload(unit, rank);
                    if let Some(p) = payload {
                        self.store.put(dev, r, p);
                    }
                    first_copy.insert(tag.clone(), (dev, r));
                    (r, t)
                } else {
                    let payload = self.load_payload(unit, rank);
                    let (r, t) = disk_copy(
                        &mut cluster, &mut self.store, dev, &tag, unit.bytes,
                        kind, ipc, payload,
                    )?;
                    first_copy.insert(tag.clone(), (dev, r));
                    (r, t)
                };
                *busy.entry(dev).or_default() += t;
                let worker = self.workers.get_mut(&dev).unwrap();
                match unit.kind {
                    UnitKind::Expert { layer, expert } => {
                        worker.vpages.bind(layer, expert, region)?;
                    }
                    _ => {
                        worker.regions.insert(tag, region);
                    }
                }
            }
            let kv = cluster.devices[dev].hbm.alloc(
                kv_bytes_per_device,
                RegionKind::KvCache,
                ipc,
                "kv",
            )?;
            *busy.entry(dev).or_default() +=
                cluster.timings.kv_alloc(kv_bytes_per_device);
            self.workers.get_mut(&dev).unwrap().kv_region = Some(kv);
        }
        self.kv_bytes_per_device = kv_bytes_per_device;
        self.expert_owner = layout.expert_owner.clone();
        self.layout = Some((parallel.clone(), layout));
        Ok(busy.values().cloned().fold(0.0, f64::max))
    }

    /// Payload lookup for the live engine.
    pub fn payload(&self, dev: DeviceId, region: RegionId) -> Option<Payload> {
        self.store.get(dev, region)
    }
}

/// Outcome of [`HmmControl::park_to_host`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ParkStats {
    /// d2h staging time, max over devices (background cost: the replica
    /// already left the serving rotation).
    pub d2h_time: f64,
    /// Weight bytes now staged in host DRAM (dedup'd, one copy per tag).
    pub dram_bytes: u64,
    /// HBM bytes released across the parked devices (weights; KV rides
    /// separately).
    pub hbm_freed: u64,
}

fn parse_expert_tag(tag: &str) -> Option<(usize, usize)> {
    let rest = tag.strip_prefix("layer")?;
    let (l, e) = rest.split_once(".expert")?;
    Some((l.parse().ok()?, e.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;

    fn setup(n_dev: usize) -> (Rc<RefCell<Cluster>>, HmmControl) {
        let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(n_dev)));
        let hmm = HmmControl::new(
            cluster.clone(),
            dsv2_lite(),
            HmmOptions::default(),
        );
        (cluster, hmm)
    }

    fn par(dp: usize, tp: usize, devs: std::ops::Range<usize>) -> ParallelConfig {
        ParallelConfig::standard(dp, tp, devs.collect()).unwrap()
    }

    const KV: u64 = 8 << 30;

    #[test]
    fn initial_load_places_everything() {
        let (cluster, mut hmm) = setup(4);
        let p = par(2, 2, 0..4);
        let t = hmm.load_initial(&p, KV).unwrap();
        assert!(t > 1.0, "cold load should take seconds: {t}");
        let c = cluster.borrow();
        for d in 0..4 {
            let used = c.devices[d].hbm.used();
            assert!(used > KV, "device {d} has weights + kv: {used}");
        }
        // Every expert bound exactly once across workers.
        let total: usize = (0..4)
            .map(|d| hmm.worker(d).unwrap().vpages.bound_count())
            .sum();
        assert_eq!(total, (27 * 64) as usize);
    }

    #[test]
    fn scale_up_plan_maximises_reuse() {
        let (_c, mut hmm) = setup(6);
        hmm.load_initial(&par(2, 2, 0..4), KV).unwrap();
        let plan = hmm.plan_scale(&par(3, 2, 0..6)).unwrap();
        // TP fixed: attention on survivors reused, never moved.
        assert!(plan.reuse_fraction() > 0.5, "{}", plan.reuse_fraction());
        // Migrations only to the two new devices.
        for op in &plan.ops {
            if let PlanOp::MigrateExpert { dst, .. } = op {
                assert!(*dst >= 4, "migration to survivor {dst}");
            }
        }
        // 64 experts over 6 ranks: ranks 4,5 get ~1/3 of experts per layer.
        let migrated = plan.migrated_expert_count();
        assert!(migrated > 0);
        assert_eq!(migrated, plan.evicted_expert_count());
    }

    #[test]
    fn execute_plan_times_and_deferred_frees() {
        let (cluster, mut hmm) = setup(6);
        hmm.load_initial(&par(2, 2, 0..4), KV).unwrap();
        let used_before: u64 = cluster.borrow().used_over(&[0, 1, 2, 3]);
        let to = par(3, 2, 0..6);
        let plan = hmm.plan_scale(&to).unwrap();
        let stats = hmm.execute_plan(&plan, &to).unwrap().stats;
        assert!(stats.total > 0.0 && stats.total < 10.0, "{stats:?}");
        assert!(stats.expert_p2p_time > 0.0);
        assert!(stats.kv_init_time > 0.0);
        // Old pages still resident (deferred).
        assert!(hmm.deferred_free_count() > 0);
        let used_mid: u64 = cluster.borrow().used_over(&[0, 1, 2, 3]);
        assert_eq!(used_mid, used_before, "survivor usage unchanged mid-scale");
        let n = hmm.apply_deferred_frees().unwrap();
        assert!(n > 0);
        let used_after: u64 = cluster.borrow().used_over(&[0, 1, 2, 3]);
        assert!(used_after < used_before, "evicted experts freed");
    }

    #[test]
    fn scale_down_moves_experts_to_survivors() {
        let (cluster, mut hmm) = setup(6);
        hmm.load_initial(&par(3, 2, 0..6), KV).unwrap();
        let to = par(2, 2, 0..4);
        let plan = hmm.plan_scale(&to).unwrap();
        for op in &plan.ops {
            if let PlanOp::MigrateExpert { src, dst, .. } = op {
                assert!(*src >= 4 && *dst < 4, "src {src} dst {dst}");
            }
        }
        let stats = hmm.execute_plan(&plan, &to).unwrap().stats;
        assert!(stats.total > 0.0);
        hmm.apply_deferred_frees().unwrap();
        // Devices 4,5 still hold attention (until instance teardown) but no
        // expert pages.
        let c = cluster.borrow();
        assert_eq!(
            c.devices[5].hbm.used_by_kind(RegionKind::ExpertWeights),
            0
        );
    }

    #[test]
    fn plan_with_kv_shares_budget_and_conserves_blocks() {
        use crate::engine::PagedKv;
        use crate::kvmigrate::KvSnapshot;

        let (_c, mut hmm) = setup(6);
        let from = par(3, 2, 0..6);
        hmm.load_initial(&from, KV).unwrap();

        // Live pool: two long sequences per DP rank (ids mod 3), one tiny
        // one on the departing rank 2.
        let mut pool = PagedKv::new(100_000, 16);
        for id in [0u64, 1, 2, 3, 4, 5] {
            pool.admit(id, 5000).unwrap();
        }
        pool.admit(8, 30).unwrap(); // rank 2, tiny → recompute by cost
        let snap = KvSnapshot::capture(&pool, &from);

        let to = par(2, 2, 0..4);
        let plan = hmm.plan_scale_with_kv(&to, Some(&snap)).unwrap();
        assert!(plan.kv_blocks_conserved(snap.total_blocks()));
        // Ranks 0/1 survive: their four long sequences remap.
        assert_eq!(plan.kv_remapped_blocks(), 4 * 313);
        // Rank 2's long sequences copy; the tiny one recomputes.
        assert_eq!(plan.kv_copied_blocks(), 2 * 313);
        assert_eq!(plan.kv_freed_blocks(), 2);
        assert_eq!(plan.kv_recompute_tokens(), 30);
        // Copy legs start on departing devices 4/5 only.
        for (src, dst, _) in plan.kv_transfers() {
            assert!(src >= 4 && dst < 4, "{src} -> {dst}");
        }
        // The weight plan is untouched by KV legs.
        assert!(plan.migrations_have_matching_evictions());

        // Executing the plan times the KV legs into the switchover-side
        // stat, not the concurrent total.
        let stats = hmm.execute_plan(&plan, &to).unwrap().stats;
        assert!(stats.kv_migrate_time > 0.0);
        assert!(
            stats.total > stats.kv_migrate_time,
            "kv time must not dominate or leak into total: {stats:?}"
        );

        // A zero leftover budget forces every mover to recompute.
        let (_c2, mut hmm2) = setup(6);
        hmm2.placement.migration_budget_bytes = 0;
        hmm2.load_initial(&from, KV).unwrap();
        let starved = hmm2.plan_scale_with_kv(&to, Some(&snap)).unwrap();
        assert_eq!(starved.kv_copied_blocks(), 0);
        assert_eq!(starved.kv_freed_blocks(), 2 * 313 + 2);
        assert!(starved.kv_blocks_conserved(snap.total_blocks()));
    }

    #[test]
    fn tp_change_is_rejected() {
        let (_c, mut hmm) = setup(8);
        hmm.load_initial(&par(2, 2, 0..4), KV).unwrap();
        let bad = ParallelConfig::standard(2, 4, (0..8).collect()).unwrap();
        assert!(hmm.plan_scale(&bad).is_err());
    }

    #[test]
    fn attach_zero_copy_does_not_grow_memory() {
        let (cluster, mut hmm) = setup(4);
        hmm.load_initial(&par(2, 2, 0..4), KV).unwrap();
        let used = cluster.borrow().used_over(&[0, 1, 2, 3]);
        let proc = hmm.alloc_proc();
        let (binding, t) = hmm.attach_instance(proc).unwrap();
        assert!(t < 2.0, "zero-copy attach should be fast: {t}");
        assert_eq!(cluster.borrow().used_over(&[0, 1, 2, 3]), used);
        assert_eq!(binding.kv_regions.len(), 4);
        assert_eq!(binding.expert_map.len(), 27);
        // Detach releases the references without freeing HMM-owned state.
        hmm.detach_instance(proc).unwrap();
        assert_eq!(cluster.borrow().used_over(&[0, 1, 2, 3]), used);
    }

    /// Feed skewed routing stats: each expert in `hots` takes 12 tokens
    /// per step, every even expert takes 1, identically for every layer.
    fn feed_skewed(hmm: &mut HmmControl, hots: &[usize], steps: usize) {
        let n = hmm.model.n_experts as usize;
        let mut tokens_per_expert = vec![Vec::new(); n];
        for &hot in hots {
            tokens_per_expert[hot] = (0..12).collect();
        }
        for (e, toks) in tokens_per_expert.iter_mut().enumerate() {
            if !hots.contains(&e) && e % 2 == 0 {
                toks.push(0);
            }
        }
        let routing = crate::engine::moe::Routing {
            n_tokens: 48,
            n_experts: n,
            tokens_per_expert,
        };
        for _ in 0..steps {
            for layer in 0..hmm.model.n_layers as usize {
                hmm.record_routing(layer, &routing);
            }
        }
    }

    #[test]
    fn load_aware_rebalance_spreads_hot_experts() {
        let (_c, mut hmm) = setup(4);
        hmm.placement = crate::placement::PlacementConfig::load_aware();
        hmm.load_initial(&par(2, 2, 0..4), KV).unwrap();
        // Four hot experts that all live on EP rank 1 under the boot
        // placement (e % 4 == 1): one device carries 48 of 80 tokens.
        feed_skewed(&mut hmm, &[5, 9, 13, 17], 10);
        assert!(hmm.placement_imbalance() > 1.5, "{}", hmm.placement_imbalance());
        let plan = hmm.plan_rebalance().unwrap();
        assert!(plan.migrated_expert_count() > 0, "skew must trigger moves");
        assert!(plan.migrations_have_matching_evictions());
        let to = hmm.current_parallel().unwrap().clone();
        hmm.execute_plan(&plan, &to).unwrap();
        hmm.apply_deferred_frees().unwrap();
        // Hot experts spread out (one-ish per device): predicted imbalance
        // collapses toward balanced.
        let after = hmm.placement_imbalance();
        assert!(after < 1.5, "imbalance after rebalance: {after}");
        // Still a partition per layer.
        let total: usize = (0..4)
            .map(|d| hmm.worker(d).unwrap().vpages.bound_count())
            .sum();
        assert_eq!(total, (27 * 64) as usize);
    }

    #[test]
    fn min_move_rebalance_plans_nothing() {
        let (_c, mut hmm) = setup(4);
        hmm.load_initial(&par(2, 2, 0..4), KV).unwrap();
        feed_skewed(&mut hmm, &[5, 9, 13, 17], 10);
        // Default MinMove mode: a redistribution-only plan is a no-op.
        let plan = hmm.plan_rebalance().unwrap();
        assert_eq!(plan.migrated_expert_count(), 0);
    }

    #[test]
    fn migration_budget_caps_load_aware_plans() {
        let (_c, mut hmm) = setup(4);
        hmm.placement = crate::placement::PlacementConfig::load_aware();
        // Budget for ~2 experts per layer.
        let per_layer = 2 * hmm.model.expert_bytes();
        hmm.placement.migration_budget_bytes =
            per_layer * hmm.model.n_layers;
        hmm.load_initial(&par(2, 2, 0..4), KV).unwrap();
        feed_skewed(&mut hmm, &[5, 9, 13, 17], 10);
        let plan = hmm.plan_rebalance().unwrap();
        let moved_bytes =
            plan.migrated_expert_count() as u64 * hmm.model.expert_bytes();
        assert!(
            moved_bytes <= hmm.placement.migration_budget_bytes,
            "{moved_bytes} > budget"
        );
        assert!(plan.migrated_expert_count() > 0, "budget allows some moves");
    }

    #[test]
    fn load_aware_scale_up_stays_a_partition() {
        let (_c, mut hmm) = setup(6);
        hmm.placement = crate::placement::PlacementConfig::load_aware();
        hmm.load_initial(&par(2, 2, 0..4), KV).unwrap();
        feed_skewed(&mut hmm, &[9], 10);
        let to = par(3, 2, 0..6);
        let plan = hmm.plan_scale(&to).unwrap();
        assert!(plan.migrations_have_matching_evictions());
        hmm.execute_plan(&plan, &to).unwrap();
        hmm.apply_deferred_frees().unwrap();
        for layer in [0usize, 26] {
            let mut seen = vec![0u32; 64];
            for d in 0..6 {
                for e in hmm.worker(d).unwrap().vpages.experts(layer) {
                    seen[e] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "layer {layer}: {seen:?}");
        }
        // New devices actually received experts (the uniform prior spreads
        // cold experts even under skew).
        assert!(hmm.worker(4).unwrap().vpages.bound_count() > 0);
        assert!(hmm.worker(5).unwrap().vpages.bound_count() > 0);
    }

    #[test]
    fn generated_plans_pair_migrations_with_evictions() {
        let (_c, mut hmm) = setup(6);
        hmm.load_initial(&par(3, 2, 0..6), KV).unwrap();
        let down = hmm.plan_scale(&par(2, 2, 0..4)).unwrap();
        assert!(down.migrations_have_matching_evictions());
        hmm.execute_plan(&down, &par(2, 2, 0..4)).unwrap();
        hmm.apply_deferred_frees().unwrap();
        let up = hmm.plan_scale(&par(3, 2, 0..6)).unwrap();
        assert!(up.migrations_have_matching_evictions());
    }

    /// Per-device HBM usage snapshot (rollback equality checks).
    fn usage(cluster: &Rc<RefCell<Cluster>>, n: usize) -> Vec<u64> {
        let c = cluster.borrow();
        (0..n).map(|d| c.devices[d].hbm.used()).collect()
    }

    #[test]
    fn faulted_execute_plan_rolls_back_to_pre_plan_state() {
        use crate::chaos::{FaultInjector, FaultKind, FaultPlan};

        let (cluster, mut hmm) = setup(6);
        hmm.load_initial(&par(2, 2, 0..4), KV).unwrap();
        let inj = Rc::new(RefCell::new(FaultInjector::new(
            FaultPlan::single(0, FaultKind::P2pLinkFail { after_legs: 5 }),
        )));
        hmm.set_fault_injector(inj.clone());

        let used_before = usage(&cluster, 6);
        let owners_before = hmm.expert_owner.clone();
        let to = par(3, 2, 0..6);
        let plan = hmm.plan_scale(&to).unwrap();
        let exec = hmm.execute_plan(&plan, &to).unwrap();

        // Aborted and rolled back: exactly one Faulted step, RolledBack
        // before it, Skipped after it.
        let report = exec.aborted.as_ref().expect("fault must abort");
        assert!(report.rolled_back);
        assert!(matches!(report.fault, FaultKind::P2pLinkFail { .. }));
        assert_eq!(exec.steps.len(), plan.ops.len());
        assert_eq!(
            exec.steps
                .iter()
                .filter(|s| matches!(s, StepOutcome::Faulted(_)))
                .count(),
            1
        );
        assert!(matches!(
            exec.steps[report.op_index],
            StepOutcome::Faulted(_)
        ));
        assert!(exec.steps[..report.op_index]
            .iter()
            .all(|s| matches!(s, StepOutcome::RolledBack)));
        assert!(exec.steps[report.op_index + 1..]
            .iter()
            .all(|s| *s == StepOutcome::Skipped));
        assert!(exec.stats.rollback_time > 0.0);
        assert!(exec.stats.total > 0.0);

        // Cluster state is byte-identical to before the plan; nothing is
        // queued for deferred free; the configuration is unchanged.
        assert_eq!(usage(&cluster, 6), used_before);
        assert_eq!(hmm.deferred_free_count(), 0);
        assert_eq!(hmm.current_parallel().unwrap().n_devices(), 4);
        assert_eq!(hmm.expert_owner, owners_before);
        let total: usize = (0..6)
            .map(|d| {
                hmm.worker(d).map(|w| w.vpages.bound_count()).unwrap_or(0)
            })
            .sum();
        assert_eq!(total, (27 * 64) as usize, "partition intact");

        // The next event (no fault armed) succeeds on the same state.
        let plan2 = hmm.plan_scale(&to).unwrap();
        let exec2 = hmm.execute_plan(&plan2, &to).unwrap();
        assert!(exec2.aborted.is_none());
        assert!(exec2.steps.iter().all(|s| *s == StepOutcome::Applied));
        hmm.apply_deferred_frees().unwrap();
        assert_eq!(hmm.current_parallel().unwrap().n_devices(), 6);
    }

    #[test]
    fn device_loss_mid_scale_down_rolls_back_shard_release() {
        use crate::chaos::{FaultInjector, FaultKind, FaultPlan};

        let (cluster, mut hmm) = setup(6);
        hmm.load_initial(&par(3, 2, 0..6), KV).unwrap();
        let inj = Rc::new(RefCell::new(FaultInjector::new(
            FaultPlan::single(0, FaultKind::DeviceLoss { dev: 4 }),
        )));
        hmm.set_fault_injector(inj);

        let used_before = usage(&cluster, 6);
        let to = par(2, 2, 0..4);
        let plan = hmm.plan_scale(&to).unwrap();
        // The scale-down releases shards of devices 4/5 and migrates their
        // experts; the first leg out of device 4 faults after the release.
        let exec = hmm.execute_plan(&plan, &to).unwrap();
        assert!(exec.aborted.is_some());
        assert_eq!(usage(&cluster, 6), used_before);
        assert_eq!(hmm.deferred_free_count(), 0);
        // Device 4's worker got its shards and KV back.
        let w = hmm.worker(4).unwrap();
        assert!(w.kv_region.is_some(), "KV region restored");
        assert!(!w.regions.is_empty(), "attention shards restored");
        assert_eq!(hmm.current_parallel().unwrap().n_devices(), 6);
    }

    #[test]
    fn hbm_pressure_shrinks_the_planned_budget() {
        use crate::chaos::{FaultInjector, FaultKind, FaultPlan};

        let (_c, mut hmm) = setup(6);
        hmm.placement.migration_budget_bytes = 1 << 30;
        hmm.load_initial(&par(3, 2, 0..6), KV).unwrap();
        let inj = Rc::new(RefCell::new(FaultInjector::new(
            FaultPlan::single(0, FaultKind::HbmPressure {
                budget_factor: 0.25,
            }),
        )));
        hmm.set_fault_injector(inj.clone());
        let to = par(2, 2, 0..4);
        let shrunk = hmm.plan_scale(&to).unwrap();
        assert_eq!(shrunk.migration_budget_bytes, 1 << 28);
        assert_eq!(inj.borrow().fired_count(), 1);
        // The next event is unshrunk.
        let normal = hmm.plan_scale(&to).unwrap();
        assert_eq!(normal.migration_budget_bytes, 1 << 30);
    }

    #[test]
    fn park_unpark_round_trip_is_dram_fast_and_conserves_state() {
        let (cluster, mut hmm) = setup(4);
        let p = par(2, 2, 0..4);
        let cold = hmm.load_initial(&p, KV).unwrap();
        let used_loaded = usage(&cluster, 4);
        let bound_before: usize = (0..4)
            .map(|d| hmm.worker(d).unwrap().vpages.bound_count())
            .sum();

        let park = hmm.park_to_host().unwrap();
        assert!(park.dram_bytes > 0);
        assert!(park.hbm_freed >= park.dram_bytes, "replicas dedup to one staged copy");
        assert!(park.d2h_time > 0.0);
        {
            let c = cluster.borrow();
            assert_eq!(c.host.used(), park.dram_bytes, "allocator agrees");
            for d in 0..4 {
                assert_eq!(c.devices[d].hbm.used(), 0, "device {d} drained");
            }
        }
        assert!(hmm.current_parallel().is_none());

        let warm = hmm.unpark_from_host(&p, KV).unwrap();
        assert!(warm > 0.0);
        assert!(
            warm < cold / 5.0,
            "DRAM-warm unpark {warm} must be far under cold load {cold}"
        );
        assert_eq!(cluster.borrow().host.used(), 0, "promotion drains DRAM");
        assert_eq!(usage(&cluster, 4), used_loaded, "HBM layout restored");
        let bound_after: usize = (0..4)
            .map(|d| hmm.worker(d).unwrap().vpages.bound_count())
            .sum();
        assert_eq!(bound_after, bound_before, "expert partition restored");
        // The journal recorded one demote + one promote per staged tag.
        let journal = hmm.tier.drain_journal();
        assert!(!journal.is_empty());
        let demotes = journal
            .iter()
            .filter(|s| s.to == crate::tier::TierLevel::HostDram)
            .count();
        let promotes = journal
            .iter()
            .filter(|s| s.from == crate::tier::TierLevel::HostDram)
            .count();
        assert_eq!(demotes, promotes);
    }

    #[test]
    fn pressure_demotes_cold_experts_and_credits_the_budget() {
        use crate::chaos::{FaultInjector, FaultKind, FaultPlan};

        let (cluster, mut hmm) = setup(6);
        hmm.placement.migration_budget_bytes = 8 * hmm.model.expert_bytes();
        hmm.placement.demote_on_pressure = true;
        hmm.load_initial(&par(3, 2, 0..6), KV).unwrap();
        // Mark a handful of experts hot so the coldest are well-defined.
        feed_skewed(&mut hmm, &[1, 2, 3, 4], 5);
        let inj = Rc::new(RefCell::new(FaultInjector::new(FaultPlan::single(
            0,
            FaultKind::HbmPressure { budget_factor: 0.0 },
        ))));
        hmm.set_fault_injector(inj);

        let to = par(2, 2, 0..4);
        let plan = hmm.plan_scale(&to).unwrap();
        let demoted = plan.demoted_expert_count();
        assert!(demoted > 0, "pressure must demote cold experts");
        assert!(demoted <= hmm.placement.max_demotions);
        // The demoted bytes are credited back, capped by the configured
        // budget.
        assert_eq!(
            plan.migration_budget_bytes,
            plan.demoted_bytes().min(hmm.placement.migration_budget_bytes)
        );
        // Hot experts (high EWMA) are never demotion victims.
        for op in &plan.ops {
            if let PlanOp::DemoteExpert { layer: 0, expert, .. } = op {
                assert!(
                    ![1usize, 2, 3, 4].contains(expert),
                    "hot expert {expert} demoted"
                );
            }
        }

        let exec = hmm.execute_plan(&plan, &to).unwrap();
        assert!(exec.aborted.is_none());
        assert!(exec.stats.d2h_time > 0.0, "demotion pays d2h");
        hmm.apply_deferred_frees().unwrap();
        assert_eq!(hmm.tier.demoted_expert_count(), demoted);
        assert_eq!(
            cluster.borrow().host.used(),
            plan.demoted_bytes(),
            "allocator and plan agree on staged bytes"
        );

        // The next (pressure-free) event promotes every expert back.
        let plan2 = hmm.plan_scale(&par(3, 2, 0..6)).unwrap();
        assert_eq!(plan2.promoted_expert_count(), demoted);
        let exec2 = hmm.execute_plan(&plan2, &par(3, 2, 0..6)).unwrap();
        assert!(exec2.aborted.is_none());
        assert!(exec2.stats.h2d_time > 0.0, "promotion pays h2d");
        hmm.apply_deferred_frees().unwrap();
        assert_eq!(hmm.tier.demoted_expert_count(), 0);
        assert_eq!(cluster.borrow().host.used(), 0);
        // Partition restored across the grown configuration.
        let total: usize = (0..6)
            .map(|d| hmm.worker(d).unwrap().vpages.bound_count())
            .sum();
        assert_eq!(total, (27 * 64) as usize);
    }

    #[test]
    fn unpark_without_staging_falls_back_to_disk() {
        let (cluster, mut hmm) = setup(4);
        let p = par(2, 2, 0..4);
        hmm.load_initial(&p, KV).unwrap();
        // Cold park: drop everything, no staging.
        hmm.teardown_all().unwrap();
        cluster.borrow_mut().disk.reset_dedup();
        let t = hmm.unpark_from_host(&p, KV).unwrap();
        // With nothing staged, unpark degenerates to a disk load.
        assert!(t > 1.0, "disk fallback must be disk-speed: {t}");
        assert_eq!(cluster.borrow().host.used(), 0);
    }

    #[test]
    fn attach_without_zero_copy_duplicates_memory() {
        let cluster = Rc::new(RefCell::new(Cluster::new(
            4,
            256, // larger HBM so the duplicate fits
            crate::device::Timings::cloudmatrix(),
        )));
        let mut hmm = HmmControl::new(
            cluster.clone(),
            dsv2_lite(),
            HmmOptions {
                use_zero_copy: false,
                ipc_safe_alloc: false,
                ..Default::default()
            },
        );
        hmm.load_initial(&par(2, 2, 0..4), KV).unwrap();
        let used = cluster.borrow().used_over(&[0, 1, 2, 3]);
        let proc = hmm.alloc_proc();
        let (_binding, t) = hmm.attach_instance(proc).unwrap();
        let used_after = cluster.borrow().used_over(&[0, 1, 2, 3]);
        assert!(
            used_after > used * 19 / 10,
            "duplication must ~double usage: {used} -> {used_after}"
        );
        assert!(t > 0.05, "duplication is slow: {t}");
        hmm.detach_instance(proc).unwrap();
        assert_eq!(cluster.borrow().used_over(&[0, 1, 2, 3]), used);
    }
}
