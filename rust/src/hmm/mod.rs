//! HBM Management Module (HMM) — the core of ElasticMoE (§4.4).
//!
//! The HMM decouples expensive memory operations (weight loading, KV-cache
//! setup, expert redistribution) from inference execution. It loads weights
//! once, keeps them resident, and serves them to inference instances through
//! zero-copy handles. During scaling it computes a minimal-cost plan that
//! maximises zero-copy reuse on surviving devices, provisions new devices
//! with high-bandwidth P2P transfers, and remaps experts in place through
//! the virtual-page tables — all while the active instance keeps serving.
//!
//! Structure mirrors the paper: a *control plane* ([`control::HmmControl`])
//! coordinating *per-device workers* ([`worker`]) that execute data-plane
//! primitives ([`primitives`]) against the simulated devices, with expert
//! tensors managed by [`vpage`] tables.

pub mod control;
pub mod plan;
pub mod primitives;
pub mod store;
pub mod vpage;
pub mod weights;
pub mod worker;

pub use control::{
    AbortReport, HmmControl, HmmOptions, ParkStats, PlanExecution,
    StepOutcome,
};
pub use plan::{PlanOp, ScalePlan};
pub use store::TensorStore;
pub use vpage::VpageTable;
pub use weights::{UnitKind, WeightLayout, WeightUnit};
