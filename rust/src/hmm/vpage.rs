//! Virtual-page expert tables (the `vpage-remap` primitive, §4.6 / D.5).
//!
//! Expert weights must look contiguous to kernels, but rebuilding a
//! contiguous buffer on every EP change is O(bytes) in both time and peak
//! memory. The paper instead backs a contiguous *virtual* range with
//! physical pages and remaps slots in O(1). This module reproduces that
//! mechanism: each device has, per layer, a table of expert slots mapping
//! logical expert ids to physical regions. Migration = bind new region into
//! a slot (O(1)); eviction = unbind (deferred free until switchover).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::device::RegionId;

/// One bound expert slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slot {
    pub expert: usize,
    pub region: RegionId,
}

/// Per-device virtual-page table: `layer -> ordered expert slots`.
///
/// The slot order *is* the virtual-address order the kernel sees; lookups
/// and remaps are O(log E) map operations (O(1) in the paper's page-table
/// sense: independent of tensor bytes).
#[derive(Debug, Clone, Default)]
pub struct VpageTable {
    layers: BTreeMap<usize, BTreeMap<usize, RegionId>>,
    /// Remap operations performed (ablation/telemetry).
    pub remap_count: u64,
}

impl VpageTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bind `expert` of `layer` to a physical region. Errors if the slot is
    /// already bound (must `unbind` first — mirrors aclrtMapMem semantics).
    pub fn bind(
        &mut self,
        layer: usize,
        expert: usize,
        region: RegionId,
    ) -> Result<()> {
        let slots = self.layers.entry(layer).or_default();
        if slots.contains_key(&expert) {
            bail!("layer{layer} expert{expert} already bound");
        }
        slots.insert(expert, region);
        self.remap_count += 1;
        Ok(())
    }

    /// Unbind a slot, returning the physical region (caller frees it —
    /// usually *deferred* until the old instance switches away).
    pub fn unbind(&mut self, layer: usize, expert: usize) -> Result<RegionId> {
        let slots = self
            .layers
            .get_mut(&layer)
            .ok_or_else(|| anyhow::anyhow!("no layer {layer}"))?;
        let region = slots
            .remove(&expert)
            .ok_or_else(|| anyhow::anyhow!("layer{layer} expert{expert} not bound"))?;
        self.remap_count += 1;
        Ok(region)
    }

    /// Rebind an existing slot to a new region in place (migration refresh),
    /// returning the old region.
    pub fn rebind(
        &mut self,
        layer: usize,
        expert: usize,
        region: RegionId,
    ) -> Result<RegionId> {
        let old = self.unbind(layer, expert)?;
        self.bind(layer, expert, region)?;
        Ok(old)
    }

    /// Physical region of a bound expert.
    pub fn lookup(&self, layer: usize, expert: usize) -> Option<RegionId> {
        self.layers.get(&layer)?.get(&expert).copied()
    }

    /// Experts bound for a layer, in virtual order.
    pub fn experts(&self, layer: usize) -> Vec<usize> {
        self.layers
            .get(&layer)
            .map(|s| s.keys().copied().collect())
            .unwrap_or_default()
    }

    /// Number of bound slots across all layers.
    pub fn bound_count(&self) -> usize {
        self.layers.values().map(|s| s.len()).sum()
    }

    /// Every binding as `(layer, expert, region)`.
    pub fn all_bindings(&self) -> Vec<(usize, usize, RegionId)> {
        self.layers
            .iter()
            .flat_map(|(&l, slots)| {
                slots.iter().map(move |(&e, &r)| (l, e, r))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_lookup_unbind() {
        let mut t = VpageTable::new();
        t.bind(0, 5, 100).unwrap();
        t.bind(0, 9, 101).unwrap();
        t.bind(1, 5, 102).unwrap();
        assert_eq!(t.lookup(0, 5), Some(100));
        assert_eq!(t.lookup(1, 5), Some(102));
        assert_eq!(t.lookup(2, 5), None);
        assert_eq!(t.experts(0), vec![5, 9]);
        assert_eq!(t.bound_count(), 3);

        let r = t.unbind(0, 5).unwrap();
        assert_eq!(r, 100);
        assert_eq!(t.lookup(0, 5), None);
        assert!(t.unbind(0, 5).is_err());
    }

    #[test]
    fn double_bind_rejected() {
        let mut t = VpageTable::new();
        t.bind(0, 1, 10).unwrap();
        assert!(t.bind(0, 1, 11).is_err());
        assert_eq!(t.lookup(0, 1), Some(10));
    }

    #[test]
    fn rebind_swaps_regions() {
        let mut t = VpageTable::new();
        t.bind(3, 7, 50).unwrap();
        let old = t.rebind(3, 7, 60).unwrap();
        assert_eq!(old, 50);
        assert_eq!(t.lookup(3, 7), Some(60));
    }

    #[test]
    fn remap_count_tracks_operations() {
        let mut t = VpageTable::new();
        t.bind(0, 0, 1).unwrap();
        t.bind(0, 1, 2).unwrap();
        t.unbind(0, 0).unwrap();
        assert_eq!(t.remap_count, 3);
    }
}
