//! Logical weight layout: which weight units live on which device under a
//! given (model, parallel) configuration. Units are the granularity of
//! zero-copy handles, P2P transfers and expert migration.

use std::collections::BTreeMap;

use crate::config::{ModelConfig, ParallelConfig};
use crate::device::DeviceId;

/// What a weight unit is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum UnitKind {
    /// Embedding shard (one per device, sharded by TP).
    Embed,
    /// Attention + gate + norm shard for one layer (sharded by TP).
    Attn { layer: usize },
    /// One routed expert's weights for one layer (owned by one EP rank).
    Expert { layer: usize, expert: usize },
    /// Shared experts for one layer (replicated on every device).
    SharedExpert { layer: usize },
}

/// A logical weight unit with its byte size.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WeightUnit {
    pub kind: UnitKind,
    pub bytes: u64,
}

impl WeightUnit {
    /// Stable string tag (disk dedup keys, IPC handle names, region tags).
    pub fn tag(&self, tp_rank: usize) -> String {
        match self.kind {
            UnitKind::Embed => format!("embed.tp{tp_rank}"),
            UnitKind::Attn { layer } => format!("layer{layer}.attn.tp{tp_rank}"),
            UnitKind::Expert { layer, expert } => {
                format!("layer{layer}.expert{expert}")
            }
            UnitKind::SharedExpert { layer } => {
                format!("layer{layer}.shared.tp{tp_rank}")
            }
        }
    }

    pub fn is_expert(&self) -> bool {
        matches!(self.kind, UnitKind::Expert { .. })
    }
}

/// Placement of every weight unit for one configuration.
#[derive(Debug, Clone)]
pub struct WeightLayout {
    /// Per device: the units resident there.
    pub per_device: BTreeMap<DeviceId, Vec<WeightUnit>>,
    /// TP rank of each device (determines which shard of attention it has).
    pub tp_rank: BTreeMap<DeviceId, usize>,
    /// Owner device of each routed expert: `owner[layer][expert]`.
    pub expert_owner: Vec<Vec<DeviceId>>,
}

impl WeightLayout {
    /// Compute the layout induced by `parallel` for `model`: attention
    /// sharded by TP on every device, routed experts round-robin over EP
    /// ranks, shared experts replicated.
    pub fn compute(model: &ModelConfig, parallel: &ParallelConfig) -> Self {
        let mut per_device: BTreeMap<DeviceId, Vec<WeightUnit>> =
            BTreeMap::new();
        let mut tp_rank = BTreeMap::new();
        let tp = parallel.tp as u64;

        for (i, &dev) in parallel.devices.iter().enumerate() {
            let rank = i % parallel.tp;
            tp_rank.insert(dev, rank);
            let units = per_device.entry(dev).or_default();
            units.push(WeightUnit {
                kind: UnitKind::Embed,
                bytes: model.embed_bytes() / tp,
            });
            for layer in 0..model.n_layers as usize {
                units.push(WeightUnit {
                    kind: UnitKind::Attn { layer },
                    bytes: model.attn_bytes_per_layer() / tp,
                });
                if model.n_shared_experts > 0 {
                    units.push(WeightUnit {
                        kind: UnitKind::SharedExpert { layer },
                        bytes: model.n_shared_experts * model.expert_bytes()
                            / tp,
                    });
                }
            }
        }

        // Routed experts over EP ranks (EP rank r = parallel.devices[r]).
        let placement = parallel.expert_placement(model.n_experts as usize);
        let mut expert_owner =
            vec![
                vec![DeviceId::MAX; model.n_experts as usize];
                model.n_layers as usize
            ];
        for (rank, experts) in placement.iter().enumerate() {
            let dev = parallel.ep_device(rank);
            for &e in experts {
                for layer in 0..model.n_layers as usize {
                    expert_owner[layer][e] = dev;
                    per_device.entry(dev).or_default().push(WeightUnit {
                        kind: UnitKind::Expert { layer, expert: e },
                        bytes: model.expert_bytes(),
                    });
                }
            }
        }

        WeightLayout {
            per_device,
            tp_rank,
            expert_owner,
        }
    }

    /// Total bytes on one device.
    pub fn device_bytes(&self, dev: DeviceId) -> u64 {
        self.per_device
            .get(&dev)
            .map(|units| units.iter().map(|u| u.bytes).sum())
            .unwrap_or(0)
    }

    /// All devices in this layout.
    pub fn devices(&self) -> Vec<DeviceId> {
        self.per_device.keys().copied().collect()
    }

    /// Units of a device (empty slice if absent).
    pub fn units(&self, dev: DeviceId) -> &[WeightUnit] {
        self.per_device
            .get(&dev)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;
    use crate::config::ParallelConfig;

    fn layout(dp: usize, tp: usize, n: usize) -> (WeightLayout, ModelConfig) {
        let m = dsv2_lite();
        let p = ParallelConfig::standard(dp, tp, (0..n).collect()).unwrap();
        (WeightLayout::compute(&m, &p), m)
    }

    #[test]
    fn every_expert_has_exactly_one_owner() {
        let (l, m) = layout(2, 2, 4);
        for layer in 0..m.n_layers as usize {
            for e in 0..m.n_experts as usize {
                let owner = l.expert_owner[layer][e];
                assert!(owner < 4, "layer{layer} expert{e} unowned");
            }
        }
        // Each expert appears on exactly one device's unit list.
        let mut count = 0;
        for dev in l.devices() {
            count += l
                .units(dev)
                .iter()
                .filter(|u| u.is_expert())
                .count();
        }
        assert_eq!(count, (m.n_layers * m.n_experts) as usize);
    }

    #[test]
    fn device_bytes_match_model_accounting() {
        let (l, m) = layout(2, 2, 4);
        let per_dev = l.device_bytes(0);
        let formula = m.device_weight_bytes(2, 4);
        // Same within rounding of shared-expert TP sharding.
        let ratio = per_dev as f64 / formula as f64;
        assert!((0.9..1.1).contains(&ratio), "{per_dev} vs {formula}");
    }

    #[test]
    fn tp_ranks_alternate() {
        let (l, _) = layout(3, 2, 6);
        assert_eq!(l.tp_rank[&0], 0);
        assert_eq!(l.tp_rank[&1], 1);
        assert_eq!(l.tp_rank[&4], 0);
        assert_eq!(l.tp_rank[&5], 1);
    }

    #[test]
    fn growing_ep_moves_experts_not_attention() {
        let (l4, m) = layout(2, 2, 4);
        let (l6, _) = layout(3, 2, 6);
        // Attention bytes per device identical (TP fixed).
        let attn4: u64 = l4
            .units(0)
            .iter()
            .filter(|u| !u.is_expert())
            .map(|u| u.bytes)
            .sum();
        let attn6: u64 = l6
            .units(0)
            .iter()
            .filter(|u| !u.is_expert())
            .map(|u| u.bytes)
            .sum();
        assert_eq!(attn4, attn6);
        // Expert count per device drops.
        let e4 = l4.units(0).iter().filter(|u| u.is_expert()).count();
        let e6 = l6.units(0).iter().filter(|u| u.is_expert()).count();
        assert!(e6 < e4);
        let _ = m;
    }

    #[test]
    fn unit_tags_are_stable_and_unique() {
        let (l, _) = layout(2, 2, 4);
        let mut tags = std::collections::HashSet::new();
        for dev in l.devices() {
            let rank = l.tp_rank[&dev];
            for u in l.units(dev) {
                let tag = u.tag(rank);
                if u.is_expert() {
                    assert!(tags.insert(tag), "duplicate expert tag");
                }
            }
        }
    }
}
