//! Per-device HMM worker: the data-plane agent bound to one accelerator
//! (§4.4). Tracks the regions it has allocated for weight units (by tag),
//! its KV-cache region, and its virtual-page expert table.

use std::collections::BTreeMap;

use crate::device::{DeviceId, RegionId};

use super::vpage::VpageTable;

/// One device's HMM worker state.
#[derive(Debug, Clone, Default)]
pub struct Worker {
    pub dev: DeviceId,
    /// Non-expert weight regions by unit tag (embed/attn/shared-expert).
    pub regions: BTreeMap<String, RegionId>,
    /// KV-cache region, if allocated.
    pub kv_region: Option<RegionId>,
    /// Expert slots (virtual-page table).
    pub vpages: VpageTable,
}

impl Worker {
    pub fn new(dev: DeviceId) -> Self {
        Worker {
            dev,
            ..Default::default()
        }
    }

    /// All regions this worker currently references (for teardown).
    pub fn all_regions(&self) -> Vec<RegionId> {
        let mut out: Vec<RegionId> = self.regions.values().copied().collect();
        out.extend(self.kv_region);
        out.extend(self.vpages.all_bindings().into_iter().map(|(_, _, r)| r));
        out
    }

    /// Number of zero-copy handles an instance needs from this worker
    /// (one per non-expert unit + one per bound expert + KV).
    pub fn handle_count(&self) -> usize {
        self.regions.len()
            + self.vpages.bound_count()
            + usize::from(self.kv_region.is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_count_tracks_state() {
        let mut w = Worker::new(0);
        assert_eq!(w.handle_count(), 0);
        w.regions.insert("embed.tp0".into(), 1);
        w.regions.insert("layer0.attn.tp0".into(), 2);
        w.vpages.bind(0, 3, 10).unwrap();
        w.kv_region = Some(99);
        assert_eq!(w.handle_count(), 4);
        let regions = w.all_regions();
        assert!(regions.contains(&1));
        assert!(regions.contains(&10));
        assert!(regions.contains(&99));
    }
}
