//! Scaling plans: the minimal-cost weight-redistribution schedule the HMM
//! control plane computes before a scaling event (§5.2, Fig 6).
//!
//! The objective is the paper's: maximise zero-copy reuse of existing
//! weights and KV caches, restrict P2P transfers to the minimal required
//! set, and perform expert migration via vpage remap instead of realloc.

use crate::device::DeviceId;

/// One planned operation.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanOp {
    /// Reuse a resident unit on a surviving device via zero-copy.
    ZeroCopyReuse { dev: DeviceId, tag: String, bytes: u64 },
    /// P2P-copy a non-expert shard (attention/embed) to a new device.
    P2pAttn {
        src: DeviceId,
        dst: DeviceId,
        tag: String,
        bytes: u64,
    },
    /// Migrate one expert to a new owner (P2P + vpage bind on dst).
    MigrateExpert {
        layer: usize,
        expert: usize,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
    },
    /// Unbind an expert from a device that no longer owns it; the physical
    /// pages are freed only at switchover (deferred).
    EvictExpert {
        layer: usize,
        expert: usize,
        dev: DeviceId,
    },
    /// Allocate a fresh KV cache on a new device.
    KvInit { dev: DeviceId, bytes: u64 },
    /// Reuse the existing KV cache on a surviving device.
    KvReuse { dev: DeviceId },
    /// Zero-copy remap of one live sequence's KV block table: its device
    /// group survives, the blocks stay physically put, and the successor
    /// adopts them through the virtual-page tables (an O(1) page-table
    /// handover per sequence, independent of context length).
    KvBlockRemap {
        request: u64,
        dev: DeviceId,
        blocks: usize,
    },
    /// P2P-copy one live sequence's KV blocks to its new owner replica.
    /// `legs` holds the per-TP-shard fabric transfers `(src, dst, bytes)`;
    /// `bytes` is their total, charged against the shared migration
    /// budget.
    KvBlockCopy {
        request: u64,
        blocks: usize,
        bytes: u64,
        legs: Vec<(DeviceId, DeviceId, u64)>,
    },
    /// Drop one live sequence's KV and re-prefill it on the successor —
    /// planned only when recompute is cheaper than the transfer or the
    /// byte budget is exhausted.
    KvDropRecompute {
        request: u64,
        tokens: usize,
        blocks: usize,
    },
    /// Release a departing device's non-expert shards and KV cache
    /// (deferred until the old instance drains).
    ReleaseShard { dev: DeviceId },
    /// Load a non-expert shard from the host-DRAM staging tier over the
    /// h2d link (the middle rung of the residency ladder: planned when no
    /// P2P source exists but the unit is DRAM-staged — cheaper than disk
    /// by an order of magnitude).
    HostLoad {
        dev: DeviceId,
        tag: String,
        bytes: u64,
    },
    /// Demote a cold expert (lowest popularity EWMA) out of HBM into host
    /// DRAM under HBM pressure, reclaiming its bytes for the migration
    /// budget instead of failing it. The expert stays logically placed on
    /// `dev` (DRAM-backed) until a later event promotes it back.
    DemoteExpert {
        layer: usize,
        expert: usize,
        dev: DeviceId,
        bytes: u64,
    },
    /// Promote a previously demoted expert from host DRAM back into HBM
    /// on `dev` (planned on the first pressure-free event).
    PromoteExpert {
        layer: usize,
        expert: usize,
        dev: DeviceId,
        bytes: u64,
    },
}

/// A full scaling plan.
#[derive(Debug, Clone, Default)]
pub struct ScalePlan {
    pub from_label: String,
    pub to_label: String,
    pub ops: Vec<PlanOp>,
    /// Effective migration-byte budget the plan was drawn under: the
    /// configured [`crate::placement::PlacementConfig`] budget after any
    /// chaos HBM-pressure shrink. KV copy legs are charged against its
    /// leftover, so [`Self::kv_copied_bytes`] never exceeds it — the
    /// byte-budget trace invariant.
    pub migration_budget_bytes: u64,
}

impl ScalePlan {
    /// Total bytes moved over the fabric.
    pub fn p2p_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match op {
                PlanOp::P2pAttn { bytes, .. }
                | PlanOp::MigrateExpert { bytes, .. } => *bytes,
                _ => 0,
            })
            .sum()
    }

    /// Bytes reused with zero-copy (no movement).
    pub fn reused_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match op {
                PlanOp::ZeroCopyReuse { bytes, .. } => *bytes,
                _ => 0,
            })
            .sum()
    }

    pub fn migrated_expert_count(&self) -> usize {
        self.ops
            .iter()
            .filter(|op| matches!(op, PlanOp::MigrateExpert { .. }))
            .count()
    }

    pub fn evicted_expert_count(&self) -> usize {
        self.ops
            .iter()
            .filter(|op| matches!(op, PlanOp::EvictExpert { .. }))
            .count()
    }

    /// Bytes moved by expert migrations alone (excludes attention P2P and
    /// KV legs). Reported in the chaos plan audit; forced moves are
    /// budget-exempt, so this is *not* compared against the budget.
    pub fn expert_migration_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match op {
                PlanOp::MigrateExpert { bytes, .. } => *bytes,
                _ => 0,
            })
            .sum()
    }

    /// The P2P transfer list `(src, dst, bytes)` for fabric timing.
    pub fn transfers(&self) -> Vec<(DeviceId, DeviceId, u64)> {
        self.ops
            .iter()
            .filter_map(|op| match op {
                PlanOp::P2pAttn {
                    src, dst, bytes, ..
                } => Some((*src, *dst, *bytes)),
                PlanOp::MigrateExpert {
                    src, dst, bytes, ..
                } => Some((*src, *dst, *bytes)),
                _ => None,
            })
            .collect()
    }

    /// Plan invariant: every [`PlanOp::MigrateExpert`] has a matching
    /// [`PlanOp::EvictExpert`] for the same `(layer, expert)` on the old
    /// owner. A migration without its eviction would leave the expert
    /// double-bound (and its old pages never freed at switchover).
    pub fn migrations_have_matching_evictions(&self) -> bool {
        self.ops.iter().all(|op| match op {
            PlanOp::MigrateExpert {
                layer, expert, src, ..
            } => self.ops.iter().any(|o| {
                matches!(
                    o,
                    PlanOp::EvictExpert { layer: l, expert: e, dev }
                        if l == layer && e == expert && dev == src
                )
            }),
            _ => true,
        })
    }

    /// ---- tier legs --------------------------------------------------------

    /// Bytes sourced from the host-DRAM tier over the h2d link (shard
    /// loads + expert promotions).
    pub fn h2d_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match op {
                PlanOp::HostLoad { bytes, .. }
                | PlanOp::PromoteExpert { bytes, .. } => *bytes,
                _ => 0,
            })
            .sum()
    }

    /// Bytes demoted out of HBM into host DRAM (cold-expert offload).
    pub fn demoted_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match op {
                PlanOp::DemoteExpert { bytes, .. } => *bytes,
                _ => 0,
            })
            .sum()
    }

    pub fn demoted_expert_count(&self) -> usize {
        self.ops
            .iter()
            .filter(|op| matches!(op, PlanOp::DemoteExpert { .. }))
            .count()
    }

    pub fn promoted_expert_count(&self) -> usize {
        self.ops
            .iter()
            .filter(|op| matches!(op, PlanOp::PromoteExpert { .. }))
            .count()
    }

    /// ---- live-KV migration legs ------------------------------------------

    /// Blocks of live sequences that remap in place (zero-copy).
    pub fn kv_remapped_blocks(&self) -> usize {
        self.ops
            .iter()
            .map(|op| match op {
                PlanOp::KvBlockRemap { blocks, .. } => *blocks,
                _ => 0,
            })
            .sum()
    }

    /// Blocks of live sequences that move over the fabric.
    pub fn kv_copied_blocks(&self) -> usize {
        self.ops
            .iter()
            .map(|op| match op {
                PlanOp::KvBlockCopy { blocks, .. } => *blocks,
                _ => 0,
            })
            .sum()
    }

    /// Blocks freed because their sequence re-prefills on the successor.
    pub fn kv_freed_blocks(&self) -> usize {
        self.ops
            .iter()
            .map(|op| match op {
                PlanOp::KvDropRecompute { blocks, .. } => *blocks,
                _ => 0,
            })
            .sum()
    }

    /// Total bytes the live-KV copy legs move.
    pub fn kv_copied_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match op {
                PlanOp::KvBlockCopy { bytes, .. } => *bytes,
                _ => 0,
            })
            .sum()
    }

    /// Tokens re-prefilled from scratch by the recompute legs.
    pub fn kv_recompute_tokens(&self) -> usize {
        self.ops
            .iter()
            .map(|op| match op {
                PlanOp::KvDropRecompute { tokens, .. } => *tokens,
                _ => 0,
            })
            .sum()
    }

    /// Per-device fabric legs of the live-KV copies (for
    /// [`crate::device::Interconnect::parallel_transfers`]). Kept
    /// separate from [`Self::transfers`]: weight migration runs in the
    /// concurrent phase, KV copies in the switchover window.
    pub fn kv_transfers(&self) -> Vec<(DeviceId, DeviceId, u64)> {
        self.ops
            .iter()
            .flat_map(|op| match op {
                PlanOp::KvBlockCopy { legs, .. } => legs.clone(),
                _ => Vec::new(),
            })
            .collect()
    }

    /// Conservation invariant over live KV: every snapshot block is
    /// accounted exactly once — remapped, copied, or freed.
    pub fn kv_blocks_conserved(&self, snapshot_blocks: usize) -> bool {
        self.kv_remapped_blocks()
            + self.kv_copied_blocks()
            + self.kv_freed_blocks()
            == snapshot_blocks
    }

    /// Reuse fraction: zero-copied bytes / (zero-copied + moved) — the
    /// plan-quality metric the paper's design maximises.
    pub fn reuse_fraction(&self) -> f64 {
        let moved = self.p2p_bytes() as f64;
        let reused = self.reused_bytes() as f64;
        if moved + reused == 0.0 {
            return 1.0;
        }
        reused / (moved + reused)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> ScalePlan {
        ScalePlan {
            from_label: "DP2-TP2-EP4".into(),
            to_label: "DP3-TP2-EP6".into(),
            ops: vec![
                PlanOp::ZeroCopyReuse {
                    dev: 0,
                    tag: "embed.tp0".into(),
                    bytes: 100,
                },
                PlanOp::P2pAttn {
                    src: 0,
                    dst: 4,
                    tag: "layer0.attn.tp0".into(),
                    bytes: 50,
                },
                PlanOp::MigrateExpert {
                    layer: 0,
                    expert: 3,
                    src: 1,
                    dst: 5,
                    bytes: 30,
                },
                PlanOp::EvictExpert {
                    layer: 0,
                    expert: 3,
                    dev: 1,
                },
                PlanOp::KvInit { dev: 4, bytes: 500 },
                PlanOp::KvReuse { dev: 0 },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn byte_accounting() {
        let p = plan();
        assert_eq!(p.p2p_bytes(), 80);
        assert_eq!(p.reused_bytes(), 100);
        assert_eq!(p.migrated_expert_count(), 1);
        assert_eq!(p.evicted_expert_count(), 1);
        assert_eq!(p.transfers(), vec![(0, 4, 50), (1, 5, 30)]);
        let rf = p.reuse_fraction();
        assert!((rf - 100.0 / 180.0).abs() < 1e-12);
    }

    #[test]
    fn empty_plan_reuses_everything() {
        assert_eq!(ScalePlan::default().reuse_fraction(), 1.0);
    }

    #[test]
    fn kv_leg_accounting_and_conservation() {
        let p = ScalePlan {
            from_label: "DP4-TP2-EP8".into(),
            to_label: "DP3-TP2-EP6".into(),
            ops: vec![
                PlanOp::KvBlockRemap { request: 1, dev: 0, blocks: 7 },
                PlanOp::KvBlockRemap { request: 2, dev: 2, blocks: 5 },
                PlanOp::KvBlockCopy {
                    request: 3,
                    blocks: 250,
                    bytes: 4000,
                    legs: vec![(6, 0, 2000), (7, 1, 2000)],
                },
                PlanOp::KvDropRecompute { request: 7, tokens: 40, blocks: 3 },
            ],
            ..Default::default()
        };
        assert_eq!(p.kv_remapped_blocks(), 12);
        assert_eq!(p.kv_copied_blocks(), 250);
        assert_eq!(p.kv_freed_blocks(), 3);
        assert_eq!(p.kv_copied_bytes(), 4000);
        assert_eq!(p.kv_recompute_tokens(), 40);
        assert_eq!(p.kv_transfers(), vec![(6, 0, 2000), (7, 1, 2000)]);
        assert!(p.kv_blocks_conserved(265));
        assert!(!p.kv_blocks_conserved(264));
        // KV legs are invisible to the weight-migration accounting.
        assert_eq!(p.p2p_bytes(), 0);
        assert_eq!(p.transfers(), Vec::new());
        assert!(p.migrations_have_matching_evictions());
    }

    #[test]
    fn tier_leg_accounting() {
        let p = ScalePlan {
            from_label: "a".into(),
            to_label: "b".into(),
            ops: vec![
                PlanOp::HostLoad {
                    dev: 4,
                    tag: "layer0.attn.tp0".into(),
                    bytes: 200,
                },
                PlanOp::DemoteExpert {
                    layer: 1,
                    expert: 7,
                    dev: 0,
                    bytes: 30,
                },
                PlanOp::DemoteExpert {
                    layer: 2,
                    expert: 9,
                    dev: 1,
                    bytes: 30,
                },
                PlanOp::PromoteExpert {
                    layer: 0,
                    expert: 2,
                    dev: 0,
                    bytes: 30,
                },
            ],
            ..Default::default()
        };
        assert_eq!(p.h2d_bytes(), 230);
        assert_eq!(p.demoted_bytes(), 60);
        assert_eq!(p.demoted_expert_count(), 2);
        assert_eq!(p.promoted_expert_count(), 1);
        // Tier legs are invisible to fabric and dedup accounting.
        assert_eq!(p.p2p_bytes(), 0);
        assert_eq!(p.transfers(), Vec::new());
        assert!(p.migrations_have_matching_evictions());
    }

    #[test]
    fn migration_eviction_pairing_invariant() {
        // The hand-built plan is well-formed.
        assert!(plan().migrations_have_matching_evictions());
        // Dropping the eviction breaks it.
        let mut p = plan();
        p.ops.retain(|op| !matches!(op, PlanOp::EvictExpert { .. }));
        assert!(!p.migrations_have_matching_evictions());
        // An eviction on the wrong device does not count.
        let mut p = plan();
        for op in &mut p.ops {
            if let PlanOp::EvictExpert { dev, .. } = op {
                *dev = 3; // migration src is 1
            }
        }
        assert!(!p.migrations_have_matching_evictions());
        // Evictions without migrations are fine (departing devices).
        let p = ScalePlan {
            from_label: "a".into(),
            to_label: "b".into(),
            ops: vec![PlanOp::EvictExpert {
                layer: 0,
                expert: 1,
                dev: 2,
            }],
            ..Default::default()
        };
        assert!(p.migrations_have_matching_evictions());
    }

    #[test]
    fn accounting_on_a_multi_expert_plan() {
        // Hand-built plan with several migrations: byte totals and counts
        // must track exactly.
        let e = |layer: usize, expert: usize, src, dst| {
            [
                PlanOp::MigrateExpert {
                    layer,
                    expert,
                    src,
                    dst,
                    bytes: 40,
                },
                PlanOp::EvictExpert { layer, expert, dev: src },
            ]
        };
        let mut ops = vec![PlanOp::ZeroCopyReuse {
            dev: 0,
            tag: "embed".into(),
            bytes: 1000,
        }];
        ops.extend(e(0, 1, 0, 2));
        ops.extend(e(0, 5, 1, 2));
        ops.extend(e(1, 1, 0, 3));
        let p = ScalePlan {
            from_label: "x".into(),
            to_label: "y".into(),
            ops,
            ..Default::default()
        };
        assert_eq!(p.migrated_expert_count(), 3);
        assert_eq!(p.evicted_expert_count(), 3);
        assert_eq!(p.p2p_bytes(), 120);
        assert_eq!(p.reused_bytes(), 1000);
        assert_eq!(
            p.transfers(),
            vec![(0, 2, 40), (1, 2, 40), (0, 3, 40)]
        );
        assert!(p.migrations_have_matching_evictions());
        let rf = p.reuse_fraction();
        assert!((rf - 1000.0 / 1120.0).abs() < 1e-12);
    }
}
