//! MoE token routing across EP shards: dispatch bookkeeping, combine-weight
//! handling, and load-balance statistics for the real (PJRT) path.

use crate::device::DeviceId;

/// Routing decision for one decode/prefill batch: which tokens go to which
/// expert, derived from the gate's dense combine-weight matrix.
#[derive(Debug, Clone)]
pub struct Routing {
    pub n_tokens: usize,
    pub n_experts: usize,
    /// Per expert: indices of tokens routed to it.
    pub tokens_per_expert: Vec<Vec<usize>>,
}

impl Routing {
    /// Build routing from a dense `[T, E]` combine-weight matrix (nonzero =
    /// routed; the gate emits exactly top-k nonzeros per row).
    pub fn from_combine_weights(cw: &[f32], t: usize, e: usize) -> Self {
        assert_eq!(cw.len(), t * e);
        let mut tokens_per_expert = vec![Vec::new(); e];
        for ti in 0..t {
            for ei in 0..e {
                if cw[ti * e + ei] > 0.0 {
                    tokens_per_expert[ei].push(ti);
                }
            }
        }
        Routing {
            n_tokens: t,
            n_experts: e,
            tokens_per_expert,
        }
    }

    /// Experts that received at least one token (the set of expert-FFN
    /// executions this step needs).
    pub fn active_experts(&self) -> Vec<usize> {
        (0..self.n_experts)
            .filter(|&e| !self.tokens_per_expert[e].is_empty())
            .collect()
    }

    /// Token count per device given an owner map `expert -> device`.
    /// Debug-asserts that every routed token lands on a device in range;
    /// use [`Self::tokens_per_device_counted`] to observe out-of-range
    /// tokens instead of asserting.
    pub fn tokens_per_device(
        &self,
        owner: &dyn Fn(usize) -> DeviceId,
        n_devices: usize,
    ) -> Vec<usize> {
        let (counts, dropped) = self.tokens_per_device_counted(owner, n_devices);
        debug_assert_eq!(
            dropped, 0,
            "{dropped} tokens routed to devices >= {n_devices}"
        );
        counts
    }

    /// Like [`Self::tokens_per_device`], but returns `(counts, dropped)`
    /// where `dropped` tallies tokens whose owner device is `>= n_devices`
    /// (a stale owner map mid-reconfiguration) rather than silently
    /// skipping them.
    pub fn tokens_per_device_counted(
        &self,
        owner: &dyn Fn(usize) -> DeviceId,
        n_devices: usize,
    ) -> (Vec<usize>, usize) {
        let mut counts = vec![0usize; n_devices];
        let mut dropped = 0usize;
        for (e, toks) in self.tokens_per_expert.iter().enumerate() {
            if toks.is_empty() {
                continue;
            }
            let d = owner(e);
            if d < n_devices {
                counts[d] += toks.len();
            } else {
                dropped += toks.len();
            }
        }
        (counts, dropped)
    }

    /// Token count per device when experts may be replicated on several
    /// devices (`owners[e]` lists every owner of expert `e`): each token
    /// goes to the owner with the fewest tokens so far — the router's
    /// least-loaded-replica pick under hot-expert replication. Tokens of
    /// experts with no in-range owner are tallied as `dropped`.
    pub fn tokens_per_device_replicated(
        &self,
        owners: &[Vec<DeviceId>],
        n_devices: usize,
    ) -> (Vec<usize>, usize) {
        let mut counts = vec![0usize; n_devices];
        let mut dropped = 0usize;
        for (e, toks) in self.tokens_per_expert.iter().enumerate() {
            if toks.is_empty() {
                continue;
            }
            let valid: Vec<DeviceId> = owners
                .get(e)
                .map(|v| {
                    v.iter().copied().filter(|&d| d < n_devices).collect()
                })
                .unwrap_or_default();
            if valid.is_empty() {
                dropped += toks.len();
                continue;
            }
            for _ in toks {
                let &d = valid
                    .iter()
                    .min_by_key(|&&d| (counts[d], d))
                    .unwrap();
                counts[d] += 1;
            }
        }
        (counts, dropped)
    }

    /// Load-balance factor: max/mean token load across devices (1.0 =
    /// perfectly balanced; the paper's L4 concerns this degrading when
    /// experts can't be redistributed).
    pub fn imbalance(
        &self,
        owner: &dyn Fn(usize) -> DeviceId,
        n_devices: usize,
    ) -> f64 {
        let counts = self.tokens_per_device(owner, n_devices);
        let total: usize = counts.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / n_devices as f64;
        let max = *counts.iter().max().unwrap() as f64;
        max / mean
    }
}

/// Accumulate the weighted expert output into the residual stream:
/// `x[t] += cw[t] * y[t]` over rows of width `d`.
pub fn combine_into(x: &mut [f32], y: &[f32], cw_col: &[f32], d: usize) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), cw_col.len() * d);
    for (t, &w) in cw_col.iter().enumerate() {
        if w == 0.0 {
            continue;
        }
        let row = t * d;
        for i in 0..d {
            x[row + i] += w * y[row + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_from_cw() {
        // 3 tokens, 4 experts, top-2 each.
        #[rustfmt::skip]
        let cw = vec![
            0.5, 0.5, 0.0, 0.0,
            0.0, 0.3, 0.7, 0.0,
            0.9, 0.0, 0.0, 0.1,
        ];
        let r = Routing::from_combine_weights(&cw, 3, 4);
        assert_eq!(r.tokens_per_expert[0], vec![0, 2]);
        assert_eq!(r.tokens_per_expert[1], vec![0, 1]);
        assert_eq!(r.tokens_per_expert[2], vec![1]);
        assert_eq!(r.tokens_per_expert[3], vec![2]);
        assert_eq!(r.active_experts(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn device_load_and_imbalance() {
        let cw = vec![
            1.0, 0.0, 0.0, 0.0,
            1.0, 0.0, 0.0, 0.0,
            1.0, 0.0, 0.0, 0.0,
            0.0, 0.0, 0.0, 1.0,
        ];
        let r = Routing::from_combine_weights(&cw, 4, 4);
        // Experts 0,1 on device 0; experts 2,3 on device 1.
        let owner = |e: usize| e / 2;
        let counts = r.tokens_per_device(&owner, 2);
        assert_eq!(counts, vec![3, 1]);
        assert_eq!(r.imbalance(&owner, 2), 1.5);
    }

    #[test]
    fn combine_accumulates_weighted_rows() {
        let d = 2;
        let mut x = vec![1.0, 1.0, 2.0, 2.0];
        let y = vec![10.0, 10.0, 10.0, 10.0];
        combine_into(&mut x, &y, &[0.5, 0.0], d);
        assert_eq!(x, vec![6.0, 6.0, 2.0, 2.0]);
    }

    #[test]
    fn empty_routing_is_balanced() {
        let r = Routing::from_combine_weights(&[], 0, 4);
        assert_eq!(r.imbalance(&|e| e, 4), 1.0);
    }

    #[test]
    fn out_of_range_owners_are_counted_not_dropped() {
        let cw = vec![
            1.0, 0.0, 0.0, 0.0,
            0.0, 1.0, 0.0, 0.0,
            0.0, 0.0, 1.0, 0.0,
        ];
        let r = Routing::from_combine_weights(&cw, 3, 4);
        // Expert 2's owner points past the device set (stale map).
        let owner = |e: usize| if e == 2 { 7 } else { 0 };
        let (counts, dropped) = r.tokens_per_device_counted(&owner, 2);
        assert_eq!(counts, vec![2, 0]);
        assert_eq!(dropped, 1);
        // In-range maps report zero dropped.
        let (_, ok) = r.tokens_per_device_counted(&|_| 1, 2);
        assert_eq!(ok, 0);
    }

    #[test]
    #[should_panic(expected = "routed to devices")]
    #[cfg(debug_assertions)]
    fn tokens_per_device_asserts_in_range_owners() {
        let cw = vec![1.0, 0.0];
        let r = Routing::from_combine_weights(&cw, 1, 2);
        let _ = r.tokens_per_device(&|_| 9, 2);
    }

    #[test]
    fn replicated_owners_split_tokens_to_least_loaded() {
        // 6 tokens all on expert 0, which is owned by devices 0 and 1;
        // expert 1's single token goes to device 2.
        let mut tokens_per_expert = vec![Vec::new(); 2];
        tokens_per_expert[0] = (0..6).collect();
        tokens_per_expert[1] = vec![6];
        let r = Routing {
            n_tokens: 7,
            n_experts: 2,
            tokens_per_expert,
        };
        let owners = vec![vec![0, 1], vec![2]];
        let (counts, dropped) = r.tokens_per_device_replicated(&owners, 3);
        assert_eq!(counts, vec![3, 3, 1]);
        assert_eq!(dropped, 0);
        // An expert with no in-range owner drops its tokens into the tally.
        let owners = vec![vec![0, 1], vec![9]];
        let (counts, dropped) = r.tokens_per_device_replicated(&owners, 3);
        assert_eq!(counts, vec![3, 3, 0]);
        assert_eq!(dropped, 1);
    }
}
