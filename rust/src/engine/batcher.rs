//! Continuous batcher: admission from the waiting queue into the running
//! batch under KV and batch-size limits, prefill-first scheduling (vLLM
//! default), and pause/resume around scaling transitions (the paper's
//! "active instance pauses intake of new requests" during scale-up).
//!
//! # Scheduling states and transition windows
//!
//! A request moves `waiting -> running -> (finished | suspended)`:
//!
//! - **waiting** — enqueued, no KV held. Admission is FIFO, gated by
//!   [`BatcherConfig::max_batch`], the per-iteration prefill-token cap,
//!   KV availability, and the intake gate.
//! - **running** — KV admitted; prefilling or decoding every iteration.
//! - **suspended** — decode paused with KV still resident: the sequence
//!   is mid-handoff across a scaling event (its blocks are in flight to
//!   the successor's owner device). Suspended sequences are invisible to
//!   [`Batcher::next_work`] but count as live work; they are drained with
//!   the running set at switchover and resume on the successor.
//!
//! Two independent gates exist during scaling transitions:
//! [`Batcher::pause_intake`] closes *admission* (the paper's intake-pause
//! window — in-flight work keeps decoding), while [`Batcher::suspend`]
//! freezes *individual sequences* (the KV-handoff window). The two
//! compose: a sequence is either drained once at switchover or migrated
//! once, never both — see `rust/tests/integration.rs`.

use std::collections::VecDeque;

use crate::workload::{Request, RequestId, RequestState};

use super::kv_cache::PagedKv;

/// Batcher policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Maximum concurrent sequences.
    pub max_batch: usize,
    /// Maximum prompt tokens prefilled in one iteration.
    pub max_prefill_tokens: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64,
            max_prefill_tokens: 8192,
        }
    }
}

/// What the engine should execute next.
#[derive(Debug, PartialEq, Eq)]
pub enum NextWork {
    /// Prefill these newly admitted requests.
    Prefill(Vec<RequestId>),
    /// Run one decode step over the running batch of `batch` sequences.
    /// Carries only the batch size — the engine iterates the running set
    /// in place, so the scheduling hot path stays allocation-free (the
    /// old form cloned every running id into a fresh `Vec` per step).
    Decode { batch: usize },
    /// Nothing runnable.
    Idle,
}

/// The continuous batcher.
#[derive(Debug)]
pub struct Batcher {
    pub cfg: BatcherConfig,
    waiting: VecDeque<Request>,
    running: Vec<Request>,
    /// Sequences frozen mid-decode for KV handoff (blocks still held).
    suspended: Vec<Request>,
    /// Intake paused (during scale transitions).
    paused: bool,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher {
            cfg,
            waiting: VecDeque::new(),
            running: Vec::new(),
            suspended: Vec::new(),
            paused: false,
        }
    }

    /// Enqueue an arriving request.
    pub fn enqueue(&mut self, r: Request) {
        self.waiting.push_back(r);
    }

    /// Pause new admissions (scale-while-serve transition).
    pub fn pause_intake(&mut self) {
        self.paused = true;
    }
    pub fn resume_intake(&mut self) {
        self.paused = false;
    }
    pub fn intake_paused(&self) -> bool {
        self.paused
    }

    /// Decide the next work item: admit + prefill waiting requests if
    /// possible, otherwise decode the running batch.
    pub fn next_work(&mut self, kv: &mut PagedKv) -> NextWork {
        // Admission: FIFO while capacity allows.
        let mut admitted = Vec::new();
        let mut prefill_tokens = 0;
        while !self.paused
            && self.running.len() + admitted.len() < self.cfg.max_batch
        {
            let Some(front) = self.waiting.front() else { break };
            let need_tokens = front.prompt_len;
            if prefill_tokens + need_tokens > self.cfg.max_prefill_tokens
                && !admitted.is_empty()
            {
                break;
            }
            if !kv.can_admit(front.total_tokens()) {
                break;
            }
            let mut r = self.waiting.pop_front().unwrap();
            kv.admit(r.id, r.prompt_len).expect("checked can_admit");
            r.state = RequestState::Prefilling;
            prefill_tokens += r.prompt_len;
            admitted.push(r);
        }
        if !admitted.is_empty() {
            let ids: Vec<RequestId> = admitted.iter().map(|r| r.id).collect();
            self.running.extend(admitted);
            return NextWork::Prefill(ids);
        }
        if !self.running.is_empty() {
            return NextWork::Decode {
                batch: self.running.len(),
            };
        }
        NextWork::Idle
    }

    /// Requests currently running (mutable, for the backend to update).
    pub fn running_mut(&mut self) -> &mut [Request] {
        &mut self.running
    }

    /// Requests currently running (read-only view).
    pub fn running(&self) -> &[Request] {
        &self.running
    }

    /// Remove finished requests from the running batch, releasing KV.
    pub fn reap_finished(&mut self, kv: &mut PagedKv) -> Vec<Request> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].is_done() {
                let r = self.running.swap_remove(i);
                kv.release(r.id);
                done.push(r);
            } else {
                i += 1;
            }
        }
        done
    }

    /// Freeze decode for the given running sequences while their KV blocks
    /// are handed off across a scaling event. Their KV stays admitted (the
    /// successor adopts it); they simply stop appearing in
    /// [`Self::next_work`] until drained at switchover — or resumed by
    /// [`Self::resume_suspended`] when the event aborts. Returns the ids
    /// actually suspended (ids not in the running batch — or already
    /// suspended — are ignored).
    pub fn suspend(&mut self, ids: &[RequestId]) -> Vec<RequestId> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.running.len() {
            if ids.contains(&self.running[i].id) {
                let mut r = self.running.swap_remove(i);
                r.state = RequestState::Suspended;
                out.push(r.id);
                self.suspended.push(r);
            } else {
                i += 1;
            }
        }
        out
    }

    /// Resume every suspended sequence back into the running batch with
    /// its decode progress intact — the path taken when a scaling event
    /// aborts and rolls back: the handoff was abandoned, the blocks never
    /// left this engine, and decode simply continues on the origin
    /// replica. Returns the resumed ids.
    pub fn resume_suspended(&mut self) -> Vec<RequestId> {
        let mut out = Vec::new();
        for mut r in std::mem::take(&mut self.suspended) {
            r.state = RequestState::Decoding;
            out.push(r.id);
            self.running.push(r);
        }
        out
    }

    /// Sequences currently frozen for KV handoff.
    pub fn suspended_len(&self) -> usize {
        self.suspended.len()
    }

    /// Frozen sequences (read-only view).
    pub fn suspended(&self) -> &[Request] {
        &self.suspended
    }

    /// Drain: take every in-flight request out — running *and* suspended —
    /// for migration to a new instance or teardown. KV is released here
    /// (the successor's pool re-admits adopted sequences; zero-copy reuse
    /// is modelled by keeping their decode progress, see
    /// [`crate::kvmigrate`]).
    pub fn take_all_running(&mut self, kv: &mut PagedKv) -> Vec<Request> {
        for r in self.running.iter().chain(self.suspended.iter()) {
            kv.release(r.id);
        }
        let mut all = std::mem::take(&mut self.running);
        all.extend(std::mem::take(&mut self.suspended));
        all
    }

    /// Take all queued (not yet admitted) requests.
    pub fn take_waiting(&mut self) -> Vec<Request> {
        self.waiting.drain(..).collect()
    }

    /// Extract every freshly decoded sequence from the running batch,
    /// releasing its KV here — the prefill→decode handoff of a
    /// disaggregated fleet: a prefill replica keeps nothing past the
    /// first token, and the decode replica re-admits each sequence's KV
    /// when the transfer leg delivers (see
    /// [`crate::coordinator::fleet`]). Prefilling sequences stay put
    /// (their handoff point is the end of their prefill step).
    pub fn take_decoding(&mut self, kv: &mut PagedKv) -> Vec<Request> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].state == RequestState::Decoding {
                let r = self.running.swap_remove(i);
                kv.release(r.id);
                out.push(r);
            } else {
                i += 1;
            }
        }
        // The extraction order must not depend on swap_remove's
        // permutation: downstream handoff planning iterates this list.
        out.sort_by_key(|r| r.id);
        out
    }

    /// Adopt an in-flight request directly into the running batch with its
    /// decode progress intact (switchover with zero-copy KV reuse). The
    /// caller must have admitted its KV already.
    pub fn adopt_running(&mut self, r: Request) {
        debug_assert_eq!(r.state, RequestState::Decoding);
        self.running.push(r);
    }

    /// Requests waiting for admission.
    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }
    /// Requests in the running batch (excludes suspended).
    pub fn running_len(&self) -> usize {
        self.running.len()
    }
    /// No work anywhere: empty queue, empty batch, nothing suspended.
    /// Suspended sequences count as live work — they are waiting on a
    /// switchover, not finished.
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty()
            && self.running.is_empty()
            && self.suspended.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, decode: usize) -> Request {
        Request::new(id, 0.0, prompt, decode)
    }

    fn setup(max_batch: usize) -> (Batcher, PagedKv) {
        (
            Batcher::new(BatcherConfig {
                max_batch,
                max_prefill_tokens: 4096,
            }),
            PagedKv::new(1000, 16),
        )
    }

    #[test]
    fn admits_fifo_until_batch_full() {
        let (mut b, mut kv) = setup(2);
        for i in 1..=3 {
            b.enqueue(req(i, 100, 10));
        }
        match b.next_work(&mut kv) {
            NextWork::Prefill(ids) => assert_eq!(ids, vec![1, 2]),
            w => panic!("expected prefill, got {w:?}"),
        }
        assert_eq!(b.queue_len(), 1);
        // Next iteration decodes the running batch (no capacity to admit).
        match b.next_work(&mut kv) {
            NextWork::Decode { batch } => assert_eq!(batch, 2),
            w => panic!("expected decode, got {w:?}"),
        }
    }

    #[test]
    fn kv_pressure_blocks_admission() {
        let (mut b, _) = setup(8);
        let mut kv = PagedKv::new(10, 16); // 160 tokens
        b.enqueue(req(1, 100, 20)); // needs 120 total
        b.enqueue(req(2, 100, 20));
        match b.next_work(&mut kv) {
            NextWork::Prefill(ids) => assert_eq!(ids, vec![1]),
            w => panic!("{w:?}"),
        }
        // Second stays queued until blocks free up.
        assert_eq!(b.queue_len(), 1);
    }

    #[test]
    fn paused_intake_decodes_only() {
        let (mut b, mut kv) = setup(8);
        b.enqueue(req(1, 50, 5));
        assert!(matches!(b.next_work(&mut kv), NextWork::Prefill(_)));
        b.enqueue(req(2, 50, 5));
        b.pause_intake();
        assert!(matches!(b.next_work(&mut kv), NextWork::Decode { .. }));
        b.resume_intake();
        assert!(matches!(b.next_work(&mut kv), NextWork::Prefill(_)));
    }

    #[test]
    fn reap_releases_kv() {
        let (mut b, mut kv) = setup(8);
        b.enqueue(req(1, 50, 5));
        b.next_work(&mut kv);
        let used = kv.used_blocks();
        assert!(used > 0);
        b.running_mut()[0].state = RequestState::Finished;
        let done = b.reap_finished(&mut kv);
        assert_eq!(done.len(), 1);
        assert_eq!(kv.used_blocks(), 0);
        assert!(b.is_idle());
    }

    #[test]
    fn drain_takes_everything() {
        let (mut b, mut kv) = setup(8);
        b.enqueue(req(1, 50, 5));
        b.enqueue(req(2, 50, 5));
        b.next_work(&mut kv);
        b.enqueue(req(3, 50, 5));
        let running = b.take_all_running(&mut kv);
        let waiting = b.take_waiting();
        assert_eq!(running.len(), 2);
        assert_eq!(waiting.len(), 1);
        assert_eq!(kv.used_blocks(), 0);
    }

    #[test]
    fn idle_when_empty() {
        let (mut b, mut kv) = setup(4);
        assert_eq!(b.next_work(&mut kv), NextWork::Idle);
    }

    #[test]
    fn suspended_sequences_keep_kv_and_drain_with_running() {
        let (mut b, mut kv) = setup(8);
        b.enqueue(req(1, 50, 5));
        b.enqueue(req(2, 50, 5));
        b.next_work(&mut kv); // both admitted
        let used = kv.used_blocks();
        assert_eq!(b.suspend(&[2, 99]), vec![2]); // unknown ids ignored
        assert_eq!(b.suspended_len(), 1);
        assert_eq!(b.suspended()[0].state, RequestState::Suspended);
        // KV stays admitted while suspended.
        assert_eq!(kv.used_blocks(), used);
        // Suspended sequences are invisible to scheduling...
        match b.next_work(&mut kv) {
            NextWork::Decode { batch } => {
                assert_eq!(batch, 1);
                assert_eq!(b.running()[0].id, 1);
            }
            w => panic!("{w:?}"),
        }
        // ...but count as live work.
        assert!(!b.is_idle());
        // Drain returns running + suspended exactly once each.
        let all = b.take_all_running(&mut kv);
        assert_eq!(all.len(), 2);
        assert_eq!(kv.used_blocks(), 0);
        assert!(b.is_idle());
    }

    #[test]
    fn resume_suspended_restores_decode_with_progress() {
        let (mut b, mut kv) = setup(8);
        b.enqueue(req(1, 50, 5));
        b.enqueue(req(2, 50, 5));
        b.next_work(&mut kv); // both admitted (Prefilling)
        for r in b.running_mut() {
            r.state = RequestState::Decoding;
            r.generated = 3;
        }
        let used = kv.used_blocks();
        assert_eq!(b.suspend(&[1, 2]).len(), 2);
        // Abort path: everything comes back, KV untouched, progress kept.
        let mut resumed = b.resume_suspended();
        resumed.sort_unstable();
        assert_eq!(resumed, vec![1, 2]);
        assert_eq!(b.suspended_len(), 0);
        assert_eq!(b.running_len(), 2);
        assert_eq!(kv.used_blocks(), used);
        for r in b.running() {
            assert_eq!(r.state, RequestState::Decoding);
            assert_eq!(r.generated, 3);
        }
        // Nothing left to resume.
        assert!(b.resume_suspended().is_empty());
    }
}
