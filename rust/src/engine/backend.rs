//! Execution backends: the engine is generic over *what executes a step* —
//! the roofline cost model (simulation experiments) or real PJRT forward
//! passes (end-to-end example). Both advance the same batcher/KV/metrics
//! machinery, so every experiment exercises the production control path.

use anyhow::Result;

use crate::config::ParallelConfig;
use crate::workload::{Request, RequestState};

use super::cost_model::CostModel;

/// What kind of step was executed (telemetry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    Prefill,
    Decode,
    Idle,
}

/// A step executor.
pub trait ExecBackend {
    /// Run prefill over the requests currently in `Prefilling` state within
    /// `running`; returns elapsed seconds. Real backends also compute the
    /// first token for each prefilled request (`output_ids`).
    fn prefill(&mut self, running: &mut [Request]) -> Result<f64>;

    /// Run one decode iteration over all `Decoding` requests; returns
    /// elapsed seconds. Real backends append one token per request.
    fn decode(&mut self, running: &mut [Request]) -> Result<f64>;

    /// The parallel layout this backend executes under.
    fn parallel(&self) -> &ParallelConfig;

    /// Throughput derating during scaling transitions (colocated baseline
    /// runs with reduced KV; see `set_derate`). 1.0 = full speed.
    fn set_derate(&mut self, factor: f64);

    /// Downcast hook (the live path rebinds a [`super::pjrt::PjrtBackend`]
    /// after scaling).
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;
}

/// Simulation backend: charges roofline-model time, produces no tokens.
#[derive(Debug, Clone)]
pub struct CostModelBackend {
    pub cost: CostModel,
    pub parallel: ParallelConfig,
    derate: f64,
}

impl CostModelBackend {
    pub fn new(cost: CostModel, parallel: ParallelConfig) -> Self {
        CostModelBackend {
            cost,
            parallel,
            derate: 1.0,
        }
    }
}

impl ExecBackend for CostModelBackend {
    fn prefill(&mut self, running: &mut [Request]) -> Result<f64> {
        let tokens: usize = running
            .iter()
            .filter(|r| r.state == RequestState::Prefilling)
            .map(|r| r.prompt_len)
            .sum();
        if tokens == 0 {
            return Ok(0.0);
        }
        Ok(self.cost.prefill_time(&self.parallel, tokens) / self.derate)
    }

    fn decode(&mut self, running: &mut [Request]) -> Result<f64> {
        let batch = running
            .iter()
            .filter(|r| r.state == RequestState::Decoding)
            .count();
        Ok(self.cost.decode_step_time(&self.parallel, batch) / self.derate)
    }

    fn parallel(&self) -> &ParallelConfig {
        &self.parallel
    }

    fn set_derate(&mut self, factor: f64) {
        self.derate = factor.clamp(0.05, 1.0);
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;
    use crate::device::Timings;

    fn backend() -> CostModelBackend {
        let p = ParallelConfig::standard(2, 2, (0..4).collect()).unwrap();
        CostModelBackend::new(
            CostModel::new(dsv2_lite(), Timings::cloudmatrix()),
            p,
        )
    }

    fn reqs(n: usize, state: RequestState) -> Vec<Request> {
        (0..n)
            .map(|i| {
                let mut r = Request::new(i as u64, 0.0, 500, 100);
                r.state = state;
                r
            })
            .collect()
    }

    #[test]
    fn prefill_time_scales_with_tokens() {
        let mut b = backend();
        let mut one = reqs(1, RequestState::Prefilling);
        let mut four = reqs(4, RequestState::Prefilling);
        let t1 = b.prefill(&mut one).unwrap();
        let t4 = b.prefill(&mut four).unwrap();
        assert!(t4 > t1 * 3.0, "t1={t1} t4={t4}");
    }

    #[test]
    fn decode_only_counts_decoding() {
        let mut b = backend();
        let mut mixed = reqs(4, RequestState::Decoding);
        mixed.extend(reqs(4, RequestState::Prefilling));
        let t_mixed = b.decode(&mut mixed).unwrap();
        let mut four = reqs(4, RequestState::Decoding);
        let t4 = b.decode(&mut four).unwrap();
        assert!((t_mixed - t4).abs() < 1e-12);
    }

    #[test]
    fn derate_slows_steps() {
        let mut b = backend();
        let mut batch = reqs(8, RequestState::Decoding);
        let t_full = b.decode(&mut batch).unwrap();
        b.set_derate(0.5);
        let t_half = b.decode(&mut batch).unwrap();
        assert!((t_half - 2.0 * t_full).abs() < 1e-9);
    }

    #[test]
    fn empty_steps_are_free() {
        let mut b = backend();
        assert_eq!(b.prefill(&mut []).unwrap(), 0.0);
        assert_eq!(b.decode(&mut []).unwrap(), 0.0);
    }
}
