//! The serving engine: continuous batching over a paged KV cache, with MoE
//! token routing across EP shards, generic over the execution backend —
//! [`backend::CostModelBackend`] (roofline-timed simulation, used by the
//! paper experiments) or [`pjrt::PjrtBackend`] (real forward passes through
//! the AOT artifacts, used by the end-to-end example).
//!
//! All five scaling methods serve through this same engine, mirroring the
//! paper's all-baselines-on-vLLM methodology.

pub mod backend;
pub mod batcher;
pub mod cost_model;
pub mod kv_cache;
pub mod moe;
pub mod pjrt;
pub mod serve;

pub use backend::{CostModelBackend, ExecBackend, StepKind};
pub use batcher::{Batcher, BatcherConfig};
pub use cost_model::CostModel;
pub use kv_cache::PagedKv;
pub use serve::{ServeEngine, StepOutcome};
