//! Paged KV-cache allocator (vLLM-style block tables) — admission control
//! for the continuous batcher and the unit of KV accounting.
//!
//! # Block accounting invariants
//!
//! - The pool holds exactly [`PagedKv::total_blocks`] blocks at all times:
//!   `free_blocks() + used_blocks() == total_blocks()` after every
//!   operation, including failed ones (exhaustion is an error, never a
//!   leak — see `exhaustion_is_an_error_not_corruption`).
//! - A request owns `ceil(len / block_tokens)` blocks, where `len` is its
//!   current sequence length ([`PagedKv::seq_len`]); growth claims at most
//!   one block per appended token.
//! - [`PagedKv::can_admit`] agrees with [`PagedKv::admit`]: whenever
//!   `can_admit(tokens)` is true, an `admit` for `tokens` succeeds
//!   (property-tested in `rust/tests/properties.rs`).
//!
//! Per-request block tables are the migratable unit of the KV-handoff
//! subsystem ([`crate::kvmigrate`]): a scaling event snapshots them via
//! [`PagedKv::sequences`] and classifies each table as remap / p2p-copy /
//! recompute.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::workload::RequestId;

/// Paged KV allocator over a fixed block pool.
#[derive(Debug, Clone)]
pub struct PagedKv {
    block_tokens: usize,
    n_blocks: usize,
    free: Vec<usize>,
    tables: HashMap<RequestId, Vec<usize>>,
    /// Tokens currently stored per request.
    lens: HashMap<RequestId, usize>,
}

impl PagedKv {
    /// A pool of `n_blocks` blocks of `block_tokens` tokens each.
    pub fn new(n_blocks: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        PagedKv {
            block_tokens,
            n_blocks,
            free: (0..n_blocks).rev().collect(),
            tables: HashMap::new(),
            lens: HashMap::new(),
        }
    }

    /// Pool sized from a byte budget. Errors when the budget is smaller
    /// than a single block — a 0-block pool would silently reject every
    /// admission, which looks like livelock rather than misconfiguration.
    pub fn from_bytes(
        budget_bytes: u64,
        bytes_per_token: u64,
        block_tokens: usize,
    ) -> Result<Self> {
        let tokens = (budget_bytes / bytes_per_token.max(1)) as usize;
        let blocks = tokens / block_tokens.max(1);
        if blocks == 0 {
            bail!(
                "KV budget {budget_bytes} B holds less than one block \
                 ({block_tokens} tokens x {bytes_per_token} B/token)"
            );
        }
        Ok(PagedKv::new(blocks, block_tokens))
    }

    /// Blocks a sequence of `tokens` total tokens occupies.
    pub fn blocks_needed(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can a sequence of `tokens` total tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_needed(tokens) <= self.free.len()
    }

    /// Reserve blocks for a new sequence (prompt only; grows on decode).
    pub fn admit(&mut self, id: RequestId, prompt_tokens: usize) -> Result<()> {
        if self.tables.contains_key(&id) {
            bail!("request {id} already admitted");
        }
        let need = self.blocks_needed(prompt_tokens.max(1));
        if need > self.free.len() {
            bail!(
                "KV pool exhausted: need {need} blocks, {} free",
                self.free.len()
            );
        }
        let blocks: Vec<usize> =
            (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.tables.insert(id, blocks);
        self.lens.insert(id, prompt_tokens);
        Ok(())
    }

    /// Append one decoded token; may claim a new block. On exhaustion the
    /// length is rolled back and the request's state is unchanged.
    pub fn append_token(&mut self, id: RequestId) -> Result<()> {
        let len = self
            .lens
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("request {id} not admitted"))?;
        *len += 1;
        let need = len.div_ceil(self.block_tokens);
        let table = self.tables.get_mut(&id).unwrap();
        if need > table.len() {
            let Some(b) = self.free.pop() else {
                *self.lens.get_mut(&id).unwrap() -= 1;
                bail!("KV pool exhausted growing request {id}");
            };
            table.push(b);
        }
        Ok(())
    }

    /// Release a finished request's blocks. Idempotent: releasing an
    /// unknown or already-released id is a no-op.
    pub fn release(&mut self, id: RequestId) {
        if let Some(blocks) = self.tables.remove(&id) {
            self.free.extend(blocks);
        }
        self.lens.remove(&id);
    }

    /// Blocks currently free.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }
    /// Blocks currently held by admitted sequences.
    pub fn used_blocks(&self) -> usize {
        self.n_blocks - self.free.len()
    }
    /// Pool capacity in blocks.
    pub fn total_blocks(&self) -> usize {
        self.n_blocks
    }
    /// Sequences currently holding blocks.
    pub fn active_requests(&self) -> usize {
        self.tables.len()
    }
    /// Tokens per block (pool-wide constant).
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Current stored length of one sequence, `None` if not admitted.
    pub fn seq_len(&self, id: RequestId) -> Option<usize> {
        self.lens.get(&id).copied()
    }

    /// Blocks held by one sequence, `None` if not admitted.
    pub fn seq_blocks(&self, id: RequestId) -> Option<usize> {
        self.tables.get(&id).map(|t| t.len())
    }

    /// Every admitted sequence as `(id, tokens, blocks)`, sorted by id
    /// (deterministic — the underlying map is not). This is the snapshot
    /// the KV-migration planner consumes at a scaling event.
    pub fn sequences(&self) -> Vec<(RequestId, usize, usize)> {
        let mut v: Vec<(RequestId, usize, usize)> = self
            .tables
            .iter()
            .map(|(&id, blocks)| (id, self.lens[&id], blocks.len()))
            .collect();
        v.sort_unstable_by_key(|&(id, _, _)| id);
        v
    }

    /// Shrink the pool (colocated baseline pre-shrinks KV to fit two model
    /// copies). Fails if in-use blocks would be lost.
    pub fn resize(&mut self, new_blocks: usize) -> Result<()> {
        let used = self.used_blocks();
        if new_blocks < used {
            bail!("cannot shrink below {used} in-use blocks");
        }
        self.n_blocks = new_blocks;
        let free_target = new_blocks - used;
        // Rebuild the free list with fresh ids (identity of free blocks is
        // immaterial).
        self.free = (0..free_target).rev().collect();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_grow_release() {
        let mut kv = PagedKv::new(10, 16);
        kv.admit(1, 100).unwrap(); // 7 blocks
        assert_eq!(kv.used_blocks(), 7);
        assert!(kv.can_admit(48));
        assert!(!kv.can_admit(64));

        // 100 -> 112 tokens fits in 7 blocks; 113 takes an 8th.
        for _ in 0..12 {
            kv.append_token(1).unwrap();
        }
        assert_eq!(kv.used_blocks(), 7);
        kv.append_token(1).unwrap();
        assert_eq!(kv.used_blocks(), 8);

        kv.release(1);
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(kv.free_blocks(), 10);
    }

    #[test]
    fn exhaustion_is_an_error_not_corruption() {
        let mut kv = PagedKv::new(2, 4);
        kv.admit(1, 8).unwrap();
        assert!(kv.admit(2, 4).is_err());
        assert!(kv.append_token(1).is_err());
        // State unchanged after failures.
        assert_eq!(kv.used_blocks(), 2);
        kv.release(1);
        kv.admit(2, 4).unwrap();
    }

    #[test]
    fn double_admit_rejected() {
        let mut kv = PagedKv::new(4, 4);
        kv.admit(1, 4).unwrap();
        assert!(kv.admit(1, 4).is_err());
    }

    #[test]
    fn from_bytes_sizing() {
        // 1 GB at 1 KB/token, 16-token blocks -> 65536 blocks.
        let kv = PagedKv::from_bytes(1 << 30, 1024, 16).unwrap();
        assert_eq!(kv.total_blocks(), 65536);
    }

    #[test]
    fn from_bytes_rejects_sub_block_budget() {
        // 16-token blocks at 1 KB/token need 16 KB; 15 KB holds none.
        assert!(PagedKv::from_bytes(15 << 10, 1024, 16).is_err());
        // Exactly one block is fine.
        let kv = PagedKv::from_bytes(16 << 10, 1024, 16).unwrap();
        assert_eq!(kv.total_blocks(), 1);
        // Zero budget is an error, not a 0-block pool.
        assert!(PagedKv::from_bytes(0, 1024, 16).is_err());
    }

    #[test]
    fn sequences_snapshot_is_sorted_and_exact() {
        let mut kv = PagedKv::new(100, 16);
        kv.admit(9, 40).unwrap(); // 3 blocks
        kv.admit(2, 16).unwrap(); // 1 block
        kv.admit(5, 17).unwrap(); // 2 blocks
        kv.append_token(2).unwrap(); // 17 tokens -> 2 blocks
        let seqs = kv.sequences();
        assert_eq!(seqs, vec![(2, 17, 2), (5, 17, 2), (9, 40, 3)]);
        assert_eq!(kv.seq_len(5), Some(17));
        assert_eq!(kv.seq_blocks(9), Some(3));
        assert_eq!(kv.seq_len(99), None);
        let total: usize = seqs.iter().map(|&(_, _, b)| b).sum();
        assert_eq!(total, kv.used_blocks());
    }

    #[test]
    fn resize_preserves_in_use() {
        let mut kv = PagedKv::new(10, 4);
        kv.admit(1, 16).unwrap(); // 4 blocks
        kv.resize(6).unwrap();
        assert_eq!(kv.free_blocks(), 2);
        assert!(kv.resize(3).is_err());
    }
}
