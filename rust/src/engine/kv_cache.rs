//! Paged KV-cache allocator (vLLM-style block tables) — admission control
//! for the continuous batcher and the unit of KV accounting.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::workload::RequestId;

/// Paged KV allocator over a fixed block pool.
#[derive(Debug, Clone)]
pub struct PagedKv {
    block_tokens: usize,
    n_blocks: usize,
    free: Vec<usize>,
    tables: HashMap<RequestId, Vec<usize>>,
    /// Tokens currently stored per request.
    lens: HashMap<RequestId, usize>,
}

impl PagedKv {
    /// A pool of `n_blocks` blocks of `block_tokens` tokens each.
    pub fn new(n_blocks: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        PagedKv {
            block_tokens,
            n_blocks,
            free: (0..n_blocks).rev().collect(),
            tables: HashMap::new(),
            lens: HashMap::new(),
        }
    }

    /// Pool sized from a byte budget.
    pub fn from_bytes(
        budget_bytes: u64,
        bytes_per_token: u64,
        block_tokens: usize,
    ) -> Self {
        let tokens = (budget_bytes / bytes_per_token.max(1)) as usize;
        PagedKv::new(tokens / block_tokens.max(1), block_tokens)
    }

    pub fn blocks_needed(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can a sequence of `tokens` total tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_needed(tokens) <= self.free.len()
    }

    /// Reserve blocks for a new sequence (prompt only; grows on decode).
    pub fn admit(&mut self, id: RequestId, prompt_tokens: usize) -> Result<()> {
        if self.tables.contains_key(&id) {
            bail!("request {id} already admitted");
        }
        let need = self.blocks_needed(prompt_tokens.max(1));
        if need > self.free.len() {
            bail!(
                "KV pool exhausted: need {need} blocks, {} free",
                self.free.len()
            );
        }
        let blocks: Vec<usize> =
            (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.tables.insert(id, blocks);
        self.lens.insert(id, prompt_tokens);
        Ok(())
    }

    /// Append one decoded token; may claim a new block.
    pub fn append_token(&mut self, id: RequestId) -> Result<()> {
        let len = self
            .lens
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("request {id} not admitted"))?;
        *len += 1;
        let need = len.div_ceil(self.block_tokens);
        let table = self.tables.get_mut(&id).unwrap();
        if need > table.len() {
            let Some(b) = self.free.pop() else {
                *self.lens.get_mut(&id).unwrap() -= 1;
                bail!("KV pool exhausted growing request {id}");
            };
            table.push(b);
        }
        Ok(())
    }

    /// Release a finished request's blocks.
    pub fn release(&mut self, id: RequestId) {
        if let Some(blocks) = self.tables.remove(&id) {
            self.free.extend(blocks);
        }
        self.lens.remove(&id);
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }
    pub fn used_blocks(&self) -> usize {
        self.n_blocks - self.free.len()
    }
    pub fn total_blocks(&self) -> usize {
        self.n_blocks
    }
    pub fn active_requests(&self) -> usize {
        self.tables.len()
    }

    /// Shrink the pool (colocated baseline pre-shrinks KV to fit two model
    /// copies). Fails if in-use blocks would be lost.
    pub fn resize(&mut self, new_blocks: usize) -> Result<()> {
        let used = self.used_blocks();
        if new_blocks < used {
            bail!("cannot shrink below {used} in-use blocks");
        }
        self.n_blocks = new_blocks;
        let free_target = new_blocks - used;
        // Rebuild the free list with fresh ids (identity of free blocks is
        // immaterial).
        self.free = (0..free_target).rev().collect();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_grow_release() {
        let mut kv = PagedKv::new(10, 16);
        kv.admit(1, 100).unwrap(); // 7 blocks
        assert_eq!(kv.used_blocks(), 7);
        assert!(kv.can_admit(48));
        assert!(!kv.can_admit(64));

        // 100 -> 112 tokens fits in 7 blocks; 113 takes an 8th.
        for _ in 0..12 {
            kv.append_token(1).unwrap();
        }
        assert_eq!(kv.used_blocks(), 7);
        kv.append_token(1).unwrap();
        assert_eq!(kv.used_blocks(), 8);

        kv.release(1);
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(kv.free_blocks(), 10);
    }

    #[test]
    fn exhaustion_is_an_error_not_corruption() {
        let mut kv = PagedKv::new(2, 4);
        kv.admit(1, 8).unwrap();
        assert!(kv.admit(2, 4).is_err());
        assert!(kv.append_token(1).is_err());
        // State unchanged after failures.
        assert_eq!(kv.used_blocks(), 2);
        kv.release(1);
        kv.admit(2, 4).unwrap();
    }

    #[test]
    fn double_admit_rejected() {
        let mut kv = PagedKv::new(4, 4);
        kv.admit(1, 4).unwrap();
        assert!(kv.admit(1, 4).is_err());
    }

    #[test]
    fn from_bytes_sizing() {
        // 1 GB at 1 KB/token, 16-token blocks -> 65536 blocks.
        let kv = PagedKv::from_bytes(1 << 30, 1024, 16);
        assert_eq!(kv.total_blocks(), 65536);
    }

    #[test]
    fn resize_preserves_in_use() {
        let mut kv = PagedKv::new(10, 4);
        kv.admit(1, 16).unwrap(); // 4 blocks
        kv.resize(6).unwrap();
        assert_eq!(kv.free_blocks(), 2);
        assert!(kv.resize(3).is_err());
    }
}
