//! Real execution backend: drives the AOT-compiled artifacts through PJRT,
//! composing the per-layer attention prefix with per-expert FFN executables
//! exactly the way the golden trace does. Expert weights are fetched from
//! whichever simulated device currently owns them (via the instance's
//! binding snapshot), so EP migrations are exercised with live numerics.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ParallelConfig;
use crate::device::{DeviceId, RegionId};
use crate::hmm::control::{HmmControl, InstanceBinding};
use crate::runtime::{HostTensor, ModelDims, Pjrt};
use crate::workload::{Request, RequestId, RequestState};

use super::backend::ExecBackend;
use super::moe::{combine_into, Routing};

/// Per-step EP routing statistics (live load-balance telemetry).
#[derive(Debug, Clone, Default)]
pub struct EpStats {
    pub steps: u64,
    pub tokens_dispatched: u64,
    pub max_imbalance: f64,
}

/// The live backend.
pub struct PjrtBackend {
    rt: Rc<Pjrt>,
    hmm: Rc<RefCell<HmmControl>>,
    binding: InstanceBinding,
    parallel: ParallelConfig,
    md: ModelDims,
    /// (dev, region) of the embedding payload `[emb, ln_f]`.
    embed_ref: (DeviceId, RegionId),
    /// Per layer: (dev, region) of `[ln1, wq, wk, wv, wo, ln2, w_gate]`.
    attn_refs: Vec<(DeviceId, RegionId)>,
    /// KV caches per layer: `[B, S, H, dh]`.
    kc: Vec<HostTensor>,
    vc: Vec<HostTensor>,
    /// Slot assignment: compiled batch row -> request.
    slots: Vec<Option<RequestId>>,
    /// Stored sequence length per slot (prompt + generated-so-far tokens
    /// whose KV is in the cache).
    lens: Vec<i32>,
    last_token: Vec<i32>,
    /// Layer index the current `moe_combine` call is operating on.
    layer_cursor: usize,
    /// Device-resident weight buffers, keyed by (device, region, tensor
    /// index). This is the real-path analogue of weights living in HBM:
    /// each payload is uploaded once per residency; migrations produce new
    /// regions and therefore fresh uploads (§Perf optimization P1).
    weight_bufs: HashMap<(DeviceId, RegionId, usize), Rc<xla::PjRtBuffer>>,
    pub ep_stats: EpStats,
}

impl PjrtBackend {
    pub fn new(
        rt: Rc<Pjrt>,
        hmm: Rc<RefCell<HmmControl>>,
        binding: InstanceBinding,
    ) -> Result<Self> {
        let md = rt.manifest().model.clone();
        let (b, s, h, dh) = (md.batch, md.max_seq, md.n_heads, md.head_dim);

        // Resolve the embedding + per-layer attention payload references
        // from the binding snapshot.
        let mut embed_ref = None;
        let mut attn_map: BTreeMap<usize, (DeviceId, RegionId)> =
            BTreeMap::new();
        for (&dev, tags) in &binding.attn_regions {
            for (tag, region) in tags {
                if tag.starts_with("embed.") && embed_ref.is_none() {
                    embed_ref = Some((dev, *region));
                } else if let Some(layer) = parse_attn_tag(tag) {
                    attn_map.entry(layer).or_insert((dev, *region));
                }
            }
        }
        let embed_ref = embed_ref.context("binding has no embed unit")?;
        let attn_refs: Vec<(DeviceId, RegionId)> = (0..md.n_layers)
            .map(|l| {
                attn_map
                    .get(&l)
                    .copied()
                    .with_context(|| format!("binding missing attn layer {l}"))
            })
            .collect::<Result<_>>()?;
        if binding.expert_map.len() != md.n_layers {
            bail!("binding expert map layers != model layers");
        }

        let parallel = binding.parallel.clone();
        Ok(PjrtBackend {
            rt,
            hmm,
            binding,
            parallel,
            kc: (0..md.n_layers)
                .map(|_| HostTensor::zeros_f32(vec![b, s, h, dh]))
                .collect(),
            vc: (0..md.n_layers)
                .map(|_| HostTensor::zeros_f32(vec![b, s, h, dh]))
                .collect(),
            slots: vec![None; b],
            lens: vec![0; b],
            last_token: vec![0; b],
            embed_ref,
            attn_refs,
            md,
            layer_cursor: 0,
            weight_bufs: HashMap::new(),
            ep_stats: EpStats::default(),
        })
    }

    /// Replace the binding after a scaling event (switchover): expert
    /// weights may now live on different devices; KV caches and slots are
    /// preserved — this is the zero-copy KV reuse of §5.2.
    pub fn rebind(&mut self, binding: InstanceBinding) -> Result<()> {
        if binding.expert_map.len() != self.md.n_layers {
            bail!("rebind: wrong layer count");
        }
        self.parallel = binding.parallel.clone();
        self.binding = binding;
        Ok(())
    }

    fn payload(&self, dev: DeviceId, region: RegionId) -> Result<Rc<Vec<HostTensor>>> {
        self.hmm
            .borrow()
            .payload(dev, region)
            .with_context(|| format!("no payload at dev {dev} region {region}"))
    }

    /// Device-resident buffer for tensor `idx` of the payload at
    /// (dev, region); uploaded on first use and cached until the region is
    /// superseded (migration ⇒ new region id ⇒ new upload, mirroring the
    /// P2P transfer).
    fn weight_buf(
        &mut self,
        dev: DeviceId,
        region: RegionId,
        idx: usize,
    ) -> Result<Rc<xla::PjRtBuffer>> {
        if let Some(b) = self.weight_bufs.get(&(dev, region, idx)) {
            return Ok(b.clone());
        }
        let payload = self.payload(dev, region)?;
        let t = payload
            .get(idx)
            .with_context(|| format!("payload idx {idx} missing"))?;
        let buf = Rc::new(self.rt.upload(t)?);
        self.weight_bufs.insert((dev, region, idx), buf.clone());
        Ok(buf)
    }

    /// Release slots whose request is no longer running.
    fn sync_slots(&mut self, running: &[Request]) {
        for slot in 0..self.slots.len() {
            if let Some(id) = self.slots[slot] {
                let alive = running.iter().any(|r| {
                    r.id == id
                        && matches!(
                            r.state,
                            RequestState::Prefilling | RequestState::Decoding
                        )
                });
                if !alive {
                    self.slots[slot] = None;
                    self.lens[slot] = 0;
                    self.last_token[slot] = 0;
                }
            }
        }
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    fn slot_of(&self, id: RequestId) -> Option<usize> {
        self.slots.iter().position(|s| *s == Some(id))
    }

    /// Gate matrix -> routing stats accounting.
    fn record_routing(&mut self, cw: &[f32], t: usize) {
        let e = self.md.n_experts;
        let routing = Routing::from_combine_weights(cw, t, e);
        let owners: Vec<DeviceId> = (0..e)
            .map(|ei| {
                self.binding.expert_map[0]
                    .get(&ei)
                    .map(|&(d, _)| d)
                    .unwrap_or(0)
            })
            .collect();
        let n_dev = self.parallel.n_devices().max(1);
        let imb = routing.imbalance(&|ei| owners[ei] % n_dev, n_dev);
        self.ep_stats.max_imbalance = self.ep_stats.max_imbalance.max(imb);
        self.ep_stats.tokens_dispatched +=
            routing.tokens_per_expert.iter().map(|v| v.len() as u64).sum::<u64>();
    }

    /// Expert dispatch/combine over flat tokens: `x_out = h + sum_e cw_e *
    /// expert_e(xn2)` in ascending expert order (matches the golden trace).
    fn moe_combine(
        &mut self,
        artifact: &str,
        h: &HostTensor,
        xn2: &HostTensor,
        cw: &HostTensor,
        t: usize,
    ) -> Result<HostTensor> {
        let d = self.md.d_model;
        let e_total = self.md.n_experts;
        let cw_data = cw.as_f32()?.to_vec();
        self.record_routing(&cw_data, t);
        let mut out = HostTensor::f32(
            h.shape().to_vec(),
            h.as_f32()?.to_vec(),
        );
        // Upload the expert input once; reuse it across all expert calls.
        let xn2_buf = self.rt.upload(xn2)?;
        for e in 0..e_total {
            let col: Vec<f32> =
                (0..t).map(|ti| cw_data[ti * e_total + e]).collect();
            if col.iter().all(|&w| w == 0.0) {
                continue;
            }
            let &(dev, region) = self.binding.expert_map[self.layer_cursor]
                .get(&e)
                .with_context(|| format!("expert {e} unbound"))?;
            let w1 = self.weight_buf(dev, region, 0)?;
            let w3 = self.weight_buf(dev, region, 1)?;
            let w2 = self.weight_buf(dev, region, 2)?;
            let y = self
                .rt
                .run_b(artifact, &[&xn2_buf, &w1, &w3, &w2])?;
            combine_into(out.as_f32_mut()?, y[0].as_f32()?, &col, d);
        }
        Ok(out)
    }
}

/// Current layer index used by `moe_combine` (single-threaded scratch).
impl PjrtBackend {
    fn set_layer(&mut self, l: usize) {
        self.layer_cursor = l;
    }
}

fn parse_attn_tag(tag: &str) -> Option<usize> {
    let rest = tag.strip_prefix("layer")?;
    let (l, kind) = rest.split_once('.')?;
    if kind.starts_with("attn") {
        l.parse().ok()
    } else {
        None
    }
}

impl ExecBackend for PjrtBackend {
    fn prefill(&mut self, running: &mut [Request]) -> Result<f64> {
        let t0 = Instant::now();
        self.sync_slots(running);
        let (b, p, d) = (self.md.batch, self.md.prefill_len, self.md.d_model);
        let (h_, dh) = (self.md.n_heads, self.md.head_dim);

        // Assign slots to the new requests.
        let mut new_slots: Vec<(usize, usize)> = Vec::new(); // (slot, idx)
        for (idx, r) in running.iter().enumerate() {
            if r.state != RequestState::Prefilling {
                continue;
            }
            if self.slot_of(r.id).is_some() {
                continue;
            }
            if r.prompt_ids.len() != r.prompt_len {
                bail!("request {} missing prompt ids", r.id);
            }
            if r.prompt_len > p {
                bail!("prompt {} exceeds compiled P={p}", r.prompt_len);
            }
            let slot = self
                .free_slot()
                .context("no free slot (batch > compiled B?)")?;
            self.slots[slot] = Some(r.id);
            self.lens[slot] = r.prompt_len as i32;
            new_slots.push((slot, idx));
        }
        if new_slots.is_empty() {
            return Ok(t0.elapsed().as_secs_f64());
        }

        // Build padded [B, P] ids and lens.
        let mut ids = vec![0i32; b * p];
        let mut lens = vec![1i32; b];
        for &(slot, idx) in &new_slots {
            let r = &running[idx];
            for (j, &tok) in r.prompt_ids.iter().enumerate() {
                ids[slot * p + j] = tok;
            }
            lens[slot] = r.prompt_len as i32;
        }
        let ids_t = HostTensor::i32(vec![b, p], ids);
        let lens_t = HostTensor::i32(vec![b], lens.clone());
        let lens_buf = self.rt.upload(&lens_t)?;

        let (e_dev, e_reg) = self.embed_ref;
        let emb_buf = self.weight_buf(e_dev, e_reg, 0)?;
        let lnf_buf = self.weight_buf(e_dev, e_reg, 1)?;
        let ids_buf = self.rt.upload(&ids_t)?;
        let mut x = self
            .rt
            .run_b("embed_prefill", &[&emb_buf, &ids_buf])?
            .remove(0);

        for layer in 0..self.md.n_layers {
            let (a_dev, a_reg) = self.attn_refs[layer];
            let w: Vec<Rc<xla::PjRtBuffer>> = (0..7)
                .map(|i| self.weight_buf(a_dev, a_reg, i))
                .collect::<Result<_>>()?;
            let x_buf = self.rt.upload(&x)?;
            let mut outs = self.rt.run_b(
                "attn_gate_prefill",
                &[
                    &x_buf, &lens_buf,
                    &w[0], &w[1], &w[2], &w[3], &w[4], &w[5], &w[6],
                ],
            )?;
            let v = outs.pop().unwrap();
            let k = outs.pop().unwrap();
            let cw = outs.pop().unwrap();
            let xn2 = outs.pop().unwrap();
            let h = outs.pop().unwrap();

            // Persist K/V rows for the NEW slots only (old slots keep their
            // existing cache — batch rows are independent in prefill).
            let s = self.md.max_seq;
            let kd = k.as_f32()?;
            let vd = v.as_f32()?;
            let kc = self.kc[layer].as_f32_mut()?;
            let vcm = self.vc[layer].as_f32_mut()?;
            for &(slot, idx) in &new_slots {
                let plen = running[idx].prompt_len;
                for pos in 0..plen {
                    for hh in 0..h_ {
                        for dd in 0..dh {
                            let src = ((slot * p + pos) * h_ + hh) * dh + dd;
                            let dst = ((slot * s + pos) * h_ + hh) * dh + dd;
                            kc[dst] = kd[src];
                            vcm[dst] = vd[src];
                        }
                    }
                }
            }

            // Expert combine over flattened tokens.
            let bp = b * p;
            let h_flat = HostTensor::f32(
                vec![bp, d],
                h.as_f32()?.to_vec(),
            );
            let xn2_flat = HostTensor::f32(
                vec![bp, d],
                xn2.as_f32()?.to_vec(),
            );
            let cw_flat = HostTensor::f32(
                vec![bp, self.md.n_experts],
                cw.as_f32()?.to_vec(),
            );
            self.set_layer(layer);
            let out = self.moe_combine(
                "expert_ffn_prefill",
                &h_flat,
                &xn2_flat,
                &cw_flat,
                bp,
            )?;
            x = HostTensor::f32(vec![b, p, d], out.as_f32()?.to_vec());
        }

        // First token: final_logits on each new request's last prompt row.
        let mut last = vec![0.0f32; b * d];
        let xd = x.as_f32()?;
        for &(slot, idx) in &new_slots {
            let plen = running[idx].prompt_len;
            let src = (slot * p + plen - 1) * d;
            last[slot * d..(slot + 1) * d]
                .copy_from_slice(&xd[src..src + d]);
        }
        let last_buf =
            self.rt.upload(&HostTensor::f32(vec![b, d], last))?;
        let logits = self
            .rt
            .run_b("final_logits", &[&last_buf, &lnf_buf, &emb_buf])?;
        let am = logits[0].argmax_last()?;
        let toks = am.as_i32()?;
        for &(slot, idx) in &new_slots {
            let tok = toks[slot];
            running[idx].output_ids.push(tok);
            self.last_token[slot] = tok;
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    fn decode(&mut self, running: &mut [Request]) -> Result<f64> {
        let t0 = Instant::now();
        self.sync_slots(running);
        let b = self.md.batch;
        let (h_, dh, s) = (self.md.n_heads, self.md.head_dim, self.md.max_seq);

        // Active decode slots.
        let mut active: Vec<(usize, usize)> = Vec::new(); // (slot, idx)
        for (idx, r) in running.iter().enumerate() {
            if r.state == RequestState::Decoding {
                if let Some(slot) = self.slot_of(r.id) {
                    active.push((slot, idx));
                }
            }
        }
        if active.is_empty() {
            return Ok(t0.elapsed().as_secs_f64());
        }

        let mut ids = vec![0i32; b];
        let mut lens = vec![1i32; b];
        for &(slot, _) in &active {
            ids[slot] = self.last_token[slot];
            lens[slot] = self.lens[slot] + 1; // includes the current token
            if lens[slot] as usize > s {
                bail!("sequence exceeds compiled max_seq {s}");
            }
        }
        let ids_t = HostTensor::i32(vec![b], ids);
        let lens_t = HostTensor::i32(vec![b], lens.clone());
        let lens_buf = self.rt.upload(&lens_t)?;

        let (e_dev, e_reg) = self.embed_ref;
        let emb_buf = self.weight_buf(e_dev, e_reg, 0)?;
        let lnf_buf = self.weight_buf(e_dev, e_reg, 1)?;
        let ids_buf = self.rt.upload(&ids_t)?;
        let mut x = self
            .rt
            .run_b("embed_decode", &[&emb_buf, &ids_buf])?
            .remove(0);

        for layer in 0..self.md.n_layers {
            let (a_dev, a_reg) = self.attn_refs[layer];
            let w: Vec<Rc<xla::PjRtBuffer>> = (0..7)
                .map(|i| self.weight_buf(a_dev, a_reg, i))
                .collect::<Result<_>>()?;
            let x_buf = self.rt.upload(&x)?;
            let kc_buf = self.rt.upload(&self.kc[layer])?;
            let vc_buf = self.rt.upload(&self.vc[layer])?;
            let mut outs = self.rt.run_b(
                "attn_gate_decode",
                &[
                    &x_buf, &lens_buf,
                    &w[0], &w[1], &w[2], &w[3], &w[4], &w[5], &w[6],
                    &kc_buf, &vc_buf,
                ],
            )?;
            let v_new = outs.pop().unwrap();
            let k_new = outs.pop().unwrap();
            let cw = outs.pop().unwrap();
            let xn2 = outs.pop().unwrap();
            let h = outs.pop().unwrap();

            // Persist this token's K/V at position lens-1 for active slots.
            let kd = k_new.as_f32()?;
            let vd = v_new.as_f32()?;
            let kc = self.kc[layer].as_f32_mut()?;
            let vcm = self.vc[layer].as_f32_mut()?;
            for &(slot, _) in &active {
                let pos = (lens[slot] - 1) as usize;
                for hh in 0..h_ {
                    for dd in 0..dh {
                        let src = (slot * h_ + hh) * dh + dd;
                        let dst = ((slot * s + pos) * h_ + hh) * dh + dd;
                        kc[dst] = kd[src];
                        vcm[dst] = vd[src];
                    }
                }
            }

            self.set_layer(layer);
            x = self.moe_combine("expert_ffn_decode", &h, &xn2, &cw, b)?;
        }

        let x_buf = self.rt.upload(&x)?;
        let logits = self
            .rt
            .run_b("final_logits", &[&x_buf, &lnf_buf, &emb_buf])?;
        let am = logits[0].argmax_last()?;
        let toks = am.as_i32()?;
        for &(slot, idx) in &active {
            let tok = toks[slot];
            running[idx].output_ids.push(tok);
            self.last_token[slot] = tok;
            self.lens[slot] += 1;
        }
        self.ep_stats.steps += 1;
        Ok(t0.elapsed().as_secs_f64())
    }

    fn parallel(&self) -> &ParallelConfig {
        &self.parallel
    }

    fn set_derate(&mut self, _factor: f64) {
        // Real backend: transition capacity effects appear naturally (the
        // batcher pauses intake), no synthetic derating.
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}
