//! Roofline cost model: step latencies and capacity limits for the
//! accounting models (DSv2-Lite / Qwen30B / DSv3) under a (DP, TP, EP)
//! layout. Decode is weight-read-bound, prefill is compute-bound — the
//! standard LLM-serving roofline, with constants from
//! [`crate::device::Timings`] (sanity-checked against real PJRT runs of the
//! e2e model; see EXPERIMENTS.md §Calibration).

use crate::config::{ModelConfig, ParallelConfig};
use crate::device::Timings;

/// Step-cost calculator for one model on one timing model.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub model: ModelConfig,
    pub timings: Timings,
    /// Max/mean token load across EP ranks (`>= 1.0`). The decode step's
    /// MoE phase is gated by the most-loaded rank — it receives `imb`
    /// times the mean token work while the other ranks idle at the
    /// all-to-all combine — so the expert read/dispatch terms stretch by
    /// this factor. 1.0 = perfectly balanced placement; the placement
    /// subsystem ([`crate::placement`]) exists to keep it there.
    pub ep_imbalance: f64,
}

impl CostModel {
    pub fn new(model: ModelConfig, timings: Timings) -> Self {
        CostModel {
            model,
            timings,
            ep_imbalance: 1.0,
        }
    }

    /// Builder: set the EP token-load imbalance (clamped to `>= 1.0`).
    pub fn with_ep_imbalance(mut self, imb: f64) -> Self {
        self.ep_imbalance = imb.max(1.0);
        self
    }

    /// One decode iteration with `batch` concurrent sequences.
    ///
    /// Per device: attention weights are read densely; expert reads cover
    /// the experts actually hit by routed tokens (bounded by residency and
    /// by tokens). EP dispatch/combine adds two all-to-all hops.
    pub fn decode_step_time(&self, p: &ParallelConfig, batch: usize) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let m = &self.model;
        let tokens = batch as f64;
        let imb = self.ep_imbalance.max(1.0);
        // Mean tokens landing on one EP rank after dispatch; the hottest
        // rank sees `imb` times this under a skewed placement.
        let tokens_per_rank =
            (tokens * m.top_k as f64 / p.ep as f64).max(1.0);
        let tokens_hot = tokens_per_rank * imb;
        let local_experts =
            p.experts_per_device(m.n_experts as usize) as f64
                + m.n_shared_experts as f64;
        let experts_hit = local_experts.min(tokens_per_rank);

        // Weight-read time per device (decode roofline). The MoE phase is
        // gated by the most-loaded rank, which carries `imb` times the
        // mean rank's expert token work while the other ranks wait at the
        // combine — applied once (linear in `imb`).
        let attn_bytes =
            (m.n_layers * m.attn_bytes_per_layer()) as f64 / p.tp as f64;
        let expert_bytes =
            m.n_layers as f64 * experts_hit * m.expert_bytes() as f64;
        let weight_time = (attn_bytes + expert_bytes * imb)
            / self.timings.hbm_bw;

        // Compute time per device: batch rows through active params.
        let batch_per_dp = (batch as f64 / p.dp as f64).ceil();
        let flops = batch_per_dp * m.flops_per_token() / p.tp as f64;
        let compute_time = flops / self.timings.flops;

        // KV read grows with context; charge the cache-read term at the
        // configured max sequence midpoint (fixed-length synthetic IO).
        let kv_read = batch_per_dp
            * (m.kv_bytes_per_token() as f64 * 1250.0)
            / p.tp as f64
            / self.timings.hbm_bw;

        // EP all-to-all dispatch + combine (sized by the hot rank's
        // shard). `tokens_hot` already counts each token's top-k routed
        // copies, so the per-rank bytes are tokens_hot activations.
        let dispatch_bytes =
            tokens_hot * m.d_model as f64 * m.dtype_bytes as f64;
        let dispatch = 2.0
            * (self.timings.dispatch_latency
                + dispatch_bytes / self.timings.p2p_bw);

        weight_time.max(compute_time + kv_read) + dispatch
    }

    /// Prefill time for `n_tokens` total prompt tokens across the instance
    /// (compute-bound). One engine iteration covers every DP replica's
    /// prefill concurrently, so the whole world contributes FLOPs.
    pub fn prefill_time(&self, p: &ParallelConfig, n_tokens: usize) -> f64 {
        let flops = n_tokens as f64 * self.model.flops_per_token();
        flops / (self.timings.flops * (p.tp * p.dp) as f64)
            + 2.0 * self.timings.dispatch_latency
    }

    /// KV bytes needed per device to admit a sequence of `seq_len` tokens
    /// (KV sharded across the TP group).
    pub fn kv_bytes_per_seq_per_device(&self, p: &ParallelConfig, seq_len: usize) -> u64 {
        self.model.kv_bytes_per_token() * seq_len as u64 / p.tp as u64
    }

    /// Total KV bytes a sequence of `seq_len` tokens occupies across its
    /// DP replica (all TP shards together).
    pub fn kv_seq_bytes(&self, seq_len: usize) -> u64 {
        self.model.kv_bytes_per_token() * seq_len as u64
    }

    /// Time to P2P-copy one sequence's KV to a new owner replica: each TP
    /// shard's slice moves on its own device pair in parallel, so the leg
    /// time is the per-shard transfer (setup + bytes/tp over the fabric).
    pub fn kv_transfer_time(&self, p: &ParallelConfig, seq_len: usize) -> f64 {
        self.timings
            .p2p(self.kv_seq_bytes(seq_len) / p.tp.max(1) as u64)
    }

    /// Time to rebuild one sequence's KV from scratch on the target
    /// configuration: a full re-prefill of its current length. This is
    /// the TTFT inflation a drained-and-recomputed sequence pays (on top
    /// of queueing), and what the paper's zero-copy KV reuse avoids.
    pub fn kv_recompute_time(&self, p: &ParallelConfig, seq_len: usize) -> f64 {
        self.prefill_time(p, seq_len)
    }

    /// KV-handoff decision for one mid-stream sequence whose owner device
    /// departs: copy its blocks when the transfer is cheaper than
    /// re-prefilling on the target, recompute otherwise. Long contexts
    /// copy (transfer is linear in bytes over a ~150 GB/s fabric); very
    /// short sequences recompute (the per-transfer setup latency exceeds
    /// their prefill cost).
    pub fn kv_prefer_copy(&self, to: &ParallelConfig, seq_len: usize) -> bool {
        self.kv_transfer_time(to, seq_len)
            < self.kv_recompute_time(to, seq_len)
    }

    /// Maximum concurrent sequences given per-device KV budget.
    pub fn max_batch(
        &self,
        p: &ParallelConfig,
        kv_bytes_per_device: u64,
        seq_len: usize,
    ) -> usize {
        let per_seq = self.kv_bytes_per_seq_per_device(p, seq_len).max(1);
        let per_replica = (kv_bytes_per_device / per_seq) as usize;
        per_replica * p.dp
    }

    /// Per-device KV budget after weights at a given EP degree (Fig 1a's
    /// mechanism: lower per-device expert memory -> more KV -> bigger
    /// batches).
    pub fn kv_budget(&self, p: &ParallelConfig, hbm_bytes: u64) -> u64 {
        let weights = self.model.device_weight_bytes(p.tp, p.ep);
        // Reserve 10% for activations/fragmentation.
        let reserve = hbm_bytes / 10;
        hbm_bytes.saturating_sub(weights + reserve)
    }

    /// Steady-state decode throughput (requests/sec) at full batch for
    /// fixed-length IO (Fig 1a / Fig 10 capacity curves).
    pub fn steady_throughput_rps(
        &self,
        p: &ParallelConfig,
        hbm_bytes: u64,
        prompt_len: usize,
        decode_len: usize,
    ) -> f64 {
        let kv = self.kv_budget(p, hbm_bytes);
        // Engines cap concurrent sequences (vLLM max_num_seqs; our
        // batcher's max_batch) — without the cap, KV-rich configs would
        // claim unbounded batches.
        let batch = self
            .max_batch(p, kv, prompt_len + decode_len)
            .min(32 * p.dp);
        if batch == 0 {
            return 0.0;
        }
        let step = self.decode_step_time(p, batch);
        let prefill = self.prefill_time(p, prompt_len);
        // Over one batch generation: `batch` requests pay `batch` prefill
        // iterations (prefill blocks decode in the engine) plus decode_len
        // shared decode steps.
        let total = decode_len as f64 * step + batch as f64 * prefill;
        batch as f64 / total.max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{dsv2_lite, dsv3};

    fn cm() -> CostModel {
        CostModel::new(dsv2_lite(), Timings::cloudmatrix())
    }

    fn par(dp: usize, n: usize) -> ParallelConfig {
        ParallelConfig::standard(dp, 2, (0..n).collect()).unwrap()
    }

    #[test]
    fn decode_step_in_plausible_range() {
        let c = cm();
        let t = c.decode_step_time(&par(2, 4), 32);
        // 2.4B active params bf16 at ~1 TB/s → ~10-100 ms class.
        assert!((0.001..0.5).contains(&t), "decode step {t}s");
    }

    #[test]
    fn decode_scales_sublinearly_with_batch() {
        // Weight-read-bound: doubling batch must not double step time.
        let c = cm();
        let p = par(2, 4);
        let t8 = c.decode_step_time(&p, 8);
        let t64 = c.decode_step_time(&p, 64);
        assert!(t64 < t8 * 8.0 * 0.8, "t8={t8} t64={t64}");
        assert!(t64 > t8, "more tokens can't be free");
    }

    #[test]
    fn more_devices_higher_throughput() {
        let c = cm();
        let hbm = 64u64 << 30;
        let t4 = c.steady_throughput_rps(&par(2, 4), hbm, 2000, 600);
        let t8 = c.steady_throughput_rps(&par(4, 8), hbm, 2000, 600);
        assert!(t8 > t4 * 1.2, "t4={t4} t8={t8}");
    }

    #[test]
    fn higher_ep_beats_replicated_experts() {
        // Fig 1a: one EP16 instance outperforms four isolated EP4 replicas
        // (per-device expert memory shrinks -> bigger batches).
        let c = cm();
        let hbm = 64u64 << 30;
        let one_big = c.steady_throughput_rps(&par(8, 16), hbm, 2000, 600);
        let one_small = c.steady_throughput_rps(&par(2, 4), hbm, 2000, 600);
        assert!(
            one_big > 4.0 * one_small,
            "EP16 {one_big} rps vs 4x EP4 {}",
            4.0 * one_small
        );
    }

    #[test]
    fn ep_imbalance_slows_decode_and_throughput() {
        let c = cm();
        let p = par(2, 4);
        let t_bal = c.decode_step_time(&p, 32);
        let c_skew = cm().with_ep_imbalance(2.0);
        let t_skew = c_skew.decode_step_time(&p, 32);
        // The expert phase dominates the decode roofline, so a 2x hot rank
        // must cost well over 20% of a step.
        assert!(t_skew > t_bal * 1.2, "bal {t_bal} skew {t_skew}");
        let hbm = 64u64 << 30;
        let r_bal = c.steady_throughput_rps(&p, hbm, 2000, 600);
        let r_skew = c_skew.steady_throughput_rps(&p, hbm, 2000, 600);
        assert!(r_skew < r_bal, "skewed {r_skew} vs balanced {r_bal}");
        // Sub-balanced values clamp: imbalance cannot speed things up.
        let t_clamp =
            cm().with_ep_imbalance(0.5).decode_step_time(&p, 32);
        assert_eq!(t_clamp, t_bal);
    }

    #[test]
    fn imbalance_penalty_is_linear_in_the_factor() {
        // The hot rank carries imb× the mean token work: the extra cost
        // over balanced must scale with (imb - 1), not quadratically —
        // also at small batches where expert reads are token-limited.
        let p = par(2, 4);
        for batch in [2usize, 32] {
            let base = cm().decode_step_time(&p, batch);
            let e2 = cm().with_ep_imbalance(2.0).decode_step_time(&p, batch)
                - base;
            let e4 = cm().with_ep_imbalance(4.0).decode_step_time(&p, batch)
                - base;
            assert!(e2 > 0.0, "batch {batch}: no penalty");
            assert!(
                e4 <= e2 * 3.0 + 1e-9,
                "batch {batch}: superlinear penalty (e2 {e2}, e4 {e4})"
            );
        }
    }

    #[test]
    fn prefill_is_compute_bound_and_longer_than_decode_step() {
        let c = cm();
        let p = par(2, 4);
        let prefill = c.prefill_time(&p, 2000);
        let decode = c.decode_step_time(&p, 1);
        assert!(prefill > decode, "prefill {prefill} vs decode {decode}");
    }

    #[test]
    fn max_batch_respects_kv_budget() {
        let c = cm();
        let p = par(2, 4);
        let kv = c.kv_budget(&p, 64 << 30);
        assert!(kv > 8 << 30, "kv budget {kv}");
        let b = c.max_batch(&p, kv, 2600);
        assert!(b > 8, "batch {b}");
        // Larger model, tighter budget.
        let c3 = CostModel::new(dsv3(), Timings::cloudmatrix());
        let p3 = ParallelConfig::standard(4, 8, (0..32).collect()).unwrap();
        let kv3 = c3.kv_budget(&p3, 64 << 30);
        assert!(kv3 < kv * 4, "dsv3 budget should be tight: {kv3}");
    }
}
