//! The serving loop: one `step()` = one batcher decision + one backend
//! execution + bookkeeping. Driven by the coordinator under either clock.
//!
//! # Drain semantics
//!
//! [`ServeEngine::drain`] is the switchover primitive: it empties the
//! engine completely — running **and** suspended sequences with their
//! decode progress, plus the untouched waiting queue — and releases every
//! KV block in this engine's pool. Whether a drained sequence resumes on
//! the successor with its progress (zero-copy remap / p2p copy) or
//! restarts from scratch (drain-and-recompute) is decided by the
//! coordinator from the scaling outcome's KV handoff plan
//! ([`crate::kvmigrate`]); the engine itself never re-prefills on drain.
//!
//! [`ServeEngine::suspend_sequences`] opens the per-sequence pause window
//! of that handoff: suspended sequences stop decoding (their KV must stay
//! byte-stable while in flight to the new owner device) but remain live
//! work until the drain.

use anyhow::Result;

use crate::sim::Clock;
use crate::workload::{Request, RequestState};

use super::backend::{ExecBackend, StepKind};
use super::batcher::{Batcher, BatcherConfig, NextWork};
use super::kv_cache::PagedKv;

/// Result of one engine step.
#[derive(Debug)]
pub struct StepOutcome {
    /// What the step executed (prefill / decode / idle).
    pub kind: StepKind,
    /// Simulated (or wall) seconds the step took.
    pub duration: f64,
    /// Requests that completed during this step, reaped with their KV.
    pub finished: Vec<Request>,
    /// Requests preempted back to the queue (KV pressure).
    pub preempted: usize,
}

/// One inference instance's serving engine.
pub struct ServeEngine {
    /// Admission + scheduling (see [`Batcher`] for the state machine).
    pub batcher: Batcher,
    /// The paged KV pool backing the running batch.
    pub kv: PagedKv,
    /// Execution backend: roofline cost model or live PJRT.
    pub backend: Box<dyn ExecBackend>,
    /// Total decode tokens produced (throughput accounting).
    pub tokens_emitted: u64,
    /// Total steps executed.
    pub steps: u64,
}

impl ServeEngine {
    pub fn new(
        cfg: BatcherConfig,
        kv: PagedKv,
        backend: Box<dyn ExecBackend>,
    ) -> Self {
        ServeEngine {
            batcher: Batcher::new(cfg),
            kv,
            backend,
            tokens_emitted: 0,
            steps: 0,
        }
    }

    /// Submit a request to this engine.
    pub fn submit(&mut self, r: Request) {
        self.batcher.enqueue(r);
    }

    /// Execute one iteration at simulated/real time `clock.now()`; advances
    /// the clock by the step duration.
    pub fn step(&mut self, clock: &dyn Clock) -> Result<StepOutcome> {
        self.steps += 1;
        let work = self.batcher.next_work(&mut self.kv);
        let (kind, duration) = match &work {
            NextWork::Prefill(_) => {
                let dt = self.backend.prefill(self.batcher.running_mut())?;
                (StepKind::Prefill, dt)
            }
            NextWork::Decode { .. } => {
                let dt = self.backend.decode(self.batcher.running_mut())?;
                (StepKind::Decode, dt)
            }
            NextWork::Idle => (StepKind::Idle, 0.0),
        };
        clock.advance(duration);
        let now = clock.now();

        let mut preempted = 0;
        match work {
            NextWork::Prefill(ids) => {
                // Prefill emits each request's first token at completion.
                for r in self.batcher.running_mut() {
                    if ids.contains(&r.id)
                        && r.state == RequestState::Prefilling
                    {
                        r.state = RequestState::Decoding;
                        r.generated = 1;
                        r.first_token_at = Some(now);
                        self.tokens_emitted += 1;
                        if r.generated >= r.max_new_tokens {
                            r.state = RequestState::Finished;
                            r.finished_at = Some(now);
                        }
                    }
                }
                // First-token KV growth.
                for id in &ids {
                    let _ = self.kv.append_token(*id);
                }
            }
            NextWork::Decode { .. } => {
                // Single pass over the running batch: grow KV (preempt on
                // pool exhaustion) and advance decode state in place. The
                // preempt list stays empty — and unallocated — on the
                // common path.
                let mut to_preempt: Vec<u64> = Vec::new();
                let mut emitted = 0u64;
                for r in self.batcher.running_mut() {
                    if self.kv.append_token(r.id).is_err() {
                        to_preempt.push(r.id);
                        continue;
                    }
                    if r.state != RequestState::Decoding {
                        continue;
                    }
                    r.generated += 1;
                    emitted += 1;
                    if r.generated >= r.max_new_tokens {
                        r.state = RequestState::Finished;
                        r.finished_at = Some(now);
                    }
                }
                self.tokens_emitted += emitted;
                preempted = self.preempt(&to_preempt, now);
            }
            NextWork::Idle => {}
        }

        let finished = self.batcher.reap_finished(&mut self.kv);
        Ok(StepOutcome {
            kind,
            duration,
            finished,
            preempted,
        })
    }

    /// Preempt requests back to the waiting queue (restart-from-scratch
    /// recompute policy, vLLM's default preemption).
    fn preempt(&mut self, ids: &[u64], now: f64) -> usize {
        if ids.is_empty() {
            return 0;
        }
        let mut n = 0;
        let running = self.batcher.running_mut();
        let mut moved = Vec::new();
        for r in running.iter_mut() {
            if ids.contains(&r.id) {
                let mut fresh = Request::new(
                    r.id,
                    r.arrival,
                    r.prompt_len,
                    r.max_new_tokens,
                )
                .with_tenant(r.tenant);
                fresh.prompt_ids = r.prompt_ids.clone();
                moved.push(fresh);
                r.state = RequestState::Dropped; // reaped below, re-queued
                r.finished_at = Some(now); // drop time, not arrival
                n += 1;
            }
        }
        let _ = self.batcher.reap_finished(&mut self.kv);
        for r in moved {
            self.batcher.enqueue(r);
        }
        n
    }

    /// Drain everything (switchover): in-flight requests — running and
    /// suspended, with their decode progress — are handed back for
    /// migration to the successor instance, followed by the waiting
    /// queue. All KV blocks in this engine's pool are released.
    pub fn drain(&mut self) -> (Vec<Request>, Vec<Request>) {
        let running = self.batcher.take_all_running(&mut self.kv);
        let waiting = self.batcher.take_waiting();
        (running, waiting)
    }

    /// Freeze decode for the given sequences while their KV blocks are
    /// copied to a new owner (scaling-event handoff). Returns the ids
    /// actually suspended. They are returned by the next [`Self::drain`]
    /// alongside the running batch — or restored by
    /// [`Self::resume_suspended`] if the event aborts.
    pub fn suspend_sequences(&mut self, ids: &[u64]) -> Vec<u64> {
        self.batcher.suspend(ids)
    }

    /// Resume every suspended sequence in place (a scaling event aborted
    /// and rolled back: the blocks never left this engine). Returns the
    /// resumed ids.
    pub fn resume_suspended(&mut self) -> Vec<u64> {
        self.batcher.resume_suspended()
    }

    pub fn has_work(&self) -> bool {
        !self.batcher.is_idle()
    }

    /// Access the live PJRT backend (for post-scaling rebinds); `None` on
    /// the simulation backend.
    pub fn backend_as_pjrt(
        &mut self,
    ) -> Option<&mut super::pjrt::PjrtBackend> {
        self.backend
            .as_any_mut()
            .downcast_mut::<super::pjrt::PjrtBackend>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;
    use crate::config::ParallelConfig;
    use crate::device::Timings;
    use crate::engine::backend::CostModelBackend;
    use crate::engine::cost_model::CostModel;
    use crate::sim::{Clock, SimClock};

    fn engine(max_batch: usize) -> ServeEngine {
        let p = ParallelConfig::standard(2, 2, (0..4).collect()).unwrap();
        let backend = CostModelBackend::new(
            CostModel::new(dsv2_lite(), Timings::cloudmatrix()),
            p,
        );
        ServeEngine::new(
            BatcherConfig {
                max_batch,
                max_prefill_tokens: 8192,
            },
            PagedKv::new(100_000, 16),
            Box::new(backend),
        )
    }

    #[test]
    fn request_flows_to_completion() {
        let clock = SimClock::new();
        let mut e = engine(8);
        e.submit(Request::new(1, 0.0, 500, 5));
        let mut finished = Vec::new();
        for _ in 0..20 {
            let out = e.step(&clock).unwrap();
            finished.extend(out.finished);
            if !e.has_work() {
                break;
            }
        }
        assert_eq!(finished.len(), 1);
        let r = &finished[0];
        assert_eq!(r.generated, 5);
        assert!(r.ttft().unwrap() > 0.0);
        assert!(r.finished_at.unwrap() > r.first_token_at.unwrap());
        assert!(clock.now() > 0.0);
        assert_eq!(e.tokens_emitted, 5);
    }

    #[test]
    fn batch_makes_progress_together() {
        let clock = SimClock::new();
        let mut e = engine(8);
        for i in 1..=4 {
            e.submit(Request::new(i, 0.0, 100, 10));
        }
        let mut done = 0;
        for _ in 0..50 {
            done += e.step(&clock).unwrap().finished.len();
        }
        assert_eq!(done, 4);
    }

    #[test]
    fn idle_step_is_free() {
        let clock = SimClock::new();
        let mut e = engine(4);
        let out = e.step(&clock).unwrap();
        assert_eq!(out.kind, StepKind::Idle);
        assert_eq!(clock.now(), 0.0);
    }

    #[test]
    fn drain_returns_inflight_and_queued() {
        let clock = SimClock::new();
        let mut e = engine(2);
        for i in 1..=4 {
            e.submit(Request::new(i, 0.0, 100, 10));
        }
        e.step(&clock).unwrap(); // prefill 2, 2 stay queued
        let (running, waiting) = e.drain();
        assert_eq!(running.len(), 2);
        assert_eq!(waiting.len(), 2);
        assert_eq!(e.kv.used_blocks(), 0);
    }

    #[test]
    fn kv_exhaustion_preempts_not_corrupts() {
        let clock = SimClock::new();
        let p = ParallelConfig::standard(2, 2, (0..4).collect()).unwrap();
        let backend = CostModelBackend::new(
            CostModel::new(dsv2_lite(), Timings::cloudmatrix()),
            p,
        );
        // Tiny pool: 2 requests of 100+20 tokens fit only barely.
        let mut e = ServeEngine::new(
            BatcherConfig {
                max_batch: 4,
                max_prefill_tokens: 8192,
            },
            PagedKv::new(16, 16), // 256 tokens total
            Box::new(backend),
        );
        for i in 1..=2 {
            e.submit(Request::new(i, 0.0, 100, 60));
        }
        let mut finished = 0;
        for _ in 0..200 {
            let out = e.step(&clock).unwrap();
            finished += out.finished.len();
            if !e.has_work() {
                break;
            }
        }
        // Both eventually finish (preemption retries), nothing lost.
        assert_eq!(finished, 2);
    }
}
