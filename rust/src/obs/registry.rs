//! The telemetry registry: named counters, gauges, log-bucket
//! histograms, and in-memory time series.
//!
//! [`Telemetry`] is the single observability sink threaded through the
//! simulators ([`crate::coordinator::ServingSim`],
//! [`crate::coordinator::FleetSim`]). Everything here is plain in-memory
//! state with no clocks, no I/O, and no randomness of its own — samples
//! are pushed by the event core at instants it was already awake for, so
//! enabling telemetry never adds queue entries and never perturbs the
//! simulation (`state_hash` is bit-identical either way; pinned by
//! `tests/determinism.rs`).
//!
//! Naming convention: per-replica series are keyed
//! `replica{N}/{metric}`; cluster-wide series use a bare metric name (or
//! a `fleet/` / `pool/` prefix). The exporters in
//! [`crate::obs::export`] parse the prefix to pick a Chrome-trace
//! process track.

use std::collections::BTreeMap;

use crate::obs::spans::SpanTracker;

/// Fixed-bucket log-scale histogram.
///
/// Bucket `i` (1-based) covers `[lo·g^(i-1), lo·g^i)`; index 0 is the
/// underflow bucket `[0, lo)` and the last index is the unbounded
/// overflow bucket. With the [`latency`](Self::latency) defaults
/// (`lo = 1e-4`, `growth = 2`, 40 buckets) the covered range is 0.1 ms
/// to ~1.1e8 s, plenty for any simulated latency.
///
/// The percentile estimate returns the **upper edge** of the bucket
/// holding the nearest-rank sample, so it is always `>=` the exact
/// sorted percentile and within one bucket width of it (pinned by a
/// property test in `tests/properties.rs`).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    lo: f64,
    growth: f64,
    /// Index 0 = underflow, `1..=n` = log buckets, `n + 1` = overflow.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl LogHistogram {
    pub fn new(lo: f64, growth: f64, buckets: usize) -> Self {
        assert!(lo > 0.0, "bucket floor must be positive");
        assert!(growth > 1.0, "bucket growth must exceed 1");
        assert!(buckets > 0, "need at least one log bucket");
        LogHistogram {
            lo,
            growth,
            counts: vec![0; buckets + 2],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Default shape for latency-like quantities (seconds).
    pub fn latency() -> Self {
        LogHistogram::new(1e-4, 2.0, 40)
    }

    /// Number of log-scale buckets (excluding underflow/overflow).
    fn n(&self) -> usize {
        self.counts.len() - 2
    }

    fn bucket_index(&self, x: f64) -> usize {
        if !(x >= self.lo) {
            // Underflow; also catches NaN and negatives.
            return 0;
        }
        let i = ((x / self.lo).ln() / self.growth.ln()).floor();
        if i >= self.n() as f64 {
            self.n() + 1
        } else {
            1 + i as usize
        }
    }

    /// Upper value edge of bucket `idx` (the percentile estimate for
    /// samples landing there). Overflow reports the observed max.
    fn upper_edge(&self, idx: usize) -> f64 {
        if idx == 0 {
            self.lo
        } else if idx == self.n() + 1 {
            self.max
        } else {
            self.lo * self.growth.powi(idx as i32)
        }
    }

    /// `[start, end)` value range of the bucket `x` falls in. The
    /// underflow bucket spans `[0, lo)`; overflow is unbounded above.
    pub fn bucket_span(&self, x: f64) -> (f64, f64) {
        let idx = self.bucket_index(x);
        if idx == 0 {
            (0.0, self.lo)
        } else if idx == self.n() + 1 {
            (self.lo * self.growth.powi(self.n() as i32), f64::INFINITY)
        } else {
            let b = self.lo * self.growth.powi((idx - 1) as i32);
            (b, b * self.growth)
        }
    }

    pub fn record(&mut self, x: f64) {
        let idx = self.bucket_index(x);
        self.counts[idx] += 1;
        self.count += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Nearest-rank percentile estimate — the same rank rule as
    /// [`crate::util::stats::percentile`], resolved to the upper edge of
    /// the bucket holding the rank sample.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank =
            ((p / 100.0).clamp(0.0, 1.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for idx in 0..self.counts.len() {
            seen += self.counts[idx];
            if seen > rank {
                return self.upper_edge(idx);
            }
        }
        self.max
    }

    /// Cumulative `(upper_edge, count)` pairs over the non-empty prefix,
    /// ending with the `+Inf` total — the Prometheus `_bucket` series.
    /// Empty trailing buckets are collapsed into the final pair.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut seen = 0u64;
        for idx in 0..self.counts.len() - 1 {
            seen += self.counts[idx];
            if self.counts[idx] > 0 {
                let edge = if idx == 0 {
                    self.lo
                } else {
                    self.lo * self.growth.powi(idx as i32)
                };
                out.push((edge, seen));
            }
        }
        out.push((f64::INFINITY, self.count));
        out
    }
}

/// One in-memory time series: `(t, value)` points in sample order.
/// Consecutive duplicate values are collapsed (the exporters render
/// step functions, so repeats carry no information) to bound memory on
/// long runs.
#[derive(Debug, Clone, Default)]
pub struct Series {
    points: Vec<(f64, f64)>,
}

impl Series {
    pub fn push(&mut self, t: f64, v: f64) {
        if let Some(&(_, last)) = self.points.last() {
            if last == v {
                return;
            }
        }
        self.points.push((t, v));
    }

    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }

    pub fn last(&self) -> Option<(f64, f64)> {
        self.points.last().copied()
    }

    /// Maximum value observed across the whole series.
    pub fn max_value(&self) -> f64 {
        self.points
            .iter()
            .map(|&(_, v)| v)
            .fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Per-replica gauge snapshot taken by the event core on ticks it was
/// already awake for (window ticks / policy ticks).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplicaSample {
    pub queue_depth: usize,
    pub running: usize,
    pub suspended: usize,
    pub kv_blocks: usize,
    pub hbm_used: u64,
    pub hbm_peak: u64,
    pub dram_used: u64,
    pub devices: usize,
    pub intake_paused: bool,
    pub parked: bool,
}

/// The telemetry registry: counters, gauges, histograms, time series,
/// and the scaling-event [`SpanTracker`]. All maps are `BTreeMap` so
/// iteration — and therefore every export — is deterministic.
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, LogHistogram>,
    series: BTreeMap<String, Series>,
    pub spans: SpanTracker,
}

impl Telemetry {
    pub fn new() -> Self {
        Telemetry::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Record into a histogram, creating it with the
    /// [`LogHistogram::latency`] shape on first touch.
    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(LogHistogram::latency)
            .record(v);
    }

    pub fn histogram(&self, name: &str) -> Option<&LogHistogram> {
        self.histograms.get(name)
    }

    /// Append a `(t, v)` point to the named time series.
    pub fn record_series(&mut self, name: &str, t: f64, v: f64) {
        self.series.entry(name.to_string()).or_default().push(t, v);
    }

    pub fn series(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    pub fn counters(&self) -> &BTreeMap<String, u64> {
        &self.counters
    }

    pub fn gauges(&self) -> &BTreeMap<String, f64> {
        &self.gauges
    }

    pub fn histograms(&self) -> &BTreeMap<String, LogHistogram> {
        &self.histograms
    }

    pub fn all_series(&self) -> &BTreeMap<String, Series> {
        &self.series
    }

    /// Snapshot one replica's gauges into its `replica{N}/...` series.
    /// Called from event-core wake handlers only — no new queue entries.
    pub fn sample_replica(
        &mut self,
        now: f64,
        replica: usize,
        s: &ReplicaSample,
    ) {
        let base = format!("replica{replica}");
        self.record_series(
            &format!("{base}/queue_depth"),
            now,
            s.queue_depth as f64,
        );
        self.record_series(&format!("{base}/running"), now, s.running as f64);
        self.record_series(
            &format!("{base}/suspended"),
            now,
            s.suspended as f64,
        );
        self.record_series(
            &format!("{base}/kv_blocks"),
            now,
            s.kv_blocks as f64,
        );
        self.record_series(
            &format!("{base}/hbm_used_bytes"),
            now,
            s.hbm_used as f64,
        );
        self.record_series(
            &format!("{base}/hbm_peak_bytes"),
            now,
            s.hbm_peak as f64,
        );
        self.record_series(
            &format!("{base}/dram_used_bytes"),
            now,
            s.dram_used as f64,
        );
        self.record_series(
            &format!("{base}/devices_active"),
            now,
            s.devices as f64,
        );
        self.record_series(
            &format!("{base}/intake_paused"),
            now,
            if s.intake_paused { 1.0 } else { 0.0 },
        );
        self.record_series(
            &format!("{base}/parked"),
            now,
            if s.parked { 1.0 } else { 0.0 },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_edges() {
        let mut h = LogHistogram::new(1.0, 2.0, 4);
        // underflow, bucket [1,2), [2,4), [4,8), [8,16), overflow
        for x in [0.5, 1.5, 3.0, 3.5, 20.0, 100.0] {
            h.record(x);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.bucket_span(0.5), (0.0, 1.0));
        assert_eq!(h.bucket_span(3.0), (2.0, 4.0));
        assert_eq!(h.bucket_span(100.0), (16.0, f64::INFINITY));
        assert_eq!(h.max(), 100.0);
        assert_eq!(h.min(), 0.5);
        // p0 = rank 0 → underflow bucket → upper edge 1.0
        assert_eq!(h.percentile(0.0), 1.0);
        // p100 → overflow → observed max
        assert_eq!(h.percentile(100.0), 100.0);
        // median (rank 2.5 → 3) is 3.5, in [2,4) → edge 4
        assert_eq!(h.percentile(50.0), 4.0);
    }

    #[test]
    fn histogram_cumulative_ends_at_total() {
        let mut h = LogHistogram::latency();
        for x in [0.001, 0.002, 0.004, 1.0] {
            h.record(x);
        }
        let cum = h.cumulative();
        let (edge, total) = *cum.last().unwrap();
        assert!(edge.is_infinite());
        assert_eq!(total, 4);
        // cumulative counts are monotone
        for w in cum.windows(2) {
            assert!(w[0].1 <= w[1].1);
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn series_collapses_duplicates() {
        let mut s = Series::default();
        s.push(0.0, 1.0);
        s.push(1.0, 1.0);
        s.push(2.0, 2.0);
        s.push(3.0, 2.0);
        assert_eq!(s.points(), &[(0.0, 1.0), (2.0, 2.0)]);
        assert_eq!(s.max_value(), 2.0);
    }

    #[test]
    fn registry_roundtrip() {
        let mut t = Telemetry::new();
        t.inc("scale_commands", 1);
        t.inc("scale_commands", 2);
        t.set_gauge("replicas", 3.0);
        t.observe("ttft", 0.25);
        t.sample_replica(
            5.0,
            0,
            &ReplicaSample {
                queue_depth: 4,
                ..Default::default()
            },
        );
        assert_eq!(t.counter("scale_commands"), 3);
        assert_eq!(t.gauge("replicas"), Some(3.0));
        assert_eq!(t.histogram("ttft").unwrap().count(), 1);
        assert_eq!(
            t.series("replica0/queue_depth").unwrap().points(),
            &[(5.0, 4.0)]
        );
    }
}
