//! Telemetry exporters: Chrome trace-event JSON and Prometheus text.
//!
//! [`chrome_trace`] renders the full [`Telemetry`] — spans, instants,
//! and time series — as a Chrome trace-event JSON object loadable in
//! Perfetto or `chrome://tracing`. Track layout:
//!
//! - **pid 0** (`cluster`) — fleet-wide series (device pool occupancy,
//!   replica counts) and any span with no replica prefix.
//! - **pid N+1** (`replica N`) — that replica's spans and its
//!   `replica{N}/...` gauge series, with one thread row per span
//!   category so concurrent phases visually overlap the serving
//!   timeline while switchover-window phases sit on their own row.
//!
//! Trace `ts`/`dur` are microseconds; the simulator's second-valued
//! clocks are scaled by 1e6. [`prometheus`] renders final
//! counter/gauge/histogram state in the Prometheus text exposition
//! format (`# TYPE` comments, cumulative `_bucket{le=...}` histogram
//! series). Both renderings are deterministic byte-for-byte: maps are
//! `BTreeMap`-ordered and spans keep insertion order (pinned by the
//! golden file under `tests/golden/chrome_trace.json`).

use std::collections::BTreeSet;

use crate::obs::registry::Telemetry;
use crate::obs::spans::{CAT_CONCURRENT, CAT_LIFECYCLE, CAT_MARK, CAT_SWITCHOVER, CAT_WINDOW};
use crate::util::json::Json;

/// Microseconds per simulated second (trace-event time unit).
const US: f64 = 1e6;

/// Thread-row id for a span category — stable small ints so Perfetto
/// groups phases of the same kind onto one row per replica.
fn tid_for(cat: &str) -> u64 {
    match cat {
        CAT_CONCURRENT => 1,
        CAT_SWITCHOVER => 2,
        CAT_WINDOW => 3,
        CAT_LIFECYCLE => 4,
        CAT_MARK => 5,
        _ => 6,
    }
}

/// Process id for a series name: `replica{N}/...` maps to pid `N + 1`,
/// everything else to the cluster track (pid 0). Returns the pid and
/// the name with the replica prefix stripped.
fn series_track(name: &str) -> (u64, &str) {
    if let Some(rest) = name.strip_prefix("replica") {
        if let Some(slash) = rest.find('/') {
            if let Ok(n) = rest[..slash].parse::<u64>() {
                return (n + 1, &rest[slash + 1..]);
            }
        }
    }
    (0, name)
}

fn meta_process(pid: u64, name: String) -> Json {
    Json::obj(vec![
        ("args", Json::obj(vec![("name", Json::str(name))])),
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
    ])
}

fn meta_thread(pid: u64, tid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("args", Json::obj(vec![("name", Json::str(name))])),
        ("name", Json::str("thread_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
    ])
}

/// Render the telemetry as a Chrome trace-event JSON document.
pub fn chrome_trace(t: &Telemetry) -> Json {
    let mut events: Vec<Json> = Vec::new();

    // Process/thread metadata first: every pid touched by a span,
    // instant, or series, in sorted order.
    let mut pids: BTreeSet<u64> = BTreeSet::new();
    let mut threads: BTreeSet<(u64, u64)> = BTreeSet::new();
    for s in t.spans.spans() {
        let pid = s.replica as u64 + 1;
        pids.insert(pid);
        threads.insert((pid, tid_for(s.cat)));
    }
    for i in t.spans.instants() {
        let pid = i.replica as u64 + 1;
        pids.insert(pid);
        threads.insert((pid, tid_for(CAT_MARK)));
    }
    for name in t.all_series().keys() {
        pids.insert(series_track(name).0);
    }
    for &pid in &pids {
        let name = if pid == 0 {
            "cluster".to_string()
        } else {
            format!("replica {}", pid - 1)
        };
        events.push(meta_process(pid, name));
    }
    for &(pid, tid) in &threads {
        let name = match tid {
            1 => CAT_CONCURRENT,
            2 => CAT_SWITCHOVER,
            3 => CAT_WINDOW,
            4 => CAT_LIFECYCLE,
            5 => CAT_MARK,
            _ => "other",
        };
        events.push(meta_thread(pid, tid, name));
    }

    // Spans as complete ("X") events, insertion order.
    for s in t.spans.spans() {
        let mut args = vec![("cat", Json::str(s.cat))];
        if let Some(e) = s.event {
            args.push(("event", Json::num(e as f64)));
        }
        events.push(Json::obj(vec![
            ("args", Json::obj(args)),
            ("cat", Json::str(s.cat)),
            ("dur", Json::num((s.end - s.start) * US)),
            ("name", Json::str(s.name.clone())),
            ("ph", Json::str("X")),
            ("pid", Json::num(s.replica as f64 + 1.0)),
            ("tid", Json::num(tid_for(s.cat) as f64)),
            ("ts", Json::num(s.start * US)),
        ]));
    }

    // Instants ("i"), insertion order.
    for i in t.spans.instants() {
        events.push(Json::obj(vec![
            ("name", Json::str(i.name.clone())),
            ("ph", Json::str("i")),
            ("pid", Json::num(i.replica as f64 + 1.0)),
            ("s", Json::str("t")),
            ("tid", Json::num(tid_for(CAT_MARK) as f64)),
            ("ts", Json::num(i.t * US)),
        ]));
    }

    // Time series as counter ("C") events, name-sorted then time order.
    for (name, series) in t.all_series() {
        let (pid, metric) = series_track(name);
        for &(ts, v) in series.points() {
            events.push(Json::obj(vec![
                ("args", Json::obj(vec![("value", Json::num(v))])),
                ("name", Json::str(metric)),
                ("ph", Json::str("C")),
                ("pid", Json::num(pid as f64)),
                ("ts", Json::num(ts * US)),
            ]));
        }
    }

    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::arr(events)),
    ])
}

/// Sanitize a telemetry name into a Prometheus metric name.
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 8);
    out.push_str("elastic_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

fn prom_num(v: f64) -> String {
    if !v.is_infinite() {
        format!("{v}")
    } else if v > 0.0 {
        "+Inf".into()
    } else {
        "-Inf".into()
    }
}

/// Render final counter/gauge/histogram state in the Prometheus text
/// exposition format. Time series are summarized as `_max` gauges (the
/// full curves live in the Chrome trace).
pub fn prometheus(t: &Telemetry) -> String {
    let mut out = String::new();
    for (name, &v) in t.counters() {
        let n = prom_name(name);
        out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
    }
    for (name, &v) in t.gauges() {
        let n = prom_name(name);
        out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", prom_num(v)));
    }
    for (name, series) in t.all_series() {
        if series.points().is_empty() {
            continue;
        }
        let n = prom_name(&format!("{name}_max"));
        out.push_str(&format!(
            "# TYPE {n} gauge\n{n} {}\n",
            prom_num(series.max_value())
        ));
    }
    for (name, h) in t.histograms() {
        let n = prom_name(name);
        out.push_str(&format!("# TYPE {n} histogram\n"));
        for (edge, count) in h.cumulative() {
            out.push_str(&format!(
                "{n}_bucket{{le=\"{}\"}} {count}\n",
                prom_num(edge)
            ));
        }
        out.push_str(&format!("{n}_sum {}\n", prom_num(h.sum())));
        out.push_str(&format!("{n}_count {}\n", h.count()));
    }
    out
}

/// Write the Chrome trace JSON (newline-terminated) to `path`.
pub fn write_trace(t: &Telemetry, path: &str) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", chrome_trace(t)))
}

/// Write the Prometheus exposition to `path`.
pub fn write_metrics(t: &Telemetry, path: &str) -> std::io::Result<()> {
    std::fs::write(path, prometheus(t))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Telemetry {
        let mut t = Telemetry::new();
        t.inc("scale_commands", 2);
        t.set_gauge("replicas", 1.0);
        t.observe("ttft_s", 0.25);
        t.record_series("replica0/queue_depth", 0.0, 3.0);
        t.record_series("replica0/queue_depth", 5.0, 7.0);
        t.record_series("pool/devices_free", 0.0, 4.0);
        t.spans
            .span(0, Some(0), "scale0/warmup", CAT_CONCURRENT, 1.0, 2.5);
        t.spans.instant(0, "fault", 2.0);
        t
    }

    #[test]
    fn chrome_trace_parses_back_and_maps_tracks() {
        let tr = chrome_trace(&sample());
        let parsed =
            crate::util::json::parse(&tr.to_string()).expect("self-parse");
        let events = parsed.get("traceEvents").as_arr().unwrap();
        // span: pid 1 (replica 0), ts scaled to µs
        let span = events
            .iter()
            .find(|e| e.get("ph").as_str() == Some("X"))
            .unwrap();
        assert_eq!(span.get("pid").as_f64(), Some(1.0));
        assert_eq!(span.get("ts").as_f64(), Some(1_000_000.0));
        assert_eq!(span.get("dur").as_f64(), Some(1_500_000.0));
        // counter series: replica prefix stripped, pool series on pid 0
        assert!(events.iter().any(|e| e.get("ph").as_str() == Some("C")
            && e.get("name").as_str() == Some("queue_depth")
            && e.get("pid").as_f64() == Some(1.0)));
        assert!(events.iter().any(|e| e.get("ph").as_str() == Some("C")
            && e.get("name").as_str() == Some("pool/devices_free")
            && e.get("pid").as_f64() == Some(0.0)));
        // metadata names both processes
        assert!(events.iter().any(|e| e.get("ph").as_str() == Some("M")
            && e.get("args").get("name").as_str() == Some("cluster")));
        assert!(events.iter().any(|e| e.get("ph").as_str() == Some("M")
            && e.get("args").get("name").as_str() == Some("replica 0")));
    }

    #[test]
    fn prometheus_exposition_shape() {
        let text = prometheus(&sample());
        assert!(text.contains("# TYPE elastic_scale_commands counter\n"));
        assert!(text.contains("elastic_scale_commands 2\n"));
        assert!(text.contains("# TYPE elastic_replicas gauge\n"));
        assert!(text.contains("# TYPE elastic_ttft_s histogram\n"));
        assert!(text.contains("elastic_ttft_s_count 1\n"));
        assert!(text.contains("le=\"+Inf\"} 1\n"));
        // series summarized with sanitized name
        assert!(text.contains("elastic_replica0_queue_depth_max 7\n"));
    }
}
