//! Observability: time-series metrics, scaling-event span timelines,
//! and trace/metrics exporters.
//!
//! The paper's headline claims — 9x lower scale-up latency, 2x
//! throughput *during* scaling, zero downtime — are statements about
//! what happens over time inside a scaling event. This subsystem makes
//! those time-resolved curves first-class: the simulators thread a
//! [`Telemetry`] registry (counters, gauges, log-bucket histograms,
//! per-replica time series) through their event cores, a [`SpanTracker`]
//! turns every scaling event into a phase timeline, and
//! [`export`] renders Chrome trace-event JSON (Perfetto /
//! `chrome://tracing`) plus a Prometheus-style text exposition.
//!
//! The contract that makes this safe to leave on: telemetry is
//! **determinism-neutral**. Samples piggyback on event-core wakeups the
//! simulator was already scheduled for (no new queue entries), nothing
//! telemetry-side feeds back into simulation state, and `state_hash` is
//! bit-identical with telemetry enabled or disabled —
//! `tests/determinism.rs` sweeps every conformance cell both ways. See
//! `docs/architecture/08-observability.md`.

pub mod attain;
pub mod export;
pub mod registry;
pub mod spans;

pub use attain::{EventCost, WindowAttainment};
pub use registry::{LogHistogram, ReplicaSample, Series, Telemetry};
pub use spans::{Instant, Span, SpanTracker};
