//! Scaling-event span timelines.
//!
//! [`SpanTracker`] turns every scaling event into a phase timeline —
//! plan/p2p/remap/tier/kv/switchover/warmup/rollback spans — plus window
//! spans for intake pauses and downtime, lifecycle spans (replica boots,
//! park intervals), and instants (fault firings, aborts). The timeline
//! is derived at command-issue time from the [`ScalingOutcome`] the
//! method already returned: the outcome is fully resolved then, so the
//! derivation is deterministic and consumes no extra simulator events.
//!
//! Span categories classify each phase against the outcome's declared
//! pause window:
//!
//! - [`CAT_CONCURRENT`] — runs while the old instance keeps serving
//!   (the HMM/IMM prep chain of the paper's §5: expert p2p, vPage remap,
//!   tier h2d/d2h, KV init, warmup).
//! - [`CAT_SWITCHOVER`] — falls inside the declared intake-pause window
//!   (final drain + reroute, and the migrating-KV handoff legs).
//!
//! The classification is geometric — a span is `switchover_window` iff
//! its midpoint lies at or past the pause start — so it holds for every
//! scaling method, not just ElasticMoE. The acceptance check in
//! `coordinator/serving.rs` tests asserts that for the zero-copy path
//! only the switchover-window phases land inside the pause.

use crate::scaling::ScalingOutcome;

/// Phase overlapped with live serving on the old instance.
pub const CAT_CONCURRENT: &str = "concurrent";
/// Phase inside the declared intake-pause (switchover) window.
pub const CAT_SWITCHOVER: &str = "switchover_window";
/// Declared window itself (intake pause, downtime).
pub const CAT_WINDOW: &str = "window";
/// Replica lifecycle (boot, park, drain).
pub const CAT_LIFECYCLE: &str = "lifecycle";
/// Zero-duration marks (faults, aborts).
pub const CAT_MARK: &str = "mark";

/// One named interval on a replica's timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub replica: usize,
    /// Scaling-event ordinal this span belongs to, if any.
    pub event: Option<usize>,
    pub name: String,
    pub cat: &'static str,
    pub start: f64,
    pub end: f64,
}

/// A zero-duration mark (fault fired, scale aborted, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct Instant {
    pub replica: usize,
    pub name: String,
    pub t: f64,
}

/// Collects spans and instants in deterministic (insertion) order.
#[derive(Debug, Clone, Default)]
pub struct SpanTracker {
    spans: Vec<Span>,
    instants: Vec<Instant>,
    /// Open intervals keyed `(replica, name)`, closed by [`Self::end`].
    open: Vec<(usize, String, f64)>,
}

impl SpanTracker {
    pub fn span(
        &mut self,
        replica: usize,
        event: Option<usize>,
        name: impl Into<String>,
        cat: &'static str,
        start: f64,
        end: f64,
    ) {
        self.spans.push(Span {
            replica,
            event,
            name: name.into(),
            cat,
            start,
            end: end.max(start),
        });
    }

    pub fn instant(&mut self, replica: usize, name: impl Into<String>, t: f64) {
        self.instants.push(Instant {
            replica,
            name: name.into(),
            t,
        });
    }

    /// Open a lifecycle interval (e.g. `park`); closed by [`Self::end`]
    /// with the same name, or by [`Self::finish`] at end of run.
    pub fn begin(&mut self, replica: usize, name: impl Into<String>, t: f64) {
        self.open.push((replica, name.into(), t));
    }

    /// Close the most recent open interval matching `(replica, name)`.
    pub fn end(&mut self, replica: usize, name: &str, t: f64) {
        if let Some(pos) = self
            .open
            .iter()
            .rposition(|(r, n, _)| *r == replica && n == name)
        {
            let (r, n, start) = self.open.remove(pos);
            self.span(r, None, n, CAT_LIFECYCLE, start, t);
        }
    }

    /// Close every still-open interval at the end-of-run timestamp.
    pub fn finish(&mut self, t: f64) {
        let open = std::mem::take(&mut self.open);
        for (r, n, start) in open {
            self.span(r, None, n, CAT_LIFECYCLE, start, t.max(start));
        }
    }

    /// Derive the full phase timeline for a scaling event commanded at
    /// absolute time `started` on `replica`.
    ///
    /// Phase placement prefers the measured `(start, end)` offsets in
    /// [`ScalingMetrics::stage_marks`](crate::metrics::ScalingMetrics)
    /// (populated by ElasticMoE from the HMM's `ScaleStats`); methods
    /// without marks fall back to laying their sequential `stages` list
    /// end-to-end from the command time — faithful for the serial
    /// baselines, whose phases genuinely are back-to-back.
    pub fn scaling_event(
        &mut self,
        replica: usize,
        event: usize,
        started: f64,
        outcome: &ScalingOutcome,
    ) {
        let m = &outcome.metrics;
        let pause = outcome
            .intake_pause
            .map(|(a, b)| (started + a, started + b));
        let marks: Vec<(String, f64, f64)> = if !m.stage_marks.is_empty() {
            m.stage_marks.clone()
        } else {
            let mut t = 0.0;
            m.stages
                .iter()
                .map(|(name, dur)| {
                    let s = t;
                    t += dur;
                    (name.clone(), s, t)
                })
                .collect()
        };
        for (name, s0, s1) in marks {
            let (a, b) = (started + s0, started + s1);
            let cat = match pause {
                Some((p0, _)) if (a + b) / 2.0 >= p0 => CAT_SWITCHOVER,
                _ => CAT_CONCURRENT,
            };
            self.span(
                replica,
                Some(event),
                format!("scale{event}/{name}"),
                cat,
                a,
                b,
            );
        }
        if let Some((p0, p1)) = pause {
            self.span(
                replica,
                Some(event),
                format!("scale{event}/intake_pause"),
                CAT_WINDOW,
                p0,
                p1,
            );
        }
        if let Some((d0, d1)) = outcome.downtime {
            self.span(
                replica,
                Some(event),
                format!("scale{event}/downtime"),
                CAT_WINDOW,
                started + d0,
                started + d1,
            );
        }
        if let Some(abort) = &outcome.aborted {
            self.instant(
                replica,
                format!("scale{event}/aborted: {}", abort.reason),
                started + outcome.ready_after,
            );
        }
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    pub fn instants(&self) -> &[Instant] {
        &self.instants
    }

    /// Spans belonging to one scaling event, in insertion order.
    pub fn for_event(&self, event: usize) -> Vec<&Span> {
        self.spans
            .iter()
            .filter(|s| s.event == Some(event))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParallelConfig;
    use crate::metrics::ScalingMetrics;

    fn outcome_with(
        stages: Vec<(&str, f64)>,
        marks: Vec<(&str, f64, f64)>,
        pause: Option<(f64, f64)>,
        ready_after: f64,
    ) -> ScalingOutcome {
        let mut m = ScalingMetrics::default();
        for (n, d) in stages {
            m.stage(n, d);
        }
        for (n, a, b) in marks {
            m.stage_mark(n, a, b);
        }
        ScalingOutcome {
            metrics: m,
            ready_after,
            downtime: None,
            intake_pause: pause,
            transition_derate: 1.0,
            preserves_inflight: true,
            kv_handoff: None,
            new_parallel: ParallelConfig::standard(2, 2, (0..4).collect())
                .unwrap(),
            peak_devices: 0,
            plan_audit: None,
            aborted: None,
        }
    }

    #[test]
    fn marks_classify_against_pause_window() {
        // Concurrent chain [0, 8], switchover [8, 10], pause (8, 10).
        let o = outcome_with(
            vec![],
            vec![
                ("hmm_expert_migration", 0.0, 6.0),
                ("warmup", 6.0, 8.0),
                ("switchover", 8.0, 10.0),
            ],
            Some((8.0, 10.0)),
            10.0,
        );
        let mut tr = SpanTracker::default();
        tr.scaling_event(0, 0, 100.0, &o);
        let spans = tr.for_event(0);
        assert_eq!(spans.len(), 4); // 3 phases + pause window
        assert_eq!(spans[0].cat, CAT_CONCURRENT);
        assert_eq!(spans[0].start, 100.0);
        assert_eq!(spans[0].end, 106.0);
        assert_eq!(spans[1].cat, CAT_CONCURRENT);
        assert_eq!(spans[2].cat, CAT_SWITCHOVER);
        assert_eq!(spans[2].start, 108.0);
        assert_eq!(spans[3].cat, CAT_WINDOW);
        assert_eq!((spans[3].start, spans[3].end), (108.0, 110.0));
    }

    #[test]
    fn sequential_fallback_lays_stages_end_to_end() {
        // No marks: stages laid back-to-back; pause covers the whole
        // transition, so every phase is in the switchover window.
        let o = outcome_with(
            vec![("teardown", 2.0), ("reload", 3.0)],
            vec![],
            Some((0.0, 5.0)),
            5.0,
        );
        let mut tr = SpanTracker::default();
        tr.scaling_event(1, 3, 10.0, &o);
        let spans = tr.for_event(3);
        assert_eq!(spans[0].name, "scale3/teardown");
        assert_eq!((spans[0].start, spans[0].end), (10.0, 12.0));
        assert_eq!((spans[1].start, spans[1].end), (12.0, 15.0));
        assert_eq!(spans[0].cat, CAT_SWITCHOVER);
        assert_eq!(spans[1].cat, CAT_SWITCHOVER);
    }

    #[test]
    fn open_intervals_close_or_finish() {
        let mut tr = SpanTracker::default();
        tr.begin(2, "park", 1.0);
        tr.begin(3, "park", 2.0);
        tr.end(2, "park", 4.0);
        tr.finish(9.0);
        let spans = tr.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(
            (spans[0].replica, spans[0].start, spans[0].end),
            (2, 1.0, 4.0)
        );
        assert_eq!(
            (spans[1].replica, spans[1].start, spans[1].end),
            (3, 2.0, 9.0)
        );
        assert!(spans.iter().all(|s| s.cat == CAT_LIFECYCLE));
    }
}
