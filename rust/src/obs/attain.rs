//! SLO attainment accounting: windowed attainment time series, burn
//! rate over a rolling horizon, and device-second cost attribution per
//! scaling event.
//!
//! This is the interpretation layer over [`crate::metrics::recorder`]:
//! the recorder stores raw per-request facts; this module buckets them
//! into conservation-checked windows (attained + violated + in-flight
//! == arrived, per window, per tenant, per pool) and prices scaling
//! decisions in device-seconds so the attainment-vs-cost tradeoff the
//! paper optimizes becomes a first-class, reportable quantity.
//!
//! Everything here is a pure function of already-recorded data — no
//! simulator state is read or written, so the PR 7 determinism-
//! neutrality contract is untouched by construction.

use std::collections::BTreeMap;

use crate::config::SloConfig;
use crate::metrics::recorder::RequestMetrics;

/// One attainment window `[t0, t1)`, bucketed by *arrival* (the paper's
/// timeline plots bucket by arrival). A request counts as *resolved* in
/// this window once its finish (or drop) time is `<= t1`; unresolved
/// arrivals are *in-flight*. The three buckets partition the arrivals,
/// so `attained + violated + in_flight == arrived` holds by
/// construction — [`WindowAttainment::conserves`] re-checks it as the
/// property-test surface.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WindowAttainment {
    pub t0: f64,
    pub t1: f64,
    /// Requests that arrived in `[t0, t1)`.
    pub arrived: usize,
    /// Resolved within the window horizon and met their SLO.
    pub attained: usize,
    /// Resolved but dropped or SLO-missed.
    pub violated: usize,
    /// Arrived in the window but still running at `t1`.
    pub in_flight: usize,
}

impl WindowAttainment {
    /// Attainment over *resolved* requests (NaN when none resolved yet
    /// — an all-in-flight window has no verdict, matching the
    /// estimator's NaN-means-no-traffic convention).
    pub fn attainment(&self) -> f64 {
        let resolved = self.attained + self.violated;
        if resolved == 0 {
            return f64::NAN;
        }
        self.attained as f64 / resolved as f64
    }

    /// The conservation law: every arrival is in exactly one bucket.
    pub fn conserves(&self) -> bool {
        self.attained + self.violated + self.in_flight == self.arrived
    }
}

/// Bucket `reqs` into consecutive `width`-second windows covering
/// `[0, end)` (the last window is clipped to `end`). Windows with no
/// arrivals still appear — a flat timeline renders gaps honestly.
pub fn windows(
    reqs: &[RequestMetrics],
    slo: &SloConfig,
    width: f64,
    end: f64,
) -> Vec<WindowAttainment> {
    assert!(width > 0.0, "window width must be positive");
    let mut out = Vec::new();
    let mut t0 = 0.0;
    while t0 < end {
        let t1 = (t0 + width).min(end);
        let mut w = WindowAttainment {
            t0,
            t1,
            arrived: 0,
            attained: 0,
            violated: 0,
            in_flight: 0,
        };
        for m in reqs.iter().filter(|m| m.arrival >= t0 && m.arrival < t1)
        {
            w.arrived += 1;
            if m.finished <= t1 {
                if !m.dropped && slo.met(m.ttft, m.tpot) {
                    w.attained += 1;
                } else {
                    w.violated += 1;
                }
            } else {
                w.in_flight += 1;
            }
        }
        out.push(w);
        t0 = t1;
    }
    out
}

/// Windowed series per group, keyed by an arbitrary partition of the
/// requests (`None` keys are skipped). Per-tenant and per-pool series
/// are both instances: [`per_tenant`] keys by the tenant tag; a
/// disaggregated report keys by handoff membership.
pub fn windows_by(
    reqs: &[RequestMetrics],
    slo: &SloConfig,
    width: f64,
    end: f64,
    key: impl Fn(&RequestMetrics) -> Option<String>,
) -> BTreeMap<String, Vec<WindowAttainment>> {
    let mut groups: BTreeMap<String, Vec<RequestMetrics>> = BTreeMap::new();
    for m in reqs {
        if let Some(k) = key(m) {
            groups.entry(k).or_default().push(*m);
        }
    }
    groups
        .into_iter()
        .map(|(k, g)| (k, windows(&g, slo, width, end)))
        .collect()
}

/// Per-tenant attainment series (keys `"tenant:<id>"`, sorted).
pub fn per_tenant(
    reqs: &[RequestMetrics],
    slo: &SloConfig,
    width: f64,
    end: f64,
) -> BTreeMap<String, Vec<WindowAttainment>> {
    windows_by(reqs, slo, width, end, |m| {
        Some(format!("tenant:{}", m.tenant))
    })
}

/// Error-budget burn rate at time `t` over the trailing `horizon`
/// seconds: the violation rate among resolved requests in windows
/// ending in `(t - horizon, t]`, divided by the SLO's error budget
/// `1 - target_attainment`. Burn 1.0 = consuming budget exactly as
/// provisioned; > 1.0 = on track to exhaust it (page someone); 0.0 when
/// nothing resolved in the horizon.
pub fn burn_rate(
    windows: &[WindowAttainment],
    target_attainment: f64,
    horizon: f64,
    t: f64,
) -> f64 {
    let (mut violated, mut resolved) = (0usize, 0usize);
    for w in windows {
        if w.t1 <= t && w.t1 > t - horizon {
            violated += w.violated;
            resolved += w.attained + w.violated;
        }
    }
    if resolved == 0 {
        return 0.0;
    }
    let budget = (1.0 - target_attainment).max(1e-9);
    (violated as f64 / resolved as f64) / budget
}

/// Integral of a device-count step timeline over `[a, b]`. `timeline`
/// is `(t, devices)` change points (each value holds until the next
/// entry); `run_end` clips the final segment.
pub fn device_seconds_between(
    timeline: &[(f64, usize)],
    a: f64,
    b: f64,
    run_end: f64,
) -> f64 {
    let b = b.min(run_end);
    if b <= a || timeline.is_empty() {
        return 0.0;
    }
    let mut total = 0.0;
    for (i, &(t0, d)) in timeline.iter().enumerate() {
        let t1 = timeline.get(i + 1).map(|&(t, _)| t).unwrap_or(run_end);
        let lo = t0.max(a);
        let hi = t1.min(b);
        if hi > lo {
            total += (hi - lo) * d as f64;
        }
    }
    total
}

/// One scaling event priced in device-seconds and bracketed by the
/// attainment it interrupted: `attainment_before` is the window ending
/// at the command, `attainment_after` the window starting at readiness
/// (NaN when no traffic resolved in the bracket). `device_seconds` is
/// the capacity held *during* the transition — what the scaling
/// decision cost while it was in flight.
#[derive(Debug, Clone, Copy)]
pub struct EventCost {
    pub event: usize,
    /// Scale-command time.
    pub start: f64,
    /// Readiness (new instance serving / rollback complete).
    pub done: f64,
    /// Device-seconds held over `[start, done]`.
    pub device_seconds: f64,
    pub attainment_before: f64,
    pub attainment_after: f64,
}

/// Price each scaling event (`(event_id, start, done)`) against the
/// device timeline and bracket it with `width`-second attainment
/// windows on both sides.
pub fn event_costs(
    reqs: &[RequestMetrics],
    slo: &SloConfig,
    timeline: &[(f64, usize)],
    events: &[(usize, f64, f64)],
    width: f64,
    run_end: f64,
) -> Vec<EventCost> {
    events
        .iter()
        .map(|&(event, start, done)| {
            let before =
                one_window(reqs, slo, (start - width).max(0.0), start);
            let after =
                one_window(reqs, slo, done, (done + width).min(run_end));
            EventCost {
                event,
                start,
                done,
                device_seconds: device_seconds_between(
                    timeline, start, done, run_end,
                ),
                attainment_before: before.attainment(),
                attainment_after: after.attainment(),
            }
        })
        .collect()
}

/// A single ad-hoc window `[t0, t1)` (no grid alignment).
pub fn one_window(
    reqs: &[RequestMetrics],
    slo: &SloConfig,
    t0: f64,
    t1: f64,
) -> WindowAttainment {
    let mut w = WindowAttainment {
        t0,
        t1,
        arrived: 0,
        attained: 0,
        violated: 0,
        in_flight: 0,
    };
    for m in reqs.iter().filter(|m| m.arrival >= t0 && m.arrival < t1) {
        w.arrived += 1;
        if m.finished <= t1 {
            if !m.dropped && slo.met(m.ttft, m.tpot) {
                w.attained += 1;
            } else {
                w.violated += 1;
            }
        } else {
            w.in_flight += 1;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(
        id: u64,
        arrival: f64,
        finished: f64,
        ttft: f64,
        dropped: bool,
        tenant: u32,
    ) -> RequestMetrics {
        RequestMetrics {
            id,
            arrival,
            finished,
            ttft,
            tpot: 0.1,
            tokens: 8,
            dropped,
            tenant,
        }
    }

    fn slo() -> SloConfig {
        SloConfig::new(1.0, 0.5)
    }

    #[test]
    fn windows_bucket_and_conserve() {
        let reqs = [
            req(1, 0.5, 2.0, 0.2, false, 0),  // attained in [0,10)
            req(2, 1.0, 3.0, 5.0, false, 0),  // ttft violation
            req(3, 2.0, 50.0, 0.2, false, 0), // in-flight at t=10
            req(4, 12.0, 13.0, 0.2, true, 1), // dropped -> violated
        ];
        let ws = windows(&reqs, &slo(), 10.0, 20.0);
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].arrived, 3);
        assert_eq!(ws[0].attained, 1);
        assert_eq!(ws[0].violated, 1);
        assert_eq!(ws[0].in_flight, 1);
        assert!(ws[0].conserves());
        assert_eq!(ws[1].arrived, 1);
        assert_eq!(ws[1].violated, 1);
        assert!(ws[1].conserves());
        assert!((ws[0].attainment() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_window_has_nan_attainment() {
        let ws = windows(&[], &slo(), 5.0, 10.0);
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].arrived, 0);
        assert!(ws[0].attainment().is_nan());
        assert!(ws[0].conserves());
    }

    #[test]
    fn per_tenant_partitions() {
        let reqs = [
            req(1, 0.5, 1.0, 0.2, false, 0),
            req(2, 0.6, 1.0, 0.2, false, 1),
            req(3, 0.7, 1.0, 9.0, false, 1),
        ];
        let by = per_tenant(&reqs, &slo(), 10.0, 10.0);
        assert_eq!(by.len(), 2);
        assert_eq!(by["tenant:0"][0].arrived, 1);
        assert_eq!(by["tenant:1"][0].arrived, 2);
        assert_eq!(by["tenant:1"][0].violated, 1);
        let total: usize =
            by.values().map(|ws| ws[0].arrived).sum();
        assert_eq!(total, reqs.len(), "partition covers every request");
    }

    #[test]
    fn burn_rate_scales_with_the_error_budget() {
        // 10% violations against a 90% target = burning the budget at
        // exactly the provisioned rate.
        let reqs: Vec<RequestMetrics> = (0..10)
            .map(|i| {
                req(i, 1.0, 2.0, if i == 0 { 9.0 } else { 0.2 }, false, 0)
            })
            .collect();
        let ws = windows(&reqs, &SloConfig::new(1.0, 0.5), 10.0, 10.0);
        let b = burn_rate(&ws, 0.9, 100.0, 10.0);
        assert!((b - 1.0).abs() < 1e-9, "{b}");
        // A stricter 99% target makes the same violations burn 10x.
        let b99 = burn_rate(&ws, 0.99, 100.0, 10.0);
        assert!((b99 - 10.0).abs() < 1e-6, "{b99}");
        // Outside the horizon: nothing resolved, zero burn.
        assert_eq!(burn_rate(&ws, 0.9, 5.0, 100.0), 0.0);
    }

    #[test]
    fn device_seconds_integrates_the_step_timeline() {
        let tl = [(0.0, 4), (10.0, 6), (20.0, 2)];
        // [5, 15]: 5s @ 4 + 5s @ 6 = 50.
        let ds = device_seconds_between(&tl, 5.0, 15.0, 30.0);
        assert!((ds - 50.0).abs() < 1e-9, "{ds}");
        // Clipped by run end.
        let tail = device_seconds_between(&tl, 25.0, 99.0, 30.0);
        assert!((tail - 10.0).abs() < 1e-9, "{tail}");
        assert_eq!(device_seconds_between(&tl, 5.0, 5.0, 30.0), 0.0);
        assert_eq!(device_seconds_between(&[], 0.0, 10.0, 30.0), 0.0);
    }

    #[test]
    fn event_costs_bracket_attainment() {
        let reqs = [
            req(1, 8.0, 9.0, 0.2, false, 0),   // before: attained
            req(2, 9.0, 9.5, 9.0, false, 0),   // before: violated
            req(3, 21.0, 22.0, 0.2, false, 0), // after: attained
        ];
        let tl = [(0.0, 4), (10.0, 8)];
        let costs = events_fixture(&reqs, &tl);
        assert_eq!(costs.len(), 1);
        let c = &costs[0];
        assert_eq!(c.event, 0);
        // [10, 20] at 8 devices.
        assert!((c.device_seconds - 80.0).abs() < 1e-9);
        assert!((c.attainment_before - 0.5).abs() < 1e-9);
        assert!((c.attainment_after - 1.0).abs() < 1e-9);
    }

    fn events_fixture(
        reqs: &[RequestMetrics],
        tl: &[(f64, usize)],
    ) -> Vec<EventCost> {
        event_costs(
            reqs,
            &slo(),
            tl,
            &[(0, 10.0, 20.0)],
            10.0,
            40.0,
        )
    }
}
