//! Deterministic fault injection for scaling events.
//!
//! A [`FaultPlan`] is a fully deterministic schedule of faults armed for
//! specific scaling events; a [`FaultInjector`] consumes it. The HMM
//! consults the injector at every fabric leg and device touch of
//! [`crate::hmm::HmmControl::execute_plan`], and at plan time for the
//! migration byte budget; the serving simulators drain the fired-fault
//! records into the run's event trace ([`super::trace`]).
//!
//! Faults come in two flavours:
//!
//! - **Aborting** ([`FaultKind::P2pLinkFail`], [`FaultKind::KvCopyFail`],
//!   [`FaultKind::DeviceLoss`]) — the op fails, the HMM rolls the whole
//!   plan back, and the scaling event surfaces as aborted.
//! - **Degrading** ([`FaultKind::HbmPressure`], [`FaultKind::Straggler`])
//!   — the event completes, but with a shrunken migration budget (more
//!   recompute verdicts) or stretched fabric legs (longer windows).
//! - **Control-plane** ([`FaultKind::HeartbeatLoss`],
//!   [`FaultKind::StaleObservedState`], [`FaultKind::DuplicateCommand`])
//!   — the data plane is untouched; instead the fleet reconciler's
//!   inputs (heartbeats, observed-state snapshots) or outputs (step
//!   enactment) are corrupted. These faults are scoped by their own
//!   counters — heartbeat index per replica, reconcile-round index —
//!   not by [`FaultInjector::begin_event`]'s scaling-event scope, and
//!   the reconciler must converge back to spec after they stop firing
//!   (`chaos::invariants::check_reconcile_convergence`).
//!
//! The trace invariants ([`super::invariants`]) must hold either way.

use std::collections::{BTreeMap, BTreeSet};

use crate::device::DeviceId;

/// One injectable fault kind.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The `after_legs`-th fabric leg of the event (1-based, counting
    /// attention P2P, expert migration and live-KV copy legs in execution
    /// order) fails mid-copy. The partially transferred bytes are
    /// discarded and the event aborts.
    P2pLinkFail {
        /// 1-based index of the first leg that fails.
        after_legs: usize,
    },
    /// Like [`FaultKind::P2pLinkFail`], but counting only live-KV copy
    /// legs — so tests can fault the KV handoff window deterministically
    /// regardless of how many weight legs the plan happens to contain.
    KvCopyFail {
        /// 1-based index of the first KV copy leg that fails.
        after_legs: usize,
    },
    /// Device `dev` drops out: the first leg touching it (as source or
    /// destination) or the first allocation targeting it fails, and the
    /// event aborts.
    DeviceLoss { dev: DeviceId },
    /// An HBM pressure spike shrinks the event's migration byte budget to
    /// `budget_factor` (clamped to `0.0..=1.0`) of its configured value.
    /// Degrades — the KV planner falls back to recompute verdicts once
    /// the shrunken budget runs out — but never aborts.
    HbmPressure { budget_factor: f64 },
    /// Device `dev` is a straggler: every fabric leg touching it takes
    /// `stretch`× its nominal time. Degrades (longer concurrent phase and
    /// switchover window), never aborts.
    Straggler { dev: DeviceId, stretch: f64 },
    /// Control plane: `replica`'s heartbeats are suppressed for `beats`
    /// consecutive beats, starting at that replica's `event`-th beat
    /// (0-based — the [`FaultEntry::event`] field indexes beats here,
    /// not scaling events). Once staleness passes the reconciler's
    /// deadline the replica is marked suspect and evicted, and its spec
    /// slot is re-planned. Never aborts a scaling event.
    HeartbeatLoss { replica: usize, beats: usize },
    /// Control plane: for `ticks` reconcile rounds starting at the
    /// `event`-th round (0-based round index), the reconciler plans
    /// against the *previous* round's observed snapshot. Idempotent
    /// planning must turn the resulting stale steps into checked
    /// no-ops. Never aborts.
    StaleObservedState { ticks: usize },
    /// Control plane: the step batch of the `event`-th reconcile round
    /// (0-based round index) is enacted twice. The second enactment
    /// must be a checked no-op. Never aborts.
    DuplicateCommand,
}

impl FaultKind {
    /// Short stable label for reports and trace rendering.
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::P2pLinkFail { .. } => "p2p-link-fail",
            FaultKind::KvCopyFail { .. } => "kv-copy-fail",
            FaultKind::DeviceLoss { .. } => "device-loss",
            FaultKind::HbmPressure { .. } => "hbm-pressure",
            FaultKind::Straggler { .. } => "straggler",
            FaultKind::HeartbeatLoss { .. } => "heartbeat-loss",
            FaultKind::StaleObservedState { .. } => "stale-observed-state",
            FaultKind::DuplicateCommand => "duplicate-command",
        }
    }

    /// Whether this fault aborts the scaling event (vs degrading it).
    pub fn aborts(&self) -> bool {
        matches!(
            self,
            FaultKind::P2pLinkFail { .. }
                | FaultKind::KvCopyFail { .. }
                | FaultKind::DeviceLoss { .. }
        )
    }
}

/// One scheduled fault: arm `kind` for the `event`-th scaling event
/// (0-based count of plans drawn since the injector was built).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEntry {
    pub event: usize,
    pub kind: FaultKind,
}

/// A deterministic fault schedule.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub entries: Vec<FaultEntry>,
}

impl FaultPlan {
    /// The empty schedule (no faults ever fire).
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// Arm a single fault for one scaling event.
    pub fn single(event: usize, kind: FaultKind) -> Self {
        FaultPlan {
            entries: vec![FaultEntry { event, kind }],
        }
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// A fault that actually fired.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultRecord {
    /// Index the fault fired at: the scaling-event ordinal for
    /// data-plane faults, the heartbeat-beat or reconcile-round index
    /// for control-plane faults.
    pub event: usize,
    pub kind: FaultKind,
}

/// Control-plane directives for one reconcile round, returned by
/// [`FaultInjector::begin_round`].
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct RoundFaults {
    /// The reconciler must plan against the previous round's observed
    /// snapshot ([`FaultKind::StaleObservedState`]).
    pub stale: bool,
    /// The planned step batch is enacted twice
    /// ([`FaultKind::DuplicateCommand`]).
    pub duplicate: bool,
}

/// Consumes a [`FaultPlan`] across a run's scaling events.
///
/// The event scope is opened by [`Self::begin_event`] — called by the HMM
/// whenever a scaling plan is drawn — and all subsequent consultations
/// (`on_leg`, `on_kv_leg`, `on_device`, `budget_factor`, `stretch`) match
/// faults armed for that event. Each armed fault fires at most once per
/// event; fired faults accumulate until [`Self::take_fired`] drains them
/// (the simulators do this into the trace).
#[derive(Debug, Default)]
pub struct FaultInjector {
    plan: FaultPlan,
    /// Scaling events planned so far; the next event gets this index.
    events_seen: usize,
    /// Current event scope (`None` before the first `begin_event`).
    event: Option<usize>,
    /// Fabric legs consulted in the current event (weight + KV).
    legs: usize,
    /// Live-KV copy legs consulted in the current event.
    kv_legs: usize,
    /// Plan-entry indices that already fired in the current event.
    fired_entries: BTreeSet<usize>,
    /// Control-plane scope: heartbeat beats consulted so far, per
    /// replica (independent of the scaling-event scope).
    beats: BTreeMap<usize, usize>,
    /// Control-plane scope: reconcile rounds opened so far.
    rounds: usize,
    /// Plan-entry indices of control-plane faults already recorded
    /// (never reset — a loss window is one fault, not one per beat).
    fired_cp: BTreeSet<usize>,
    fired: Vec<FaultRecord>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        FaultInjector {
            plan,
            ..Default::default()
        }
    }

    /// Open the scope of the next scaling event. Called once per event,
    /// when the HMM draws the plan.
    pub fn begin_event(&mut self) {
        self.event = Some(self.events_seen);
        self.events_seen += 1;
        self.legs = 0;
        self.kv_legs = 0;
        self.fired_entries.clear();
    }

    /// Index of the current event scope (`None` before the first plan).
    pub fn event_index(&self) -> Option<usize> {
        self.event
    }

    /// Faults armed for the current event, with their plan-entry indices.
    fn armed(&self) -> Vec<(usize, FaultKind)> {
        let Some(ev) = self.event else {
            return Vec::new();
        };
        self.plan
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.event == ev)
            .map(|(i, e)| (i, e.kind))
            .collect()
    }

    fn fire(&mut self, entry: usize, kind: FaultKind) {
        if self.fired_entries.insert(entry) {
            self.fired.push(FaultRecord {
                event: self.event.unwrap_or(0),
                kind,
            });
        }
    }

    /// Effective migration-budget factor for the current event: the
    /// minimum of all armed [`FaultKind::HbmPressure`] factors (1.0 when
    /// none). Consulting records the pressure fault as fired.
    pub fn budget_factor(&mut self) -> f64 {
        let mut factor = 1.0f64;
        for (i, kind) in self.armed() {
            if let FaultKind::HbmPressure { budget_factor } = kind {
                factor = factor.min(budget_factor.clamp(0.0, 1.0));
                self.fire(i, kind);
            }
        }
        factor
    }

    /// Consult before a weight-plane fabric leg. `Some(fault)` means the
    /// leg fails and the event must abort.
    pub fn on_leg(&mut self, src: DeviceId, dst: DeviceId) -> Option<FaultKind> {
        self.legs += 1;
        let legs = self.legs;
        let hit = self.armed().into_iter().find(|&(_, kind)| match kind {
            FaultKind::P2pLinkFail { after_legs } => legs >= after_legs,
            FaultKind::DeviceLoss { dev } => dev == src || dev == dst,
            _ => false,
        });
        if let Some((i, kind)) = hit {
            self.fire(i, kind);
            return Some(kind);
        }
        None
    }

    /// Consult before a live-KV copy leg. KV-scoped faults are checked
    /// first; otherwise the leg also counts toward the global leg counter
    /// via [`Self::on_leg`].
    pub fn on_kv_leg(
        &mut self,
        src: DeviceId,
        dst: DeviceId,
    ) -> Option<FaultKind> {
        self.kv_legs += 1;
        let kv_legs = self.kv_legs;
        let hit = self.armed().into_iter().find(|&(_, kind)| {
            matches!(kind, FaultKind::KvCopyFail { after_legs } if kv_legs >= after_legs)
        });
        if let Some((i, kind)) = hit {
            self.fire(i, kind);
            return Some(kind);
        }
        self.on_leg(src, dst)
    }

    /// Consult when an op touches `dev` without a fabric leg (e.g. a KV
    /// cache allocation on a new device).
    pub fn on_device(&mut self, dev: DeviceId) -> Option<FaultKind> {
        let hit = self.armed().into_iter().find(|&(_, kind)| {
            matches!(kind, FaultKind::DeviceLoss { dev: d } if d == dev)
        });
        if let Some((i, kind)) = hit {
            self.fire(i, kind);
            return Some(kind);
        }
        None
    }

    /// Straggler stretch factor (`>= 1.0`) for a fabric leg between `src`
    /// and `dst`. Consulting records the straggler fault as fired.
    pub fn stretch(&mut self, src: DeviceId, dst: DeviceId) -> f64 {
        let mut factor = 1.0f64;
        for (i, kind) in self.armed() {
            if let FaultKind::Straggler { dev, stretch } = kind {
                if dev == src || dev == dst {
                    factor = factor.max(stretch.max(1.0));
                    self.fire(i, kind);
                }
            }
        }
        factor
    }

    /// Record a control-plane fault as fired at `at` (a beat or round
    /// index), once per plan entry across the whole run.
    fn fire_cp(&mut self, entry: usize, at: usize, kind: FaultKind) {
        if self.fired_cp.insert(entry) {
            self.fired.push(FaultRecord { event: at, kind });
        }
    }

    /// Consult at one heartbeat of `replica` (control-plane scope —
    /// beats are counted per replica, independent of
    /// [`Self::begin_event`]). Returns `true` when this beat is lost:
    /// an armed [`FaultKind::HeartbeatLoss`] window `[event, event +
    /// beats)` covers the replica's current beat index.
    pub fn on_heartbeat(&mut self, replica: usize) -> bool {
        let beat = {
            let b = self.beats.entry(replica).or_insert(0);
            let cur = *b;
            *b += 1;
            cur
        };
        let hits: Vec<(usize, usize, FaultKind)> = self
            .plan
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| {
                matches!(e.kind, FaultKind::HeartbeatLoss { replica: r, beats }
                    if r == replica && beat >= e.event && beat < e.event + beats)
            })
            .map(|(i, e)| (i, e.event, e.kind))
            .collect();
        let lost = !hits.is_empty();
        for (i, at, kind) in hits {
            self.fire_cp(i, at, kind);
        }
        lost
    }

    /// Open the next reconcile round (control-plane scope) and return
    /// the round's directives: whether the reconciler sees a stale
    /// observed snapshot, and whether its step batch is enacted twice.
    pub fn begin_round(&mut self) -> RoundFaults {
        let round = self.rounds;
        self.rounds += 1;
        let mut out = RoundFaults::default();
        let hits: Vec<(usize, usize, FaultKind)> = self
            .plan
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| match e.kind {
                FaultKind::StaleObservedState { ticks } => {
                    round >= e.event && round < e.event + ticks
                }
                FaultKind::DuplicateCommand => round == e.event,
                _ => false,
            })
            .map(|(i, e)| (i, e.event, e.kind))
            .collect();
        for (i, at, kind) in hits {
            match kind {
                FaultKind::StaleObservedState { .. } => out.stale = true,
                FaultKind::DuplicateCommand => out.duplicate = true,
                _ => unreachable!(),
            }
            self.fire_cp(i, at, kind);
        }
        out
    }

    /// Drain the fired-fault records accumulated so far.
    pub fn take_fired(&mut self) -> Vec<FaultRecord> {
        std::mem::take(&mut self.fired)
    }

    /// Faults fired so far and not yet drained.
    pub fn fired_count(&self) -> usize {
        self.fired.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_event_scope_never_fires() {
        let mut inj = FaultInjector::new(FaultPlan::single(
            0,
            FaultKind::P2pLinkFail { after_legs: 1 },
        ));
        assert!(inj.on_leg(0, 1).is_none(), "no scope, no fault");
        assert_eq!(inj.budget_factor(), 1.0);
        assert_eq!(inj.fired_count(), 0);
    }

    #[test]
    fn p2p_fault_fires_on_the_right_leg_and_event() {
        let mut inj = FaultInjector::new(FaultPlan::single(
            1,
            FaultKind::P2pLinkFail { after_legs: 3 },
        ));
        inj.begin_event(); // event 0: not armed
        for _ in 0..5 {
            assert!(inj.on_leg(0, 1).is_none());
        }
        inj.begin_event(); // event 1: armed
        assert!(inj.on_leg(0, 1).is_none());
        assert!(inj.on_leg(0, 1).is_none());
        let f = inj.on_leg(0, 1).expect("third leg must fail");
        assert!(f.aborts());
        assert_eq!(inj.take_fired().len(), 1);
        assert!(inj.take_fired().is_empty(), "drained");
    }

    #[test]
    fn kv_scope_counts_only_kv_legs() {
        let mut inj = FaultInjector::new(FaultPlan::single(
            0,
            FaultKind::KvCopyFail { after_legs: 2 },
        ));
        inj.begin_event();
        // Weight legs never trip a KV-scoped fault.
        for _ in 0..10 {
            assert!(inj.on_leg(2, 3).is_none());
        }
        assert!(inj.on_kv_leg(2, 3).is_none());
        assert!(inj.on_kv_leg(2, 3).is_some(), "second KV leg fails");
    }

    #[test]
    fn device_loss_hits_legs_and_allocations() {
        let mut inj = FaultInjector::new(FaultPlan::single(
            0,
            FaultKind::DeviceLoss { dev: 4 },
        ));
        inj.begin_event();
        assert!(inj.on_leg(0, 1).is_none());
        assert!(inj.on_leg(0, 4).is_some(), "leg into the lost device");
        inj.begin_event();
        assert!(inj.on_device(3).is_none());
        assert!(inj.on_device(4).is_none(), "event 1 is not armed");
    }

    #[test]
    fn pressure_and_straggler_degrade_without_aborting() {
        let mut inj = FaultInjector::new(FaultPlan {
            entries: vec![
                FaultEntry {
                    event: 0,
                    kind: FaultKind::HbmPressure { budget_factor: 0.25 },
                },
                FaultEntry {
                    event: 0,
                    kind: FaultKind::Straggler { dev: 5, stretch: 4.0 },
                },
            ],
        });
        inj.begin_event();
        assert_eq!(inj.budget_factor(), 0.25);
        assert_eq!(inj.stretch(5, 1), 4.0);
        assert_eq!(inj.stretch(0, 1), 1.0, "legs off the straggler");
        assert!(inj.on_leg(5, 1).is_none(), "degrading faults never abort");
        // Each armed fault fires (is recorded) exactly once per event.
        assert_eq!(inj.budget_factor(), 0.25);
        assert_eq!(inj.stretch(5, 1), 4.0);
        assert_eq!(inj.take_fired().len(), 2);
    }

    #[test]
    fn labels_and_abort_classes() {
        assert!(FaultKind::DeviceLoss { dev: 0 }.aborts());
        assert!(FaultKind::KvCopyFail { after_legs: 1 }.aborts());
        assert!(!FaultKind::HbmPressure { budget_factor: 0.5 }.aborts());
        assert!(!FaultKind::Straggler { dev: 0, stretch: 2.0 }.aborts());
        assert_eq!(
            FaultKind::P2pLinkFail { after_legs: 1 }.label(),
            "p2p-link-fail"
        );
        // Control-plane faults never abort a scaling event.
        assert!(!FaultKind::HeartbeatLoss { replica: 0, beats: 3 }.aborts());
        assert!(!FaultKind::StaleObservedState { ticks: 2 }.aborts());
        assert!(!FaultKind::DuplicateCommand.aborts());
        assert_eq!(
            FaultKind::StaleObservedState { ticks: 2 }.label(),
            "stale-observed-state"
        );
    }

    #[test]
    fn heartbeat_loss_covers_its_window_per_replica() {
        let mut inj = FaultInjector::new(FaultPlan::single(
            2,
            FaultKind::HeartbeatLoss { replica: 1, beats: 3 },
        ));
        // Replica 0 is never armed.
        for _ in 0..6 {
            assert!(!inj.on_heartbeat(0));
        }
        // Replica 1 loses exactly beats 2, 3 and 4.
        let lost: Vec<bool> = (0..7).map(|_| inj.on_heartbeat(1)).collect();
        assert_eq!(lost, [false, false, true, true, true, false, false]);
        // One loss window = one fired record, stamped with the first
        // suppressed beat index.
        let fired = inj.take_fired();
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].event, 2);
    }

    #[test]
    fn round_faults_hit_their_round_windows() {
        let mut inj = FaultInjector::new(FaultPlan {
            entries: vec![
                FaultEntry {
                    event: 1,
                    kind: FaultKind::StaleObservedState { ticks: 2 },
                },
                FaultEntry { event: 2, kind: FaultKind::DuplicateCommand },
            ],
        });
        let rounds: Vec<RoundFaults> =
            (0..4).map(|_| inj.begin_round()).collect();
        assert!(!rounds[0].stale && !rounds[0].duplicate);
        assert!(rounds[1].stale && !rounds[1].duplicate);
        assert!(rounds[2].stale && rounds[2].duplicate);
        assert!(!rounds[3].stale && !rounds[3].duplicate);
        // Each armed entry records exactly once across its window.
        assert_eq!(inj.take_fired().len(), 2);
    }

    #[test]
    fn control_plane_scope_is_independent_of_event_scope() {
        let mut inj = FaultInjector::new(FaultPlan::single(
            0,
            FaultKind::HeartbeatLoss { replica: 0, beats: 1 },
        ));
        // No begin_event needed: control-plane consults have their own
        // counters, and data-plane consults ignore control-plane kinds.
        assert!(inj.on_heartbeat(0));
        inj.begin_event();
        assert!(inj.on_leg(0, 1).is_none());
        assert!(inj.on_device(0).is_none());
        assert_eq!(inj.budget_factor(), 1.0);
    }
}
