//! Structured event trace of a serving run.
//!
//! Both simulators ([`crate::coordinator::ServingSim`],
//! [`crate::coordinator::FleetSim`]) emit a [`Trace`] alongside their
//! metrics: every arrival, scale command (with its declared intake-pause
//! window and plan audit), fault firing, intake-pause edge,
//! suspend/resume, per-sequence switchover disposition, and finish. The
//! trace is the machine-checkable record the conformance checkers
//! ([`super::invariants`]) run over — the point is that correctness
//! claims ("no token loss", "blocks conserved even across aborts") are
//! verified against what the run *actually did*, not against what the
//! scaling method promised.

use crate::tier::TierLevel;

use super::faults::FaultKind;

/// Plan-level accounting of one scaling event, captured when the command
/// is issued (rides in [`crate::scaling::ScalingOutcome::plan_audit`]).
/// Present whenever the plan was drawn against a live KV snapshot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanAudit {
    /// Live KV blocks at the snapshot — the conservation baseline.
    pub snapshot_blocks: usize,
    /// Blocks that remap in place (zero-copy).
    pub kv_remapped_blocks: usize,
    /// Blocks that move over the fabric.
    pub kv_copied_blocks: usize,
    /// Blocks freed because their sequence re-prefills.
    pub kv_freed_blocks: usize,
    /// Bytes the KV copy legs move (charged against the budget).
    pub kv_copied_bytes: u64,
    /// Effective migration-byte budget the plan was drawn under (the
    /// configured budget after any HBM-pressure shrink).
    pub migration_budget_bytes: u64,
    /// Bytes moved by expert migrations (forced moves are budget-exempt;
    /// reported for the record, not checked against the budget).
    pub expert_migration_bytes: u64,
}

impl PlanAudit {
    /// Conservation invariant: every snapshot block accounted exactly
    /// once — remapped, copied, or freed.
    pub fn blocks_conserved(&self) -> bool {
        self.kv_remapped_blocks + self.kv_copied_blocks + self.kv_freed_blocks
            == self.snapshot_blocks
    }
}

/// One event in a serving run's trace. All times are absolute simulated
/// seconds; `event` is the run-wide scaling-event ordinal (0-based).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A request entered the system.
    Arrival { t: f64, id: u64, tokens: usize },
    /// A scale command was issued. `declared_pause` is the outcome's
    /// intake-pause window in absolute time — the bound the pause edges
    /// must respect.
    ScaleCommand {
        t: f64,
        event: usize,
        from_devices: usize,
        to_devices: usize,
        declared_pause: Option<(f64, f64)>,
    },
    /// The event's plan-level accounting (present when a live KV snapshot
    /// was planned against).
    PlanAudited {
        t: f64,
        event: usize,
        audit: PlanAudit,
    },
    /// An injected fault fired during the event.
    FaultFired {
        t: f64,
        event: usize,
        fault: FaultKind,
    },
    /// The active engine stopped admitting new requests.
    IntakePaused { t: f64, event: usize },
    /// Admission reopened (switchover completed or event aborted).
    IntakeResumed { t: f64, event: usize },
    /// A running sequence was frozen for the KV handoff window.
    Suspended { t: f64, event: usize, id: u64 },
    /// A suspended sequence resumed on its origin replica (event abort).
    Resumed { t: f64, event: usize, id: u64 },
    /// A drained sequence was adopted by the successor with its decode
    /// progress intact (`remap` = blocks stayed put; otherwise copied).
    Adopted {
        t: f64,
        event: usize,
        id: u64,
        remap: bool,
    },
    /// A drained sequence restarted from scratch on the successor.
    Restarted { t: f64, event: usize, id: u64 },
    /// The event completed: the successor serves `devices` devices.
    ScaleCompleted { t: f64, event: usize, devices: usize },
    /// The event aborted; `rolled_back` means the cluster returned to its
    /// pre-plan state and the old instance kept serving.
    ScaleAborted {
        t: f64,
        event: usize,
        rolled_back: bool,
        reason: String,
    },
    /// A request finished, having produced `tokens` decode tokens.
    Finished { t: f64, id: u64, tokens: usize },
    /// One weight unit crossed a residency-tier boundary on `replica`
    /// (demote, promote, stage, park, unpark — drained from the
    /// method's [`crate::tier::TieredWeightStore`] journal).
    TierShift {
        t: f64,
        replica: usize,
        tag: String,
        bytes: u64,
        from: TierLevel,
        to: TierLevel,
    },
    /// Independent audit point: `replica`'s host-DRAM *allocator*
    /// reports `dram_bytes` staged. The conservation invariant replays
    /// the journal ([`TraceEvent::TierShift`]) and must land exactly
    /// here — journal and allocator are separate accounting paths.
    TierAudit {
        t: f64,
        replica: usize,
        dram_bytes: u64,
    },
}

impl TraceEvent {
    /// The event's timestamp.
    pub fn t(&self) -> f64 {
        match self {
            TraceEvent::Arrival { t, .. }
            | TraceEvent::ScaleCommand { t, .. }
            | TraceEvent::PlanAudited { t, .. }
            | TraceEvent::FaultFired { t, .. }
            | TraceEvent::IntakePaused { t, .. }
            | TraceEvent::IntakeResumed { t, .. }
            | TraceEvent::Suspended { t, .. }
            | TraceEvent::Resumed { t, .. }
            | TraceEvent::Adopted { t, .. }
            | TraceEvent::Restarted { t, .. }
            | TraceEvent::ScaleCompleted { t, .. }
            | TraceEvent::ScaleAborted { t, .. }
            | TraceEvent::Finished { t, .. }
            | TraceEvent::TierShift { t, .. }
            | TraceEvent::TierAudit { t, .. } => *t,
        }
    }
}

/// An append-only event log for one simulated run.
#[derive(Debug, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn new() -> Self {
        Trace::default()
    }

    pub fn push(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Count events matching a predicate.
    pub fn count(&self, f: impl Fn(&TraceEvent) -> bool) -> usize {
        self.events.iter().filter(|e| f(e)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn audit_conservation() {
        let mut a = PlanAudit {
            snapshot_blocks: 10,
            kv_remapped_blocks: 6,
            kv_copied_blocks: 3,
            kv_freed_blocks: 1,
            kv_copied_bytes: 100,
            migration_budget_bytes: 1000,
            expert_migration_bytes: 0,
        };
        assert!(a.blocks_conserved());
        a.kv_freed_blocks = 2;
        assert!(!a.blocks_conserved());
    }

    #[test]
    fn trace_collects_and_counts() {
        let mut tr = Trace::new();
        assert!(tr.is_empty());
        tr.push(TraceEvent::Arrival {
            t: 0.5,
            id: 1,
            tokens: 10,
        });
        tr.push(TraceEvent::Finished {
            t: 2.0,
            id: 1,
            tokens: 10,
        });
        assert_eq!(tr.len(), 2);
        assert_eq!(
            tr.count(|e| matches!(e, TraceEvent::Finished { .. })),
            1
        );
        assert_eq!(tr.events[0].t(), 0.5);
    }
}
