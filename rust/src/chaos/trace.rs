//! Structured event trace of a serving run.
//!
//! Both simulators ([`crate::coordinator::ServingSim`],
//! [`crate::coordinator::FleetSim`]) emit a [`Trace`] alongside their
//! metrics: every arrival, scale command (with its declared intake-pause
//! window and plan audit), fault firing, intake-pause edge,
//! suspend/resume, per-sequence switchover disposition, and finish. The
//! trace is the machine-checkable record the conformance checkers
//! ([`super::invariants`]) run over — the point is that correctness
//! claims ("no token loss", "blocks conserved even across aborts") are
//! verified against what the run *actually did*, not against what the
//! scaling method promised.

use crate::sim::StateHash;
use crate::tier::TierLevel;
use crate::util::json::Json;

use super::faults::FaultKind;

/// Plan-level accounting of one scaling event, captured when the command
/// is issued (rides in [`crate::scaling::ScalingOutcome::plan_audit`]).
/// Present whenever the plan was drawn against a live KV snapshot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanAudit {
    /// Live KV blocks at the snapshot — the conservation baseline.
    pub snapshot_blocks: usize,
    /// Blocks that remap in place (zero-copy).
    pub kv_remapped_blocks: usize,
    /// Blocks that move over the fabric.
    pub kv_copied_blocks: usize,
    /// Blocks freed because their sequence re-prefills.
    pub kv_freed_blocks: usize,
    /// Bytes the KV copy legs move (charged against the budget).
    pub kv_copied_bytes: u64,
    /// Effective migration-byte budget the plan was drawn under (the
    /// configured budget after any HBM-pressure shrink).
    pub migration_budget_bytes: u64,
    /// Bytes moved by expert migrations (forced moves are budget-exempt;
    /// reported for the record, not checked against the budget).
    pub expert_migration_bytes: u64,
}

impl PlanAudit {
    /// Conservation invariant: every snapshot block accounted exactly
    /// once — remapped, copied, or freed.
    pub fn blocks_conserved(&self) -> bool {
        self.kv_remapped_blocks + self.kv_copied_blocks + self.kv_freed_blocks
            == self.snapshot_blocks
    }
}

/// One event in a serving run's trace. All times are absolute simulated
/// seconds; `event` is the run-wide scaling-event ordinal (0-based).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A request entered the system.
    Arrival { t: f64, id: u64, tokens: usize },
    /// A scale command was issued. `declared_pause` is the outcome's
    /// intake-pause window in absolute time — the bound the pause edges
    /// must respect.
    ScaleCommand {
        t: f64,
        event: usize,
        from_devices: usize,
        to_devices: usize,
        declared_pause: Option<(f64, f64)>,
    },
    /// The event's plan-level accounting (present when a live KV snapshot
    /// was planned against).
    PlanAudited {
        t: f64,
        event: usize,
        audit: PlanAudit,
    },
    /// An injected fault fired during the event.
    FaultFired {
        t: f64,
        event: usize,
        fault: FaultKind,
    },
    /// The active engine stopped admitting new requests.
    IntakePaused { t: f64, event: usize },
    /// Admission reopened (switchover completed or event aborted).
    IntakeResumed { t: f64, event: usize },
    /// A running sequence was frozen for the KV handoff window.
    Suspended { t: f64, event: usize, id: u64 },
    /// A suspended sequence resumed on its origin replica (event abort).
    Resumed { t: f64, event: usize, id: u64 },
    /// A drained sequence was adopted by the successor with its decode
    /// progress intact (`remap` = blocks stayed put; otherwise copied).
    Adopted {
        t: f64,
        event: usize,
        id: u64,
        remap: bool,
    },
    /// A drained sequence restarted from scratch on the successor.
    Restarted { t: f64, event: usize, id: u64 },
    /// The event completed: the successor serves `devices` devices.
    ScaleCompleted { t: f64, event: usize, devices: usize },
    /// The event aborted; `rolled_back` means the cluster returned to its
    /// pre-plan state and the old instance kept serving.
    ScaleAborted {
        t: f64,
        event: usize,
        rolled_back: bool,
        reason: String,
    },
    /// A request finished, having produced `tokens` decode tokens.
    Finished { t: f64, id: u64, tokens: usize },
    /// One weight unit crossed a residency-tier boundary on `replica`
    /// (demote, promote, stage, park, unpark — drained from the
    /// method's [`crate::tier::TieredWeightStore`] journal).
    TierShift {
        t: f64,
        replica: usize,
        tag: String,
        bytes: u64,
        from: TierLevel,
        to: TierLevel,
    },
    /// Independent audit point: `replica`'s host-DRAM *allocator*
    /// reports `dram_bytes` staged. The conservation invariant replays
    /// the journal ([`TraceEvent::TierShift`]) and must land exactly
    /// here — journal and allocator are separate accounting paths.
    TierAudit {
        t: f64,
        replica: usize,
        dram_bytes: u64,
    },
    /// The fleet policy declared its desired state for one reconcile
    /// round: `replicas` spec slots holding `devices` total, `parked` of
    /// them parked. `drift` is the number of reconcile steps planned to
    /// converge the observed fleet onto the spec (0 = converged).
    SpecDeclared {
        t: f64,
        replicas: usize,
        devices: usize,
        parked: usize,
        drift: usize,
    },
    /// One reconcile step was enacted against `replica` (`step` is the
    /// step's stable description, e.g. `"resize->4"`). `applied` is
    /// false when enactment found the observed state already satisfied
    /// (or vetoed) the step and made it a checked no-op — the mark that
    /// distinguishes idempotent re-derivation from silent mutation.
    ReconcileStep {
        t: f64,
        replica: usize,
        step: String,
        applied: bool,
    },
    /// A live replica's heartbeat failed to arrive at its beat time.
    HeartbeatMissed { t: f64, replica: usize },
    /// `replica` exceeded the heartbeat staleness deadline and was
    /// evicted from the fleet; `requeued` of its requests were re-homed
    /// onto surviving replicas.
    ReplicaEvicted {
        t: f64,
        replica: usize,
        requeued: usize,
    },
    /// Sequence `id` finished its prefill on `from_replica` and a KV
    /// transfer leg toward decode pool member `to_replica` was planned:
    /// `bytes` over `legs` fabric legs (disaggregated fleets only).
    HandoffPlanned {
        t: f64,
        id: u64,
        from_replica: usize,
        to_replica: usize,
        bytes: u64,
        legs: usize,
    },
    /// The prefill→decode handoff of sequence `id` was dispositioned on
    /// `to_replica`: adopted with its KV intact, or (`recompute`) its
    /// transfer was aborted/rejected and the decode replica re-prefills
    /// from scratch. Every [`TraceEvent::HandoffPlanned`] must be
    /// followed by exactly one `HandoffDone` for the same sequence.
    HandoffDone {
        t: f64,
        id: u64,
        to_replica: usize,
        recompute: bool,
    },
    /// One autoscaling decision, explained: what the policy kernel
    /// observed for `pool` at the tick ending at `t`, where its
    /// hysteresis counters stood after the window was folded in, which
    /// direction the estimator chose, and the concrete action projected
    /// from it. `vetoed` marks a fired trigger that no guard-passing
    /// candidate could absorb (busy/cooling replicas, exhausted pool,
    /// replica floor) — the decision was refunded and the spec holds.
    /// `attainment` is the value fed to the estimator (queue-pressure
    /// clamped; `-1` encodes a no-traffic window whose attainment is
    /// undefined). Emitted on every policy tick whether or not the
    /// fleet moves, so the trace carries the full decision ledger.
    DecisionExplain {
        t: f64,
        pool: &'static str,
        serving: usize,
        attainment: f64,
        occupancy: f64,
        queue: usize,
        bad_windows: usize,
        good_windows: usize,
        cooling: bool,
        rearmed: bool,
        reburst: bool,
        decision: &'static str,
        action: String,
        vetoed: bool,
    },
}

impl TraceEvent {
    /// The event's timestamp.
    pub fn t(&self) -> f64 {
        match self {
            TraceEvent::Arrival { t, .. }
            | TraceEvent::ScaleCommand { t, .. }
            | TraceEvent::PlanAudited { t, .. }
            | TraceEvent::FaultFired { t, .. }
            | TraceEvent::IntakePaused { t, .. }
            | TraceEvent::IntakeResumed { t, .. }
            | TraceEvent::Suspended { t, .. }
            | TraceEvent::Resumed { t, .. }
            | TraceEvent::Adopted { t, .. }
            | TraceEvent::Restarted { t, .. }
            | TraceEvent::ScaleCompleted { t, .. }
            | TraceEvent::ScaleAborted { t, .. }
            | TraceEvent::Finished { t, .. }
            | TraceEvent::TierShift { t, .. }
            | TraceEvent::TierAudit { t, .. }
            | TraceEvent::SpecDeclared { t, .. }
            | TraceEvent::ReconcileStep { t, .. }
            | TraceEvent::HeartbeatMissed { t, .. }
            | TraceEvent::ReplicaEvicted { t, .. }
            | TraceEvent::HandoffPlanned { t, .. }
            | TraceEvent::HandoffDone { t, .. }
            | TraceEvent::DecisionExplain { t, .. } => *t,
        }
    }
}

impl TraceEvent {
    /// Fold this event into an incremental digest. Every field of every
    /// variant participates, each variant under a distinct discriminant
    /// tag, so a trace's digest pins the exact event sequence bit-for-bit.
    /// Allocation-free: called on the simulators' hot path via
    /// [`Trace::push`].
    fn fold_into(&self, h: &mut StateHash) {
        match self {
            TraceEvent::Arrival { t, id, tokens } => {
                h.fold_u64(0);
                h.fold_f64(*t);
                h.fold_u64(*id);
                h.fold_usize(*tokens);
            }
            TraceEvent::ScaleCommand {
                t,
                event,
                from_devices,
                to_devices,
                declared_pause,
            } => {
                h.fold_u64(1);
                h.fold_f64(*t);
                h.fold_usize(*event);
                h.fold_usize(*from_devices);
                h.fold_usize(*to_devices);
                match declared_pause {
                    Some((a, b)) => {
                        h.fold_bool(true);
                        h.fold_f64(*a);
                        h.fold_f64(*b);
                    }
                    None => h.fold_bool(false),
                }
            }
            TraceEvent::PlanAudited { t, event, audit } => {
                h.fold_u64(2);
                h.fold_f64(*t);
                h.fold_usize(*event);
                h.fold_usize(audit.snapshot_blocks);
                h.fold_usize(audit.kv_remapped_blocks);
                h.fold_usize(audit.kv_copied_blocks);
                h.fold_usize(audit.kv_freed_blocks);
                h.fold_u64(audit.kv_copied_bytes);
                h.fold_u64(audit.migration_budget_bytes);
                h.fold_u64(audit.expert_migration_bytes);
            }
            TraceEvent::FaultFired { t, event, fault } => {
                h.fold_u64(3);
                h.fold_f64(*t);
                h.fold_usize(*event);
                match fault {
                    FaultKind::P2pLinkFail { after_legs } => {
                        h.fold_u64(0);
                        h.fold_usize(*after_legs);
                    }
                    FaultKind::KvCopyFail { after_legs } => {
                        h.fold_u64(1);
                        h.fold_usize(*after_legs);
                    }
                    FaultKind::DeviceLoss { dev } => {
                        h.fold_u64(2);
                        h.fold_usize(*dev);
                    }
                    FaultKind::HbmPressure { budget_factor } => {
                        h.fold_u64(3);
                        h.fold_f64(*budget_factor);
                    }
                    FaultKind::Straggler { dev, stretch } => {
                        h.fold_u64(4);
                        h.fold_usize(*dev);
                        h.fold_f64(*stretch);
                    }
                    FaultKind::HeartbeatLoss { replica, beats } => {
                        h.fold_u64(5);
                        h.fold_usize(*replica);
                        h.fold_usize(*beats);
                    }
                    FaultKind::StaleObservedState { ticks } => {
                        h.fold_u64(6);
                        h.fold_usize(*ticks);
                    }
                    FaultKind::DuplicateCommand => h.fold_u64(7),
                }
            }
            TraceEvent::IntakePaused { t, event } => {
                h.fold_u64(4);
                h.fold_f64(*t);
                h.fold_usize(*event);
            }
            TraceEvent::IntakeResumed { t, event } => {
                h.fold_u64(5);
                h.fold_f64(*t);
                h.fold_usize(*event);
            }
            TraceEvent::Suspended { t, event, id } => {
                h.fold_u64(6);
                h.fold_f64(*t);
                h.fold_usize(*event);
                h.fold_u64(*id);
            }
            TraceEvent::Resumed { t, event, id } => {
                h.fold_u64(7);
                h.fold_f64(*t);
                h.fold_usize(*event);
                h.fold_u64(*id);
            }
            TraceEvent::Adopted {
                t,
                event,
                id,
                remap,
            } => {
                h.fold_u64(8);
                h.fold_f64(*t);
                h.fold_usize(*event);
                h.fold_u64(*id);
                h.fold_bool(*remap);
            }
            TraceEvent::Restarted { t, event, id } => {
                h.fold_u64(9);
                h.fold_f64(*t);
                h.fold_usize(*event);
                h.fold_u64(*id);
            }
            TraceEvent::ScaleCompleted { t, event, devices } => {
                h.fold_u64(10);
                h.fold_f64(*t);
                h.fold_usize(*event);
                h.fold_usize(*devices);
            }
            TraceEvent::ScaleAborted {
                t,
                event,
                rolled_back,
                reason,
            } => {
                h.fold_u64(11);
                h.fold_f64(*t);
                h.fold_usize(*event);
                h.fold_bool(*rolled_back);
                h.fold_str(reason);
            }
            TraceEvent::Finished { t, id, tokens } => {
                h.fold_u64(12);
                h.fold_f64(*t);
                h.fold_u64(*id);
                h.fold_usize(*tokens);
            }
            TraceEvent::TierShift {
                t,
                replica,
                tag,
                bytes,
                from,
                to,
            } => {
                h.fold_u64(13);
                h.fold_f64(*t);
                h.fold_usize(*replica);
                h.fold_str(tag);
                h.fold_u64(*bytes);
                h.fold_str(from.label());
                h.fold_str(to.label());
            }
            TraceEvent::TierAudit {
                t,
                replica,
                dram_bytes,
            } => {
                h.fold_u64(14);
                h.fold_f64(*t);
                h.fold_usize(*replica);
                h.fold_u64(*dram_bytes);
            }
            TraceEvent::SpecDeclared {
                t,
                replicas,
                devices,
                parked,
                drift,
            } => {
                h.fold_u64(15);
                h.fold_f64(*t);
                h.fold_usize(*replicas);
                h.fold_usize(*devices);
                h.fold_usize(*parked);
                h.fold_usize(*drift);
            }
            TraceEvent::ReconcileStep {
                t,
                replica,
                step,
                applied,
            } => {
                h.fold_u64(16);
                h.fold_f64(*t);
                h.fold_usize(*replica);
                h.fold_str(step);
                h.fold_bool(*applied);
            }
            TraceEvent::HeartbeatMissed { t, replica } => {
                h.fold_u64(17);
                h.fold_f64(*t);
                h.fold_usize(*replica);
            }
            TraceEvent::ReplicaEvicted {
                t,
                replica,
                requeued,
            } => {
                h.fold_u64(18);
                h.fold_f64(*t);
                h.fold_usize(*replica);
                h.fold_usize(*requeued);
            }
            TraceEvent::HandoffPlanned {
                t,
                id,
                from_replica,
                to_replica,
                bytes,
                legs,
            } => {
                h.fold_u64(19);
                h.fold_f64(*t);
                h.fold_u64(*id);
                h.fold_usize(*from_replica);
                h.fold_usize(*to_replica);
                h.fold_u64(*bytes);
                h.fold_usize(*legs);
            }
            TraceEvent::HandoffDone {
                t,
                id,
                to_replica,
                recompute,
            } => {
                h.fold_u64(20);
                h.fold_f64(*t);
                h.fold_u64(*id);
                h.fold_usize(*to_replica);
                h.fold_bool(*recompute);
            }
            TraceEvent::DecisionExplain {
                t,
                pool,
                serving,
                attainment,
                occupancy,
                queue,
                bad_windows,
                good_windows,
                cooling,
                rearmed,
                reburst,
                decision,
                action,
                vetoed,
            } => {
                h.fold_u64(21);
                h.fold_f64(*t);
                h.fold_str(pool);
                h.fold_usize(*serving);
                h.fold_f64(*attainment);
                h.fold_f64(*occupancy);
                h.fold_usize(*queue);
                h.fold_usize(*bad_windows);
                h.fold_usize(*good_windows);
                h.fold_bool(*cooling);
                h.fold_bool(*rearmed);
                h.fold_bool(*reburst);
                h.fold_str(decision);
                h.fold_str(action);
                h.fold_bool(*vetoed);
            }
        }
    }

    /// JSON rendering of one event: `{"ev": "<kind>", ...fields}`. Keys
    /// come out alphabetically sorted and compact via [`Json`]'s
    /// `Display`, which is what makes the golden-trace file byte-stable.
    pub fn to_json(&self) -> Json {
        match self {
            TraceEvent::Arrival { t, id, tokens } => Json::obj(vec![
                ("ev", Json::str("arrival")),
                ("t", Json::num(*t)),
                ("id", Json::num(*id as f64)),
                ("tokens", Json::num(*tokens as f64)),
            ]),
            TraceEvent::ScaleCommand {
                t,
                event,
                from_devices,
                to_devices,
                declared_pause,
            } => Json::obj(vec![
                ("ev", Json::str("scale_command")),
                ("t", Json::num(*t)),
                ("event", Json::num(*event as f64)),
                ("from_devices", Json::num(*from_devices as f64)),
                ("to_devices", Json::num(*to_devices as f64)),
                (
                    "declared_pause",
                    match declared_pause {
                        Some((a, b)) => {
                            Json::arr([Json::num(*a), Json::num(*b)])
                        }
                        None => Json::Null,
                    },
                ),
            ]),
            TraceEvent::PlanAudited { t, event, audit } => Json::obj(vec![
                ("ev", Json::str("plan_audited")),
                ("t", Json::num(*t)),
                ("event", Json::num(*event as f64)),
                (
                    "audit",
                    Json::obj(vec![
                        (
                            "snapshot_blocks",
                            Json::num(audit.snapshot_blocks as f64),
                        ),
                        (
                            "kv_remapped_blocks",
                            Json::num(audit.kv_remapped_blocks as f64),
                        ),
                        (
                            "kv_copied_blocks",
                            Json::num(audit.kv_copied_blocks as f64),
                        ),
                        (
                            "kv_freed_blocks",
                            Json::num(audit.kv_freed_blocks as f64),
                        ),
                        (
                            "kv_copied_bytes",
                            Json::num(audit.kv_copied_bytes as f64),
                        ),
                        (
                            "migration_budget_bytes",
                            Json::num(audit.migration_budget_bytes as f64),
                        ),
                        (
                            "expert_migration_bytes",
                            Json::num(audit.expert_migration_bytes as f64),
                        ),
                    ]),
                ),
            ]),
            TraceEvent::FaultFired { t, event, fault } => {
                let mut pairs = vec![
                    ("ev", Json::str("fault_fired")),
                    ("t", Json::num(*t)),
                    ("event", Json::num(*event as f64)),
                    ("fault", Json::str(fault.label())),
                ];
                match fault {
                    FaultKind::P2pLinkFail { after_legs }
                    | FaultKind::KvCopyFail { after_legs } => {
                        pairs.push((
                            "after_legs",
                            Json::num(*after_legs as f64),
                        ));
                    }
                    FaultKind::DeviceLoss { dev } => {
                        pairs.push(("dev", Json::num(*dev as f64)));
                    }
                    FaultKind::HbmPressure { budget_factor } => {
                        pairs.push((
                            "budget_factor",
                            Json::num(*budget_factor),
                        ));
                    }
                    FaultKind::Straggler { dev, stretch } => {
                        pairs.push(("dev", Json::num(*dev as f64)));
                        pairs.push(("stretch", Json::num(*stretch)));
                    }
                    FaultKind::HeartbeatLoss { replica, beats } => {
                        pairs.push(("replica", Json::num(*replica as f64)));
                        pairs.push(("beats", Json::num(*beats as f64)));
                    }
                    FaultKind::StaleObservedState { ticks } => {
                        pairs.push(("ticks", Json::num(*ticks as f64)));
                    }
                    FaultKind::DuplicateCommand => {}
                }
                Json::obj(pairs)
            }
            TraceEvent::IntakePaused { t, event } => Json::obj(vec![
                ("ev", Json::str("intake_paused")),
                ("t", Json::num(*t)),
                ("event", Json::num(*event as f64)),
            ]),
            TraceEvent::IntakeResumed { t, event } => Json::obj(vec![
                ("ev", Json::str("intake_resumed")),
                ("t", Json::num(*t)),
                ("event", Json::num(*event as f64)),
            ]),
            TraceEvent::Suspended { t, event, id } => Json::obj(vec![
                ("ev", Json::str("suspended")),
                ("t", Json::num(*t)),
                ("event", Json::num(*event as f64)),
                ("id", Json::num(*id as f64)),
            ]),
            TraceEvent::Resumed { t, event, id } => Json::obj(vec![
                ("ev", Json::str("resumed")),
                ("t", Json::num(*t)),
                ("event", Json::num(*event as f64)),
                ("id", Json::num(*id as f64)),
            ]),
            TraceEvent::Adopted {
                t,
                event,
                id,
                remap,
            } => Json::obj(vec![
                ("ev", Json::str("adopted")),
                ("t", Json::num(*t)),
                ("event", Json::num(*event as f64)),
                ("id", Json::num(*id as f64)),
                ("remap", Json::Bool(*remap)),
            ]),
            TraceEvent::Restarted { t, event, id } => Json::obj(vec![
                ("ev", Json::str("restarted")),
                ("t", Json::num(*t)),
                ("event", Json::num(*event as f64)),
                ("id", Json::num(*id as f64)),
            ]),
            TraceEvent::ScaleCompleted { t, event, devices } => {
                Json::obj(vec![
                    ("ev", Json::str("scale_completed")),
                    ("t", Json::num(*t)),
                    ("event", Json::num(*event as f64)),
                    ("devices", Json::num(*devices as f64)),
                ])
            }
            TraceEvent::ScaleAborted {
                t,
                event,
                rolled_back,
                reason,
            } => Json::obj(vec![
                ("ev", Json::str("scale_aborted")),
                ("t", Json::num(*t)),
                ("event", Json::num(*event as f64)),
                ("rolled_back", Json::Bool(*rolled_back)),
                ("reason", Json::str(reason.clone())),
            ]),
            TraceEvent::Finished { t, id, tokens } => Json::obj(vec![
                ("ev", Json::str("finished")),
                ("t", Json::num(*t)),
                ("id", Json::num(*id as f64)),
                ("tokens", Json::num(*tokens as f64)),
            ]),
            TraceEvent::TierShift {
                t,
                replica,
                tag,
                bytes,
                from,
                to,
            } => Json::obj(vec![
                ("ev", Json::str("tier_shift")),
                ("t", Json::num(*t)),
                ("replica", Json::num(*replica as f64)),
                ("tag", Json::str(tag.clone())),
                ("bytes", Json::num(*bytes as f64)),
                ("from", Json::str(from.label())),
                ("to", Json::str(to.label())),
            ]),
            TraceEvent::TierAudit {
                t,
                replica,
                dram_bytes,
            } => Json::obj(vec![
                ("ev", Json::str("tier_audit")),
                ("t", Json::num(*t)),
                ("replica", Json::num(*replica as f64)),
                ("dram_bytes", Json::num(*dram_bytes as f64)),
            ]),
            TraceEvent::SpecDeclared {
                t,
                replicas,
                devices,
                parked,
                drift,
            } => Json::obj(vec![
                ("ev", Json::str("spec_declared")),
                ("t", Json::num(*t)),
                ("replicas", Json::num(*replicas as f64)),
                ("devices", Json::num(*devices as f64)),
                ("parked", Json::num(*parked as f64)),
                ("drift", Json::num(*drift as f64)),
            ]),
            TraceEvent::ReconcileStep {
                t,
                replica,
                step,
                applied,
            } => Json::obj(vec![
                ("ev", Json::str("reconcile_step")),
                ("t", Json::num(*t)),
                ("replica", Json::num(*replica as f64)),
                ("step", Json::str(step.clone())),
                ("applied", Json::Bool(*applied)),
            ]),
            TraceEvent::HeartbeatMissed { t, replica } => Json::obj(vec![
                ("ev", Json::str("heartbeat_missed")),
                ("t", Json::num(*t)),
                ("replica", Json::num(*replica as f64)),
            ]),
            TraceEvent::ReplicaEvicted {
                t,
                replica,
                requeued,
            } => Json::obj(vec![
                ("ev", Json::str("replica_evicted")),
                ("t", Json::num(*t)),
                ("replica", Json::num(*replica as f64)),
                ("requeued", Json::num(*requeued as f64)),
            ]),
            TraceEvent::HandoffPlanned {
                t,
                id,
                from_replica,
                to_replica,
                bytes,
                legs,
            } => Json::obj(vec![
                ("ev", Json::str("handoff_planned")),
                ("t", Json::num(*t)),
                ("id", Json::num(*id as f64)),
                ("from_replica", Json::num(*from_replica as f64)),
                ("to_replica", Json::num(*to_replica as f64)),
                ("bytes", Json::num(*bytes as f64)),
                ("legs", Json::num(*legs as f64)),
            ]),
            TraceEvent::HandoffDone {
                t,
                id,
                to_replica,
                recompute,
            } => Json::obj(vec![
                ("ev", Json::str("handoff_done")),
                ("t", Json::num(*t)),
                ("id", Json::num(*id as f64)),
                ("to_replica", Json::num(*to_replica as f64)),
                ("recompute", Json::Bool(*recompute)),
            ]),
            TraceEvent::DecisionExplain {
                t,
                pool,
                serving,
                attainment,
                occupancy,
                queue,
                bad_windows,
                good_windows,
                cooling,
                rearmed,
                reburst,
                decision,
                action,
                vetoed,
            } => Json::obj(vec![
                ("ev", Json::str("decision_explain")),
                ("t", Json::num(*t)),
                ("pool", Json::str(*pool)),
                ("serving", Json::num(*serving as f64)),
                ("attainment", Json::num(*attainment)),
                ("occupancy", Json::num(*occupancy)),
                ("queue", Json::num(*queue as f64)),
                ("bad_windows", Json::num(*bad_windows as f64)),
                ("good_windows", Json::num(*good_windows as f64)),
                ("cooling", Json::Bool(*cooling)),
                ("rearmed", Json::Bool(*rearmed)),
                ("reburst", Json::Bool(*reburst)),
                ("decision", Json::str(*decision)),
                ("action", Json::str(action.clone())),
                ("vetoed", Json::Bool(*vetoed)),
            ]),
        }
    }
}

/// An append-only event log for one simulated run.
///
/// Every [`push`](Trace::push) also folds the event into an incremental
/// [`StateHash`], so [`Trace::state_hash`] pins the full event sequence —
/// two runs with equal digests logged bit-identical traces, without
/// re-walking the event vector.
#[derive(Debug, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    hash: StateHash,
}

impl Trace {
    pub fn new() -> Self {
        Trace::default()
    }

    pub fn push(&mut self, ev: TraceEvent) {
        ev.fold_into(&mut self.hash);
        self.events.push(ev);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// FNV-1a digest over every event pushed so far (variant tags plus
    /// all fields; floats by bit pattern).
    pub fn state_hash(&self) -> u64 {
        self.hash.value()
    }

    /// Count events matching a predicate.
    pub fn count(&self, f: impl Fn(&TraceEvent) -> bool) -> usize {
        self.events.iter().filter(|e| f(e)).count()
    }

    /// JSON rendering of the whole trace:
    /// `{"events":[...],"state_hash":"<hex>"}`. The digest rides along as
    /// a hex string (JSON numbers are f64 — a u64 would lose bits).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "events",
                Json::arr(self.events.iter().map(|e| e.to_json())),
            ),
            (
                "state_hash",
                Json::str(format!("{:016x}", self.state_hash())),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn audit_conservation() {
        let mut a = PlanAudit {
            snapshot_blocks: 10,
            kv_remapped_blocks: 6,
            kv_copied_blocks: 3,
            kv_freed_blocks: 1,
            kv_copied_bytes: 100,
            migration_budget_bytes: 1000,
            expert_migration_bytes: 0,
        };
        assert!(a.blocks_conserved());
        a.kv_freed_blocks = 2;
        assert!(!a.blocks_conserved());
    }

    #[test]
    fn trace_collects_and_counts() {
        let mut tr = Trace::new();
        assert!(tr.is_empty());
        tr.push(TraceEvent::Arrival {
            t: 0.5,
            id: 1,
            tokens: 10,
        });
        tr.push(TraceEvent::Finished {
            t: 2.0,
            id: 1,
            tokens: 10,
        });
        assert_eq!(tr.len(), 2);
        assert_eq!(
            tr.count(|e| matches!(e, TraceEvent::Finished { .. })),
            1
        );
        assert_eq!(tr.events[0].t(), 0.5);
    }

    #[test]
    fn hash_is_incremental_and_order_sensitive() {
        let a = TraceEvent::Arrival {
            t: 0.5,
            id: 1,
            tokens: 10,
        };
        let f = TraceEvent::Finished {
            t: 2.0,
            id: 1,
            tokens: 10,
        };
        let mut t1 = Trace::new();
        let mut t2 = Trace::new();
        assert_eq!(t1.state_hash(), t2.state_hash(), "empty traces agree");
        t1.push(a.clone());
        t1.push(f.clone());
        t2.push(a.clone());
        t2.push(f.clone());
        assert_eq!(t1.state_hash(), t2.state_hash(), "same events, same hash");
        let mut t3 = Trace::new();
        t3.push(f);
        t3.push(a);
        assert_ne!(t1.state_hash(), t3.state_hash(), "order matters");
    }

    #[test]
    fn json_rendering_is_compact_and_sorted() {
        let mut tr = Trace::new();
        tr.push(TraceEvent::Arrival {
            t: 0.5,
            id: 1,
            tokens: 10,
        });
        let j = tr.to_json().to_string();
        assert!(j.starts_with(r#"{"events":[{"ev":"arrival","#));
        assert!(j.contains(r#""state_hash":""#));
        // Keys within an event come out alphabetically sorted.
        assert!(j.contains(r#"{"ev":"arrival","id":1,"t":0.5,"tokens":10}"#));
    }

    #[test]
    fn every_variant_serializes() {
        let audit = PlanAudit {
            snapshot_blocks: 4,
            kv_remapped_blocks: 2,
            kv_copied_blocks: 1,
            kv_freed_blocks: 1,
            kv_copied_bytes: 64,
            migration_budget_bytes: 128,
            expert_migration_bytes: 0,
        };
        let events = vec![
            TraceEvent::Arrival { t: 0.0, id: 1, tokens: 8 },
            TraceEvent::ScaleCommand {
                t: 1.0,
                event: 0,
                from_devices: 4,
                to_devices: 8,
                declared_pause: Some((1.5, 2.0)),
            },
            TraceEvent::PlanAudited { t: 1.0, event: 0, audit },
            TraceEvent::FaultFired {
                t: 1.25,
                event: 0,
                fault: FaultKind::Straggler { dev: 3, stretch: 2.5 },
            },
            TraceEvent::IntakePaused { t: 1.5, event: 0 },
            TraceEvent::Suspended { t: 1.5, event: 0, id: 1 },
            TraceEvent::Resumed { t: 1.75, event: 0, id: 1 },
            TraceEvent::Adopted { t: 2.0, event: 0, id: 1, remap: true },
            TraceEvent::Restarted { t: 2.0, event: 0, id: 2 },
            TraceEvent::IntakeResumed { t: 2.0, event: 0 },
            TraceEvent::ScaleCompleted { t: 2.0, event: 0, devices: 8 },
            TraceEvent::ScaleAborted {
                t: 3.0,
                event: 1,
                rolled_back: true,
                reason: "p2p-link-fail".to_string(),
            },
            TraceEvent::TierShift {
                t: 3.5,
                replica: 0,
                tag: "expert-7".to_string(),
                bytes: 1024,
                from: TierLevel::Hbm,
                to: TierLevel::HostDram,
            },
            TraceEvent::TierAudit { t: 3.5, replica: 0, dram_bytes: 1024 },
            TraceEvent::Finished { t: 4.0, id: 1, tokens: 8 },
            TraceEvent::SpecDeclared {
                t: 4.5,
                replicas: 2,
                devices: 6,
                parked: 0,
                drift: 1,
            },
            TraceEvent::ReconcileStep {
                t: 4.5,
                replica: 1,
                step: "resize->4".to_string(),
                applied: true,
            },
            TraceEvent::HeartbeatMissed { t: 5.0, replica: 1 },
            TraceEvent::ReplicaEvicted { t: 5.5, replica: 1, requeued: 3 },
            TraceEvent::HandoffPlanned {
                t: 6.0,
                id: 3,
                from_replica: 0,
                to_replica: 2,
                bytes: 4096,
                legs: 2,
            },
            TraceEvent::HandoffDone {
                t: 6.5,
                id: 3,
                to_replica: 2,
                recompute: false,
            },
            TraceEvent::DecisionExplain {
                t: 7.0,
                pool: "unified",
                serving: 2,
                attainment: 0.75,
                occupancy: 0.9,
                queue: 4,
                bad_windows: 1,
                good_windows: 0,
                cooling: false,
                rearmed: false,
                reburst: false,
                decision: "up",
                action: "grow 4->6".to_string(),
                vetoed: false,
            },
        ];
        let mut tr = Trace::new();
        let mut hashes = vec![tr.state_hash()];
        for e in events {
            tr.push(e);
            // Every variant must perturb the digest.
            let h = tr.state_hash();
            assert!(!hashes.contains(&h));
            hashes.push(h);
        }
        let j = tr.to_json().to_string();
        // Round-trips through the parser (structurally valid JSON).
        let parsed = crate::util::json::parse(&j).unwrap();
        assert_eq!(parsed.get("events").as_arr().unwrap().len(), 22);
    }
}
