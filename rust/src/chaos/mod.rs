//! Fault injection and trace conformance (the chaos harness).
//!
//! ElasticMoE's headline claim is zero-downtime scaling, but the bursty,
//! unreliable cloud conditions the paper targets make partial failure the
//! norm, not the exception. This subsystem turns the repro's correctness
//! story from happy-path acceptance tests into a conformance suite:
//!
//! - [`faults`] — a deterministic, seeded [`FaultPlan`] of injected
//!   faults (P2P link failure mid-copy-leg, device loss, HBM pressure
//!   that shrinks the migration byte budget, straggler devices) plus
//!   control-plane faults (heartbeat loss, stale observed snapshots,
//!   duplicate command enactment — see
//!   `docs/architecture/09-control-plane.md`),
//!   consumed through a [`FaultInjector`] hook that
//!   [`crate::hmm::HmmControl::execute_plan`] consults at every fabric
//!   leg and the serving simulators drain into the event trace.
//! - [`trace`] — a structured [`Trace`] of every serving run (scale
//!   commands, plan audits, intake-pause edges, suspend/resume,
//!   per-sequence dispositions, finishes), emitted by
//!   [`crate::coordinator::ServingSim`] and
//!   [`crate::coordinator::FleetSim`].
//! - [`invariants`] — pure checkers over a trace: KV block conservation
//!   across any event *including aborts*, exactly-once finish per
//!   sequence with no token loss, migration bytes within the (possibly
//!   pressure-shrunk) budget, and intake pauses bounded by their
//!   declared switchover windows.
//!
//! Abortability itself lives in the scaling stack: on a fault,
//! [`crate::hmm::HmmControl::execute_plan`] rolls every applied op back
//! and [`crate::scaling::ElasticMoE`] returns a
//! [`crate::scaling::ScalingOutcome`] whose `aborted` field tells the
//! simulators to keep the old instance and resume suspended sequences on
//! their origin replica. `repro exp chaos` sweeps a scenario matrix of
//! method × scale direction × fault type and asserts every invariant in
//! every cell; see `docs/architecture/05-failure-model.md`.

pub mod faults;
pub mod invariants;
pub mod trace;

pub use faults::{
    FaultEntry, FaultInjector, FaultKind, FaultPlan, FaultRecord,
    RoundFaults,
};
pub use invariants::{
    check_all, check_handoff_disposition, check_reconcile_convergence,
    check_tier_conservation, Violation, CONVERGENCE_ROUNDS,
};
pub use trace::{PlanAudit, Trace, TraceEvent};
