//! Pure conformance checkers over a chaos [`Trace`].
//!
//! Each checker walks the event log and returns the violations it found;
//! [`check_all`] runs the full catalog. The checkers assume a *drained*
//! run (the simulators stop only once every arrival is served), which is
//! what `repro exp chaos`, the integration suite, and the CI smoke run
//! provide. The catalog:
//!
//! 1. **Block conservation** — every audited plan accounts for each live
//!    KV block exactly once (remap + copy + freed = snapshot), including
//!    plans whose event later aborted.
//! 2. **Byte budget** — KV copy bytes never exceed the effective
//!    migration budget the plan was drawn under (post HBM-pressure).
//! 3. **Exactly-once finish / no token loss** — every arrival finishes
//!    exactly once, no unknown id finishes, and each finished request
//!    produced exactly the tokens it asked for.
//! 4. **Bounded intake pause** — every pause resumes exactly once per
//!    event, and both edges lie inside the event's declared pause window
//!    (the closing edge may lag by one engine step — see
//!    [`STEP_SLACK`]).
//! 5. **Suspend disposition** — every suspended sequence is disposed of
//!    exactly once: resumed on its origin replica (abort), or adopted /
//!    restarted at switchover.
//! 6. **Tier conservation** — tier residency bytes conserve across every
//!    demote / promote / park / unpark journal entry, and every
//!    allocator audit matches the journal replay.
//! 7. **Reconcile convergence** — once faults stop firing, the fleet's
//!    spec drift cannot stay positive for [`CONVERGENCE_ROUNDS`]
//!    consecutive reconcile rounds: the reconciler must converge on the
//!    declared spec instead of chasing it forever.
//! 8. **Handoff disposition** — every planned prefill→decode KV
//!    handoff is dispositioned exactly once on its decode replica
//!    (adopted, or recomputed after an aborted transfer), never twice
//!    and never dropped. Vacuous for unified fleets.

use std::collections::BTreeMap;

use crate::tier::TierLevel;

use super::trace::{Trace, TraceEvent};

/// Slack for floating-point window comparisons.
const EPS: f64 = 1e-6;

/// Default event-loop granularity allowance on a window's *closing*
/// edge: the simulators enact pause windows between engine steps, so the
/// resume lands at the first step boundary at or after the declared end
/// — up to one (possibly full-prefill-sized) step late. 4 simulated
/// seconds comfortably bounds one step for the stock experiments
/// (16 384 prefill tokens on the CloudMatrix cost model); runs with
/// slower timings or larger models should use
/// [`check_intake_pause_bounded_with_slack`]. Opening edges get no such
/// allowance: pausing outside the declared window is a real violation.
pub const STEP_SLACK: f64 = 4.0;

/// One invariant violation.
#[derive(Debug, Clone, PartialEq)]
pub struct Violation {
    /// Which invariant failed (stable slug).
    pub invariant: &'static str,
    pub detail: String,
}

impl Violation {
    fn new(invariant: &'static str, detail: String) -> Self {
        Violation { invariant, detail }
    }
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.invariant, self.detail)
    }
}

/// Run the full invariant catalog. Empty result = conformant trace.
pub fn check_all(trace: &Trace) -> Vec<Violation> {
    let mut out = Vec::new();
    out.extend(check_block_conservation(trace));
    out.extend(check_byte_budget(trace));
    out.extend(check_exactly_once_finish(trace));
    out.extend(check_intake_pause_bounded(trace));
    out.extend(check_suspend_disposition(trace));
    out.extend(check_tier_conservation(trace));
    out.extend(check_reconcile_convergence(trace));
    out.extend(check_handoff_disposition(trace));
    out
}

/// Bound on consecutive drifting reconcile rounds after the last fault.
/// A healthy reconciler clears any single disruption in one or two
/// rounds (plan → enact → observe); eight covers multi-step recoveries
/// (evict + re-add + resize) with margin while still catching a loop
/// that chases its spec forever.
pub const CONVERGENCE_ROUNDS: usize = 8;

/// Invariant 7: bounded reconcile convergence. After the last
/// [`TraceEvent::FaultFired`], no [`CONVERGENCE_ROUNDS`] *consecutive*
/// [`TraceEvent::SpecDeclared`] rounds may all carry positive drift —
/// the reconciler must reach (or at least touch) the declared spec.
/// A trailing drifting round or two is fine: fleet runs stop as soon as
/// every arrival is served, which can truncate the final enactment.
/// Traces with no `SpecDeclared` events (single-instance runs) pass
/// vacuously.
pub fn check_reconcile_convergence(trace: &Trace) -> Vec<Violation> {
    let mut out = Vec::new();
    let last_fault = trace
        .events
        .iter()
        .rposition(|ev| matches!(ev, TraceEvent::FaultFired { .. }));
    let mut streak = 0usize;
    let mut streak_start = 0.0f64;
    for (i, ev) in trace.events.iter().enumerate() {
        if let TraceEvent::SpecDeclared { t, drift, .. } = ev {
            if last_fault.is_some_and(|f| i < f) {
                // Rounds while faults are still firing are excused.
                streak = 0;
                continue;
            }
            if *drift > 0 {
                if streak == 0 {
                    streak_start = *t;
                }
                streak += 1;
                if streak == CONVERGENCE_ROUNDS {
                    out.push(Violation::new(
                        "reconcile-convergence",
                        format!(
                            "spec drift stayed positive for \
                             {CONVERGENCE_ROUNDS} consecutive rounds \
                             after faults stopped (since t={streak_start:.6})"
                        ),
                    ));
                }
            } else {
                streak = 0;
            }
        }
    }
    out
}

/// Invariant 6: tier residency bytes conserve across every demote /
/// promote / park / unpark event. Per replica, the checker replays the
/// journalled [`TraceEvent::TierShift`]s as a per-tag state machine
/// (a unit can only leave the tier it is in, with the byte size it
/// entered with) and a running host-DRAM total, and every
/// [`TraceEvent::TierAudit`] — the *allocator's* independent figure —
/// must match the replayed total exactly.
pub fn check_tier_conservation(trace: &Trace) -> Vec<Violation> {
    let mut out = Vec::new();
    // replica -> (tag -> (level, bytes), running dram bytes).
    type TagState = BTreeMap<String, (TierLevel, u64)>;
    let mut tags: BTreeMap<usize, TagState> = BTreeMap::new();
    let mut dram: BTreeMap<usize, u64> = BTreeMap::new();
    for ev in &trace.events {
        match ev {
            TraceEvent::TierShift {
                replica,
                tag,
                bytes,
                from,
                to,
                ..
            } => {
                if from == to {
                    out.push(Violation::new(
                        "tier-conservation",
                        format!(
                            "replica {replica}: '{tag}' shifted \
                             {} -> {} (not a move)",
                            from.label(),
                            to.label()
                        ),
                    ));
                    continue;
                }
                let state = tags.entry(*replica).or_default();
                match state.get(tag) {
                    Some(&(level, prev_bytes)) => {
                        if level != *from {
                            out.push(Violation::new(
                                "tier-conservation",
                                format!(
                                    "replica {replica}: '{tag}' shifted \
                                     from {} but resides in {}",
                                    from.label(),
                                    level.label()
                                ),
                            ));
                        }
                        if prev_bytes != *bytes {
                            out.push(Violation::new(
                                "tier-conservation",
                                format!(
                                    "replica {replica}: '{tag}' moved \
                                     {bytes} bytes but entered the tier \
                                     system with {prev_bytes}"
                                ),
                            ));
                        }
                    }
                    // First sighting: accept `from` as the unit's
                    // origin tier (boot-time HBM/disk residency is not
                    // journalled).
                    None => {}
                }
                state.insert(tag.clone(), (*to, *bytes));
                let total = dram.entry(*replica).or_default();
                if *from == TierLevel::HostDram {
                    match total.checked_sub(*bytes) {
                        Some(v) => *total = v,
                        None => {
                            out.push(Violation::new(
                                "tier-conservation",
                                format!(
                                    "replica {replica}: '{tag}' left DRAM \
                                     with {bytes} bytes but only {total} \
                                     were staged"
                                ),
                            ));
                            *total = 0;
                        }
                    }
                }
                if *to == TierLevel::HostDram {
                    *total += *bytes;
                }
            }
            TraceEvent::TierAudit {
                replica,
                dram_bytes,
                ..
            } => {
                let replayed = dram.get(replica).copied().unwrap_or(0);
                if replayed != *dram_bytes {
                    out.push(Violation::new(
                        "tier-conservation",
                        format!(
                            "replica {replica}: journal replays to \
                             {replayed} DRAM bytes but the allocator \
                             audits {dram_bytes}"
                        ),
                    ));
                }
            }
            _ => {}
        }
    }
    out
}

/// Invariant 1: every audited plan conserves KV blocks.
pub fn check_block_conservation(trace: &Trace) -> Vec<Violation> {
    let mut out = Vec::new();
    for ev in &trace.events {
        if let TraceEvent::PlanAudited { event, audit, .. } = ev {
            if !audit.blocks_conserved() {
                out.push(Violation::new(
                    "block-conservation",
                    format!(
                        "event {event}: {} + {} + {} != {} snapshot blocks",
                        audit.kv_remapped_blocks,
                        audit.kv_copied_blocks,
                        audit.kv_freed_blocks,
                        audit.snapshot_blocks
                    ),
                ));
            }
        }
    }
    out
}

/// Invariant 2: KV copy bytes within the effective migration budget.
pub fn check_byte_budget(trace: &Trace) -> Vec<Violation> {
    let mut out = Vec::new();
    for ev in &trace.events {
        if let TraceEvent::PlanAudited { event, audit, .. } = ev {
            if audit.kv_copied_bytes > audit.migration_budget_bytes {
                out.push(Violation::new(
                    "byte-budget",
                    format!(
                        "event {event}: {} KV copy bytes exceed the {} \
                         byte budget",
                        audit.kv_copied_bytes, audit.migration_budget_bytes
                    ),
                ));
            }
        }
    }
    out
}

/// Invariant 3: exactly-once finish per sequence, no token loss.
pub fn check_exactly_once_finish(trace: &Trace) -> Vec<Violation> {
    let mut out = Vec::new();
    // id -> (requested tokens, finish count).
    let mut ledger: BTreeMap<u64, (usize, usize)> = BTreeMap::new();
    for ev in &trace.events {
        match ev {
            TraceEvent::Arrival { id, tokens, .. } => {
                if ledger.insert(*id, (*tokens, 0)).is_some() {
                    out.push(Violation::new(
                        "exactly-once",
                        format!("request {id} arrived twice"),
                    ));
                }
            }
            TraceEvent::Finished { id, tokens, .. } => {
                match ledger.get_mut(id) {
                    Some((want, n)) => {
                        *n += 1;
                        if *n > 1 {
                            out.push(Violation::new(
                                "exactly-once",
                                format!("request {id} finished {n} times"),
                            ));
                        }
                        if *want != *tokens {
                            out.push(Violation::new(
                                "token-loss",
                                format!(
                                    "request {id} produced {tokens} of \
                                     {want} requested tokens"
                                ),
                            ));
                        }
                    }
                    None => out.push(Violation::new(
                        "exactly-once",
                        format!("request {id} finished without arriving"),
                    )),
                }
            }
            _ => {}
        }
    }
    for (id, (_, n)) in &ledger {
        if *n == 0 {
            out.push(Violation::new(
                "exactly-once",
                format!("request {id} never finished (lost)"),
            ));
        }
    }
    out
}

/// Invariant 4 with the default [`STEP_SLACK`] resume allowance.
pub fn check_intake_pause_bounded(trace: &Trace) -> Vec<Violation> {
    check_intake_pause_bounded_with_slack(trace, STEP_SLACK)
}

/// Invariant 4: intake pauses always resume, never double-open per
/// event, and stay inside the owning event's declared pause window.
/// `resume_slack` is the caller's upper bound on one engine step in
/// simulated seconds — the closing edge may lag the declared end by
/// that much, since windows are enacted between steps.
pub fn check_intake_pause_bounded_with_slack(
    trace: &Trace,
    resume_slack: f64,
) -> Vec<Violation> {
    let mut out = Vec::new();
    // event -> declared window (absolute).
    let mut declared: BTreeMap<usize, Option<(f64, f64)>> = BTreeMap::new();
    for ev in &trace.events {
        if let TraceEvent::ScaleCommand {
            event,
            declared_pause,
            ..
        } = ev
        {
            declared.insert(*event, *declared_pause);
        }
    }
    let check_edge = |event: usize, t: f64, edge: &str| -> Option<Violation> {
        // Resumes may lag the declared end by one engine step.
        let tail = if edge == "resume" { resume_slack } else { EPS };
        match declared.get(&event) {
            Some(Some((a, b))) => {
                if t < a - EPS || t > b + tail {
                    return Some(Violation::new(
                        "intake-pause-bounded",
                        format!(
                            "event {event}: {edge} at {t:.6} outside the \
                             declared window [{a:.6}, {b:.6}]"
                        ),
                    ));
                }
                None
            }
            Some(None) => Some(Violation::new(
                "intake-pause-bounded",
                format!(
                    "event {event}: {edge} at {t:.6} but no pause window \
                     was declared"
                ),
            )),
            None => Some(Violation::new(
                "intake-pause-bounded",
                format!("event {event}: {edge} for an unknown event"),
            )),
        }
    };
    // Pauses are tracked per event: a fleet run can have two replicas'
    // windows overlapping in (global) trace order, which is fine — what
    // is not fine is two pauses for the *same* event, a resume without a
    // pause, or a pause that never resumes.
    let mut open: BTreeMap<usize, f64> = BTreeMap::new();
    for ev in &trace.events {
        match ev {
            TraceEvent::IntakePaused { t, event } => {
                if open.insert(*event, *t).is_some() {
                    out.push(Violation::new(
                        "intake-pause-bounded",
                        format!(
                            "event {event}: pause at {t:.6} while its \
                             earlier pause is still open"
                        ),
                    ));
                }
                out.extend(check_edge(*event, *t, "pause"));
            }
            TraceEvent::IntakeResumed { t, event } => {
                match open.remove(event) {
                    Some(t0) => {
                        if *t < t0 - EPS {
                            out.push(Violation::new(
                                "intake-pause-bounded",
                                format!(
                                    "event {event}: resume at {t:.6} before \
                                     pause at {t0:.6}"
                                ),
                            ));
                        }
                    }
                    None => out.push(Violation::new(
                        "intake-pause-bounded",
                        format!(
                            "event {event}: resume at {t:.6} without an \
                             open pause"
                        ),
                    )),
                }
                out.extend(check_edge(*event, *t, "resume"));
            }
            _ => {}
        }
    }
    for (e, t0) in &open {
        out.push(Violation::new(
            "intake-pause-bounded",
            format!("event {e}: pause opened at {t0:.6} never resumed"),
        ));
    }
    out
}

/// Invariant 5: every suspended sequence is disposed of exactly once —
/// resumed (abort), adopted, or restarted.
pub fn check_suspend_disposition(trace: &Trace) -> Vec<Violation> {
    let mut out = Vec::new();
    // (event, id) -> dispositions seen after suspension.
    let mut suspended: BTreeMap<(usize, u64), usize> = BTreeMap::new();
    for ev in &trace.events {
        match ev {
            TraceEvent::Suspended { event, id, .. } => {
                if suspended.insert((*event, *id), 0).is_some() {
                    out.push(Violation::new(
                        "suspend-disposition",
                        format!("event {event}: request {id} suspended twice"),
                    ));
                }
            }
            TraceEvent::Resumed { event, id, .. } => {
                match suspended.get_mut(&(*event, *id)) {
                    Some(n) => *n += 1,
                    None => out.push(Violation::new(
                        "suspend-disposition",
                        format!(
                            "event {event}: request {id} resumed without \
                             being suspended"
                        ),
                    )),
                }
            }
            TraceEvent::Adopted { event, id, .. }
            | TraceEvent::Restarted { event, id, .. } => {
                // Only counts as the suspension's disposition when this
                // sequence was suspended for this event; unsuspended
                // drained sequences are disposed here too, legitimately.
                if let Some(n) = suspended.get_mut(&(*event, *id)) {
                    *n += 1;
                }
            }
            _ => {}
        }
    }
    for ((event, id), n) in &suspended {
        if *n != 1 {
            out.push(Violation::new(
                "suspend-disposition",
                format!(
                    "event {event}: request {id} suspended but disposed \
                     {n} times (want exactly 1)"
                ),
            ));
        }
    }
    out
}

/// Invariant 8: exactly-once handoff disposition. Every
/// [`TraceEvent::HandoffPlanned`] is answered by exactly one
/// [`TraceEvent::HandoffDone`] for the same sequence — the decode
/// replica either adopted the transferred KV or fell back to recompute,
/// but never both and never neither. A sequence may hand off more than
/// once over its life (an eviction can send it back through prefill);
/// each planned leg still needs its own disposition. Traces with no
/// handoffs (unified fleets, single-instance runs) pass vacuously.
pub fn check_handoff_disposition(trace: &Trace) -> Vec<Violation> {
    let mut out = Vec::new();
    // id -> handoffs planned but not yet dispositioned.
    let mut open: BTreeMap<u64, usize> = BTreeMap::new();
    for ev in &trace.events {
        match ev {
            TraceEvent::HandoffPlanned { id, .. } => {
                *open.entry(*id).or_default() += 1;
            }
            TraceEvent::HandoffDone { id, .. } => {
                match open.get_mut(id) {
                    Some(n) if *n > 0 => *n -= 1,
                    _ => out.push(Violation::new(
                        "handoff-disposition",
                        format!(
                            "request {id} dispositioned a handoff that \
                             was never planned"
                        ),
                    )),
                }
            }
            _ => {}
        }
    }
    for (id, n) in &open {
        if *n > 0 {
            out.push(Violation::new(
                "handoff-disposition",
                format!(
                    "request {id}: {n} planned handoff(s) never \
                     dispositioned"
                ),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::trace::PlanAudit;

    fn audit(snapshot: usize, remap: usize, copy: usize, freed: usize) -> PlanAudit {
        PlanAudit {
            snapshot_blocks: snapshot,
            kv_remapped_blocks: remap,
            kv_copied_blocks: copy,
            kv_freed_blocks: freed,
            kv_copied_bytes: 10,
            migration_budget_bytes: 100,
            expert_migration_bytes: 0,
        }
    }

    fn conformant_trace() -> Trace {
        let mut tr = Trace::new();
        tr.push(TraceEvent::Arrival { t: 0.0, id: 1, tokens: 5 });
        tr.push(TraceEvent::Arrival { t: 0.1, id: 2, tokens: 7 });
        tr.push(TraceEvent::ScaleCommand {
            t: 10.0,
            event: 0,
            from_devices: 4,
            to_devices: 6,
            declared_pause: Some((12.0, 13.0)),
        });
        tr.push(TraceEvent::PlanAudited {
            t: 10.0,
            event: 0,
            audit: audit(10, 6, 3, 1),
        });
        tr.push(TraceEvent::IntakePaused { t: 12.0, event: 0 });
        tr.push(TraceEvent::Suspended { t: 12.0, event: 0, id: 2 });
        tr.push(TraceEvent::IntakeResumed { t: 13.0, event: 0 });
        tr.push(TraceEvent::Adopted { t: 13.0, event: 0, id: 1, remap: true });
        tr.push(TraceEvent::Adopted { t: 13.0, event: 0, id: 2, remap: false });
        tr.push(TraceEvent::ScaleCompleted { t: 13.0, event: 0, devices: 6 });
        tr.push(TraceEvent::Finished { t: 14.0, id: 1, tokens: 5 });
        tr.push(TraceEvent::Finished { t: 15.0, id: 2, tokens: 7 });
        tr
    }

    #[test]
    fn conformant_trace_passes_everything() {
        let v = check_all(&conformant_trace());
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn broken_conservation_is_caught() {
        let mut tr = conformant_trace();
        tr.push(TraceEvent::PlanAudited {
            t: 20.0,
            event: 1,
            audit: audit(10, 6, 3, 0), // one block vanished
        });
        let v = check_block_conservation(&tr);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].invariant, "block-conservation");
    }

    #[test]
    fn budget_overrun_is_caught() {
        let mut tr = Trace::new();
        let mut a = audit(4, 0, 4, 0);
        a.kv_copied_bytes = 200; // budget is 100
        tr.push(TraceEvent::PlanAudited { t: 1.0, event: 0, audit: a });
        let v = check_byte_budget(&tr);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].invariant, "byte-budget");
    }

    #[test]
    fn double_finish_and_token_loss_are_caught() {
        let mut tr = conformant_trace();
        tr.push(TraceEvent::Finished { t: 16.0, id: 1, tokens: 5 });
        tr.push(TraceEvent::Arrival { t: 16.0, id: 3, tokens: 9 });
        tr.push(TraceEvent::Finished { t: 17.0, id: 3, tokens: 4 });
        let v = check_exactly_once_finish(&tr);
        assert!(v.iter().any(|v| v.invariant == "exactly-once"
            && v.detail.contains("finished 2 times")));
        assert!(v.iter().any(|v| v.invariant == "token-loss"));
    }

    #[test]
    fn lost_request_is_caught() {
        let mut tr = Trace::new();
        tr.push(TraceEvent::Arrival { t: 0.0, id: 9, tokens: 5 });
        let v = check_exactly_once_finish(&tr);
        assert!(v.iter().any(|v| v.detail.contains("never finished")));
    }

    #[test]
    fn out_of_window_pause_is_caught() {
        let mut tr = Trace::new();
        tr.push(TraceEvent::ScaleCommand {
            t: 10.0,
            event: 0,
            from_devices: 4,
            to_devices: 6,
            declared_pause: Some((12.0, 13.0)),
        });
        tr.push(TraceEvent::IntakePaused { t: 10.5, event: 0 });
        tr.push(TraceEvent::IntakeResumed { t: 13.0, event: 0 });
        let v = check_intake_pause_bounded(&tr);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].detail.contains("outside the declared window"));
    }

    #[test]
    fn resume_may_lag_one_step_but_not_more() {
        let command = TraceEvent::ScaleCommand {
            t: 10.0,
            event: 0,
            from_devices: 4,
            to_devices: 6,
            declared_pause: Some((12.0, 13.0)),
        };
        // Resume one engine step after the declared end: tolerated.
        let mut tr = Trace::new();
        tr.push(command.clone());
        tr.push(TraceEvent::IntakePaused { t: 12.0, event: 0 });
        tr.push(TraceEvent::IntakeResumed { t: 14.5, event: 0 });
        assert!(check_intake_pause_bounded(&tr).is_empty());
        // Far beyond the slack: violation.
        let mut tr = Trace::new();
        tr.push(command);
        tr.push(TraceEvent::IntakePaused { t: 12.0, event: 0 });
        tr.push(TraceEvent::IntakeResumed { t: 20.0, event: 0 });
        assert_eq!(check_intake_pause_bounded(&tr).len(), 1);
    }

    #[test]
    fn unresumed_pause_is_caught() {
        let mut tr = Trace::new();
        tr.push(TraceEvent::ScaleCommand {
            t: 10.0,
            event: 0,
            from_devices: 4,
            to_devices: 6,
            declared_pause: Some((12.0, 13.0)),
        });
        tr.push(TraceEvent::IntakePaused { t: 12.0, event: 0 });
        let v = check_intake_pause_bounded(&tr);
        assert!(v.iter().any(|v| v.detail.contains("never resumed")));
    }

    #[test]
    fn tier_conservation_reconciles_journal_and_audit() {
        use crate::tier::TierLevel::{Disk, HostDram, Hbm};
        let shift = |replica, tag: &str, bytes, from, to| {
            TraceEvent::TierShift {
                t: 1.0,
                replica,
                tag: tag.into(),
                bytes,
                from,
                to,
            }
        };
        let audit = |replica, dram_bytes| TraceEvent::TierAudit {
            t: 2.0,
            replica,
            dram_bytes,
        };

        // Clean park → unpark cycle on replica 0; a staged prefetch on
        // replica 1 (per-replica totals are independent).
        let mut tr = Trace::new();
        tr.push(shift(0, "w", 100, Hbm, HostDram));
        tr.push(shift(0, "e", 50, Hbm, HostDram));
        tr.push(audit(0, 150));
        tr.push(shift(1, "w", 100, Disk, HostDram));
        tr.push(audit(1, 100));
        tr.push(shift(0, "w", 100, HostDram, Hbm));
        tr.push(shift(0, "e", 50, HostDram, Hbm));
        tr.push(audit(0, 0));
        assert!(check_tier_conservation(&tr).is_empty());

        // Audit mismatch: the allocator says 10 bytes leaked.
        let mut bad = Trace::new();
        bad.push(shift(0, "w", 100, Hbm, HostDram));
        bad.push(audit(0, 90));
        let v = check_tier_conservation(&bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].invariant, "tier-conservation");

        // Wrong source tier: the unit never entered DRAM.
        let mut bad = Trace::new();
        bad.push(shift(0, "w", 100, Hbm, HostDram));
        bad.push(shift(0, "w", 100, Disk, Hbm));
        assert!(!check_tier_conservation(&bad).is_empty());

        // Byte-size drift between entry and exit.
        let mut bad = Trace::new();
        bad.push(shift(0, "w", 100, Hbm, HostDram));
        bad.push(shift(0, "w", 60, HostDram, Hbm));
        assert!(!check_tier_conservation(&bad).is_empty());

        // A non-move shift is rejected outright.
        let mut bad = Trace::new();
        bad.push(shift(0, "w", 100, Hbm, Hbm));
        assert!(!check_tier_conservation(&bad).is_empty());
    }

    #[test]
    fn convergence_bounds_post_fault_drift() {
        let declared = |t: f64, drift: usize| TraceEvent::SpecDeclared {
            t,
            replicas: 2,
            devices: 6,
            parked: 0,
            drift,
        };
        // A fleet that settles: drift clears well inside the bound.
        let mut tr = Trace::new();
        tr.push(TraceEvent::FaultFired {
            t: 5.0,
            event: 0,
            fault: crate::chaos::FaultKind::DuplicateCommand,
        });
        tr.push(declared(10.0, 2));
        tr.push(declared(15.0, 1));
        tr.push(declared(20.0, 0));
        assert!(check_reconcile_convergence(&tr).is_empty());

        // Drift held for CONVERGENCE_ROUNDS rounds after the fault:
        // violation.
        let mut tr = Trace::new();
        tr.push(TraceEvent::FaultFired {
            t: 5.0,
            event: 0,
            fault: crate::chaos::FaultKind::DuplicateCommand,
        });
        for i in 0..CONVERGENCE_ROUNDS {
            tr.push(declared(10.0 + i as f64, 1));
        }
        let v = check_reconcile_convergence(&tr);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].invariant, "reconcile-convergence");

        // The same drifting streak *before* the last fault is excused.
        tr.push(TraceEvent::FaultFired {
            t: 50.0,
            event: 1,
            fault: crate::chaos::FaultKind::DuplicateCommand,
        });
        tr.push(declared(55.0, 0));
        assert!(check_reconcile_convergence(&tr).is_empty());

        // No SpecDeclared events at all (single-instance runs): vacuous.
        assert!(check_reconcile_convergence(&conformant_trace()).is_empty());
    }

    #[test]
    fn handoff_disposition_is_exactly_once() {
        let planned = |t: f64, id: u64| TraceEvent::HandoffPlanned {
            t,
            id,
            from_replica: 0,
            to_replica: 1,
            bytes: 2048,
            legs: 2,
        };
        let done = |t: f64, id: u64, recompute| TraceEvent::HandoffDone {
            t,
            id,
            to_replica: 1,
            recompute,
        };
        // Happy path: one adoption and one recompute fallback, each
        // dispositioned exactly once.
        let mut tr = Trace::new();
        tr.push(planned(1.0, 7));
        tr.push(planned(1.0, 8));
        tr.push(done(2.0, 7, false));
        tr.push(done(2.5, 8, true));
        assert!(check_handoff_disposition(&tr).is_empty());

        // A sequence may hand off twice (eviction sent it back through
        // prefill) as long as both legs disposition.
        tr.push(planned(3.0, 7));
        tr.push(done(4.0, 7, true));
        assert!(check_handoff_disposition(&tr).is_empty());

        // Dropped handoff: planned, never dispositioned.
        let mut bad = Trace::new();
        bad.push(planned(1.0, 9));
        let v = check_handoff_disposition(&bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].invariant, "handoff-disposition");
        assert!(v[0].detail.contains("never dispositioned"));

        // Double disposition of a single planned handoff.
        let mut bad = Trace::new();
        bad.push(planned(1.0, 9));
        bad.push(done(2.0, 9, false));
        bad.push(done(2.1, 9, true));
        let v = check_handoff_disposition(&bad);
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains("never planned"));

        // Unified fleets (no handoff events at all): vacuous pass.
        assert!(check_handoff_disposition(&conformant_trace()).is_empty());
    }

    #[test]
    fn dangling_suspension_is_caught() {
        let mut tr = Trace::new();
        tr.push(TraceEvent::Suspended { t: 1.0, event: 0, id: 5 });
        let v = check_suspend_disposition(&tr);
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains("disposed 0 times"));
        // A resume settles it.
        tr.push(TraceEvent::Resumed { t: 2.0, event: 0, id: 5 });
        assert!(check_suspend_disposition(&tr).is_empty());
        // A second disposition breaks it again.
        tr.push(TraceEvent::Restarted { t: 3.0, event: 0, id: 5 });
        assert_eq!(check_suspend_disposition(&tr).len(), 1);
    }
}
