//! SLO targets: TTFT/TPOT thresholds and the attainment goal the paper's
//! Coordinator monitors (§4.3, §7.3).

/// Service-level objective for a serving deployment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    /// Time-to-first-token threshold, seconds.
    pub ttft: f64,
    /// Time-per-output-token threshold, seconds.
    pub tpot: f64,
    /// Target attainment fraction (paper uses 90%).
    pub target_attainment: f64,
}

impl SloConfig {
    pub fn new(ttft: f64, tpot: f64) -> Self {
        SloConfig {
            ttft,
            tpot,
            target_attainment: 0.9,
        }
    }

    /// §7.6's thresholds: TTFT <= 1000 ms, TPOT <= 1000 ms.
    pub fn strict() -> Self {
        SloConfig::new(1.0, 1.0)
    }

    /// §7.5 scale-up setting: TTFT <= 5 s, TPOT <= 1.5 s.
    pub fn scale_up_demo() -> Self {
        SloConfig::new(5.0, 1.5)
    }

    /// §7.5 scale-down setting: TTFT <= 2 s, TPOT <= 1 s.
    pub fn scale_down_demo() -> Self {
        SloConfig::new(2.0, 1.0)
    }

    /// Does a request with the given latencies meet the SLO?
    pub fn met(&self, ttft: f64, tpot: f64) -> bool {
        ttft <= self.ttft && tpot <= self.tpot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholds() {
        let slo = SloConfig::strict();
        assert!(slo.met(0.5, 0.9));
        assert!(!slo.met(1.5, 0.5));
        assert!(!slo.met(0.5, 1.5));
        assert_eq!(slo.target_attainment, 0.9);
    }
}
