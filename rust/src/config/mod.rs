//! Configuration: model architectures (the paper's three MoE LLMs as
//! byte-accurate accounting configs + the live e2e model), parallelism
//! layouts (DP/TP/EP), SLO targets and cluster settings.

pub mod model;
pub mod parallel;
pub mod slo;

pub use model::{ModelConfig, MODELS};
pub use parallel::ParallelConfig;
pub use slo::SloConfig;
