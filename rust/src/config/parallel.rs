//! Parallelism layout: the (DP, TP, EP) triple and the expert placement it
//! induces. The paper scales by adjusting DP and EP while TP stays fixed
//! (§4.1), with the common constraint `EP = TP x DP` (§2.1).

use anyhow::{bail, Result};

use super::model::ModelConfig;
use crate::device::DeviceId;

/// One inference instance's parallel layout over a concrete device set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParallelConfig {
    pub dp: usize,
    pub tp: usize,
    pub ep: usize,
    /// The devices this layout occupies, in rank order: device
    /// `devices[d*tp + t]` is DP replica `d`, TP shard `t`.
    pub devices: Vec<DeviceId>,
}

impl ParallelConfig {
    /// Standard layout: `EP = TP x DP`, one EP shard per device.
    pub fn standard(dp: usize, tp: usize, devices: Vec<DeviceId>) -> Result<Self> {
        if dp * tp != devices.len() {
            bail!(
                "DP{dp} x TP{tp} needs {} devices, got {}",
                dp * tp,
                devices.len()
            );
        }
        Ok(ParallelConfig {
            dp,
            tp,
            ep: dp * tp,
            devices,
        })
    }

    /// Explicit-EP layout: used to model horizontally replicated instances,
    /// where the *aggregate* device set is large but each replica confines
    /// its experts to its own EP group (the paper's L4 inefficiency).
    pub fn with_ep(
        dp: usize,
        tp: usize,
        ep: usize,
        devices: Vec<DeviceId>,
    ) -> Result<Self> {
        if dp * tp != devices.len() {
            bail!(
                "DP{dp} x TP{tp} needs {} devices, got {}",
                dp * tp,
                devices.len()
            );
        }
        if ep == 0 || ep > devices.len() {
            bail!("EP{ep} invalid for {} devices", devices.len());
        }
        Ok(ParallelConfig {
            dp,
            tp,
            ep,
            devices,
        })
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Short display form, e.g. "DP3-TP2-EP6".
    pub fn label(&self) -> String {
        format!("DP{}-TP{}-EP{}", self.dp, self.tp, self.ep)
    }

    /// The device holding EP rank `r`.
    pub fn ep_device(&self, r: usize) -> DeviceId {
        self.devices[r % self.devices.len()]
    }

    /// Balanced expert placement: expert `e` of `n_experts` lives on EP rank
    /// `e % ep` (round-robin, the paper's default). Load-aware rebalancing
    /// lives in [`crate::placement`] and takes over during scaling events
    /// when [`crate::placement::PlacementMode::LoadAware`] is enabled.
    /// Returns, per EP rank, the expert ids it owns.
    pub fn expert_placement(&self, n_experts: usize) -> Vec<Vec<usize>> {
        let mut owners = vec![Vec::new(); self.ep];
        for e in 0..n_experts {
            owners[e % self.ep].push(e);
        }
        owners
    }

    /// Experts per device (ceiling), for memory sizing.
    pub fn experts_per_device(&self, n_experts: usize) -> usize {
        n_experts.div_ceil(self.ep)
    }

    /// Validate against a model (TP must match the model's fixed TP and the
    /// expert count must be divisible enough to be balanced).
    pub fn check_model(&self, m: &ModelConfig) -> Result<()> {
        if self.tp != m.tp {
            bail!(
                "model {} fixes TP={}, layout has TP={}",
                m.name,
                m.tp,
                self.tp
            );
        }
        if self.ep > m.n_experts as usize {
            bail!(
                "EP{} exceeds expert count {} of {}",
                self.ep,
                m.n_experts,
                m.name
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;

    #[test]
    fn standard_layout() {
        let p = ParallelConfig::standard(3, 2, (0..6).collect()).unwrap();
        assert_eq!(p.ep, 6);
        assert_eq!(p.label(), "DP3-TP2-EP6");
        assert!(ParallelConfig::standard(2, 2, vec![0, 1]).is_err());
    }

    #[test]
    fn placement_is_balanced_and_complete() {
        let p = ParallelConfig::standard(2, 2, (0..4).collect()).unwrap();
        let placement = p.expert_placement(64);
        assert_eq!(placement.len(), 4);
        let counts: Vec<usize> = placement.iter().map(|v| v.len()).collect();
        assert!(counts.iter().all(|&c| c == 16));
        let mut all: Vec<usize> =
            placement.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn uneven_placement_spreads_remainder() {
        let p = ParallelConfig::standard(3, 2, (0..6).collect()).unwrap();
        let placement = p.expert_placement(64); // 64 over 6 ranks
        let counts: Vec<usize> = placement.iter().map(|v| v.len()).collect();
        assert_eq!(counts.iter().sum::<usize>(), 64);
        assert!(counts.iter().max().unwrap() - counts.iter().min().unwrap() <= 1);
    }

    #[test]
    fn model_check_enforces_fixed_tp() {
        let m = dsv2_lite();
        let ok = ParallelConfig::standard(2, 2, (0..4).collect()).unwrap();
        assert!(ok.check_model(&m).is_ok());
        let bad_tp = ParallelConfig::standard(1, 4, (0..4).collect()).unwrap();
        assert!(bad_tp.check_model(&m).is_err());
        let bad_ep = ParallelConfig::standard(64, 2, (0..128).collect()).unwrap();
        assert!(bad_ep.check_model(&m).is_err());
    }
}
