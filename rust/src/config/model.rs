//! Model architecture configs.
//!
//! The three paper models are *accounting configs*: their published layer /
//! expert / dimension counts produce real byte counts that drive the memory
//! model (Fig 4b, Fig 8, Tables 1/3) and the roofline cost model (Figs 1,
//! 9, 10, Table 2). The `e2e` config mirrors `python/compile/config.py` and
//! is served live through PJRT.

/// Architecture + serving-relevant constants for one MoE model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: u64,
    pub d_model: u64,
    pub n_layers: u64,
    pub n_heads: u64,
    pub head_dim: u64,
    /// KV projection dim per token per layer (bytes follow from dtype).
    /// MLA-style models compress KV; this is the *effective* cached dim.
    pub kv_dim: u64,
    /// Per-expert FFN hidden dim.
    pub d_ff_expert: u64,
    /// Dense (shared) FFN hidden dim; 0 if the model has no dense FFN path.
    pub d_ff_dense: u64,
    pub n_experts: u64,
    pub n_shared_experts: u64,
    pub top_k: u64,
    /// Weight dtype bytes (bf16 = 2 for the paper models, f32 = 4 for e2e).
    pub dtype_bytes: u64,
    /// Fixed TP degree used during scaling (the paper holds TP fixed).
    pub tp: usize,
    /// Minimum devices for one instance (weights must fit).
    pub min_devices: usize,
}

impl ModelConfig {
    /// ---- byte accounting -------------------------------------------------

    /// Attention + gate + norms per layer (everything except experts).
    pub fn attn_bytes_per_layer(&self) -> u64 {
        let qkv = self.n_heads * self.head_dim;
        // wq, wk, wv, wo (+ gate + norms, small)
        let attn = 4 * self.d_model * qkv;
        let gate = self.d_model * self.n_experts;
        let norms = 2 * self.d_model;
        let dense_ffn = 3 * self.d_model * self.d_ff_dense;
        (attn + gate + norms + dense_ffn) * self.dtype_bytes
    }

    /// One expert's weights (SwiGLU: w1, w3, w2).
    pub fn expert_bytes(&self) -> u64 {
        3 * self.d_model * self.d_ff_expert * self.dtype_bytes
    }

    /// Embedding (+ tied output head) bytes.
    pub fn embed_bytes(&self) -> u64 {
        self.vocab * self.d_model * self.dtype_bytes
    }

    /// Total model bytes.
    pub fn total_bytes(&self) -> u64 {
        self.embed_bytes()
            + self.n_layers
                * (self.attn_bytes_per_layer()
                    + (self.n_experts + self.n_shared_experts)
                        * self.expert_bytes())
    }

    /// Per-device weight bytes under a (TP, EP) layout: attention sharded by
    /// TP, experts spread over EP devices, shared experts + embeddings
    /// replicated per TP group.
    pub fn device_weight_bytes(&self, tp: usize, ep: usize) -> u64 {
        let experts_here = (self.n_experts as usize).div_ceil(ep) as u64
            + self.n_shared_experts;
        self.embed_bytes() / tp as u64
            + self.n_layers
                * (self.attn_bytes_per_layer() / tp as u64
                    + experts_here * self.expert_bytes())
    }

    /// KV-cache bytes per token (all layers, both K and V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.n_layers * self.kv_dim * self.dtype_bytes
    }

    /// Active (touched-per-token) weight bytes per decode step per device —
    /// drives the weight-read-bound decode roofline.
    pub fn active_bytes_per_device(&self, tp: usize, ep: usize) -> u64 {
        // Attention is dense; only top_k (+ shared) experts are touched, but
        // with large batches most resident experts are hit: we charge the
        // min(resident, per-batch-activated) experts in the cost model; here
        // report the dense part + one expert as the per-token lower bound.
        let experts_resident = (self.n_experts as usize).div_ceil(ep) as u64
            + self.n_shared_experts;
        self.n_layers
            * (self.attn_bytes_per_layer() / tp as u64
                + experts_resident.min(self.top_k + self.n_shared_experts)
                    * self.expert_bytes())
    }

    /// FLOPs per token per decode step (2 * active params, standard rule).
    pub fn flops_per_token(&self) -> f64 {
        let qkv = self.n_heads * self.head_dim;
        let attn = 4 * self.d_model * qkv;
        let experts =
            (self.top_k + self.n_shared_experts) * 3 * self.d_model * self.d_ff_expert;
        let dense = 3 * self.d_model * self.d_ff_dense;
        2.0 * (self.n_layers * (attn + experts + dense) + self.embed_bytes()
            / self.dtype_bytes) as f64
    }

    pub fn param_count(&self) -> u64 {
        self.total_bytes() / self.dtype_bytes
    }
}

/// DeepSeek V2 Lite: 15.7B total / 2.4B active, 26 MoE layers, 64 routed
/// experts (+2 shared), top-6, d_model 2048, expert hidden 1408, MLA KV.
pub fn dsv2_lite() -> ModelConfig {
    ModelConfig {
        name: "dsv2lite",
        vocab: 102_400,
        d_model: 2048,
        n_layers: 27,
        n_heads: 16,
        head_dim: 128,
        kv_dim: 576, // MLA compressed KV per token per layer
        d_ff_expert: 1408,
        d_ff_dense: 0,
        n_experts: 64,
        n_shared_experts: 2,
        top_k: 6,
        dtype_bytes: 2,
        tp: 2,
        min_devices: 2,
    }
}

/// Qwen3-30B-A3B: 30.5B total / 3.3B active, 48 layers, 128 experts, top-8,
/// d_model 2048, expert hidden 768, GQA (4 KV heads x 128).
pub fn qwen30b() -> ModelConfig {
    ModelConfig {
        name: "qwen30b",
        vocab: 151_936,
        d_model: 2048,
        n_heads: 32,
        head_dim: 128,
        kv_dim: 2 * 4 * 128 / 2, // 4 KV heads * 128, counted once per K/V
        n_layers: 48,
        d_ff_expert: 768,
        d_ff_dense: 0,
        n_experts: 128,
        n_shared_experts: 0,
        top_k: 8,
        dtype_bytes: 2,
        tp: 2,
        min_devices: 4,
    }
}

/// DeepSeek V3: 671B total / 37B active, 61 layers, 256 routed experts
/// (+1 shared), top-8, d_model 7168, expert hidden 2048, MLA KV.
pub fn dsv3() -> ModelConfig {
    ModelConfig {
        name: "dsv3",
        vocab: 129_280,
        d_model: 7168,
        n_heads: 128,
        head_dim: 128,
        kv_dim: 576,
        n_layers: 61,
        d_ff_expert: 2048,
        d_ff_dense: 0,
        n_experts: 256,
        n_shared_experts: 1,
        top_k: 8,
        dtype_bytes: 2,
        tp: 8,
        // "even a minimal DeepSeek V3 inference instance may span 32
        // accelerators" (§1) — and indeed EP16 would need ~91 GB/device.
        min_devices: 32,
    }
}

/// The live end-to-end model (mirrors `python/compile/config.py::E2E`).
pub fn e2e() -> ModelConfig {
    ModelConfig {
        name: "elastic-moe-e2e",
        vocab: 2048,
        d_model: 256,
        n_layers: 4,
        n_heads: 4,
        head_dim: 64,
        kv_dim: 256,
        d_ff_expert: 512,
        d_ff_dense: 0,
        n_experts: 8,
        n_shared_experts: 0,
        top_k: 2,
        dtype_bytes: 4,
        tp: 1,
        min_devices: 1,
    }
}

/// Model registry by name.
pub const MODELS: &[&str] = &["dsv2lite", "qwen30b", "dsv3", "e2e"];

/// Look up a model config by name.
pub fn by_name(name: &str) -> Option<ModelConfig> {
    match name {
        "dsv2lite" => Some(dsv2_lite()),
        "qwen30b" => Some(qwen30b()),
        "dsv3" => Some(dsv3()),
        "e2e" | "elastic-moe-e2e" => Some(e2e()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published_sizes() {
        // Within 20% of the published totals — the accounting formula only
        // covers the structural blocks we model.
        let d = dsv2_lite();
        let b = d.param_count() as f64 / 1e9;
        assert!((12.0..19.0).contains(&b), "dsv2lite {b}B");

        let q = qwen30b();
        let b = q.param_count() as f64 / 1e9;
        assert!((24.0..36.0).contains(&b), "qwen30b {b}B");

        let v3 = dsv3();
        let b = v3.param_count() as f64 / 1e9;
        assert!((550.0..780.0).contains(&b), "dsv3 {b}B");
    }

    #[test]
    fn experts_dominate_model_size() {
        // The paper's L4: "expert layers dominate model size".
        for m in [dsv2_lite(), qwen30b(), dsv3()] {
            let expert_total =
                m.n_layers * m.n_experts * m.expert_bytes();
            assert!(
                expert_total as f64 / m.total_bytes() as f64 > 0.7,
                "{}: experts only {:.0}%",
                m.name,
                100.0 * expert_total as f64 / m.total_bytes() as f64
            );
        }
    }

    #[test]
    fn higher_ep_means_less_weight_memory_per_device() {
        // Fig 4b's monotonic shape.
        let m = dsv2_lite();
        let mut prev = u64::MAX;
        for ep in [2usize, 4, 8, 16, 32, 64] {
            let b = m.device_weight_bytes(m.tp, ep);
            assert!(b < prev, "EP{ep}: {b} !< {prev}");
            prev = b;
        }
    }

    #[test]
    fn device_weights_fit_in_hbm_at_min_devices() {
        for m in [dsv2_lite(), qwen30b(), dsv3()] {
            let ep = m.min_devices;
            let per_dev = m.device_weight_bytes(m.tp, ep);
            assert!(
                per_dev < 64 << 30,
                "{}: {} GB per device at min config",
                m.name,
                per_dev >> 30
            );
        }
    }

    #[test]
    fn e2e_matches_python_manifest_params() {
        // python/compile/config.py reports 14.2M params for E2E.
        let m = e2e();
        let p = m.param_count() as f64 / 1e6;
        assert!((13.0..15.0).contains(&p), "e2e {p}M");
    }

    #[test]
    fn registry_lookup() {
        assert!(by_name("dsv2lite").is_some());
        assert!(by_name("nope").is_none());
        for name in MODELS {
            if *name != "e2e" {
                assert!(by_name(name).is_some());
            }
        }
    }
}
