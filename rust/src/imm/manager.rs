//! Instance manager: tracks instance lifecycles, the standby LRU cache, and
//! the active-instance pointer; produces ready-to-attach instances for the
//! scaling choreography (§4.5).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::config::ParallelConfig;
use crate::device::Timings;

use super::instance::{Instance, InstanceId, InstanceState};
use super::lru::LruCache;

/// IMM policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct ImmOptions {
    /// Keep pre-initialised standby instances (the `-PreInit` ablation
    /// disables this: every acquisition pays full CPU pre-init).
    pub pre_init: bool,
    /// Hot standby cache capacity (fully pre-initialised, free to
    /// acquire).
    pub lru_cap: usize,
    /// DRAM-warm second-level capacity: instances evicted from the hot
    /// level demote here (engine state swapped to host memory, comm
    /// groups kept) instead of dropping; acquiring one pays only
    /// [`Timings::host_restore`] instead of full CPU pre-init. 0
    /// disables the level (hot evictions drop, the pre-tier behaviour).
    pub dram_cap: usize,
}

impl Default for ImmOptions {
    fn default() -> Self {
        ImmOptions {
            pre_init: true,
            // One slot per anticipated configuration (ElasticMoE prepares
            // standbys for deltas -1/+1/+2/+4 and the current shape).
            lru_cap: 5,
            dram_cap: 8,
        }
    }
}

/// The Inference Management Module.
pub struct InstanceManager {
    pub opts: ImmOptions,
    timings: Timings,
    next_id: InstanceId,
    /// Hot standby level: fully pre-initialised, free to acquire.
    standby: LruCache<String, Instance>,
    /// DRAM-warm level: evictees of the hot level, one host-restore away.
    dram_warm: Option<LruCache<String, Instance>>,
    pub instances: BTreeMap<InstanceId, Instance>,
    pub active: Option<InstanceId>,
}

impl InstanceManager {
    pub fn new(opts: ImmOptions, timings: Timings) -> Self {
        InstanceManager {
            opts,
            timings,
            next_id: 1,
            standby: LruCache::new(opts.lru_cap.max(1)),
            dram_warm: (opts.dram_cap > 0)
                .then(|| LruCache::new(opts.dram_cap)),
            instances: BTreeMap::new(),
            active: None,
        }
    }

    /// Insert into the hot standby level; a hot eviction demotes into the
    /// DRAM-warm level (HBM → DRAM → gone, never straight to gone while
    /// the second level has room).
    fn insert_standby(&mut self, label: String, inst: Instance) {
        if let Some((demoted_label, demoted)) = self.standby.insert(label, inst)
        {
            if let Some(warm) = self.dram_warm.as_mut() {
                // A second-level eviction is the true drop (back to disk).
                warm.insert(demoted_label, demoted);
            }
        }
    }

    fn next_id(&mut self) -> InstanceId {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Pre-initialise a standby instance for an anticipated configuration
    /// (done in the background; no scale-time cost).
    pub fn prepare_standby(
        &mut self,
        parallel: ParallelConfig,
        proc: u32,
    ) -> InstanceId {
        let id = self.next_id();
        let inst = Instance::standby(id, proc, parallel.clone());
        self.insert_standby(parallel.label(), inst);
        id
    }

    /// Whether a hot standby instance exists for the configuration.
    pub fn has_standby(&self, parallel: &ParallelConfig) -> bool {
        self.standby.contains(&parallel.label())
    }

    /// Whether a DRAM-warm (second-level) standby exists for the
    /// configuration.
    pub fn has_dram_warm(&self, parallel: &ParallelConfig) -> bool {
        self.dram_warm
            .as_ref()
            .map(|w| w.contains(&parallel.label()))
            .unwrap_or(false)
    }

    /// Pin the hot standby for `parallel` — the shape the next
    /// activation is most likely to need (the current configuration:
    /// redistribution-only events and park/unpark reacquire it) — so
    /// background anticipation churn cannot evict it. One shape is
    /// protected at a time: any previous pin is cleared. Returns false
    /// when the shape has no hot standby.
    pub fn pin_standby(&mut self, parallel: &ParallelConfig) -> bool {
        let keys: Vec<String> = self.standby.keys().cloned().collect();
        for k in &keys {
            self.standby.unpin(k);
        }
        self.standby.pin(&parallel.label())
    }

    /// Acquire an instance for `parallel`. Cost by warmth: a hot standby
    /// hit is free (pre-initialised, comm groups ready); a DRAM-warm hit
    /// pays only the host-memory state restore; a miss pays full CPU
    /// pre-init + communication-group setup. Returns (instance,
    /// prep_time).
    pub fn acquire(
        &mut self,
        parallel: &ParallelConfig,
        proc: u32,
    ) -> (Instance, f64) {
        if self.opts.pre_init {
            if let Some(mut inst) = self.standby.take(&parallel.label()) {
                inst.proc = proc;
                return (inst, 0.0);
            }
            if let Some(mut inst) = self
                .dram_warm
                .as_mut()
                .and_then(|w| w.take(&parallel.label()))
            {
                inst.proc = proc;
                return (inst, self.timings.host_restore);
            }
        }
        let id = self.next_id();
        let inst = Instance::standby(id, proc, parallel.clone());
        let t = self.timings.preinit_cpu
            + self.timings.comm_init(parallel.n_devices());
        (inst, t)
    }

    /// Register a prepared instance and mark it Ready.
    pub fn register_ready(&mut self, mut inst: Instance, now: f64) -> Result<InstanceId> {
        inst.transition(InstanceState::Preparing)?;
        inst.transition(InstanceState::Ready)?;
        inst.ready_at = Some(now);
        let id = inst.id;
        self.instances.insert(id, inst);
        Ok(id)
    }

    /// Route traffic to an instance (switchover endpoint).
    pub fn activate(&mut self, id: InstanceId) -> Result<()> {
        let inst = self
            .instances
            .get_mut(&id)
            .context("no such instance")?;
        inst.transition(InstanceState::Active)?;
        self.active = Some(id);
        Ok(())
    }

    /// Stop routing new requests to the active instance (begin drain).
    pub fn drain_active(&mut self) -> Result<Option<InstanceId>> {
        let Some(id) = self.active.take() else {
            return Ok(None);
        };
        self.instances
            .get_mut(&id)
            .context("active instance missing")?
            .transition(InstanceState::Draining)?;
        Ok(Some(id))
    }

    /// Retire an instance; optionally return it to the standby cache for
    /// future reuse (scale-down keeps the config warm).
    pub fn retire(
        &mut self,
        id: InstanceId,
        back_to_standby: bool,
    ) -> Result<Instance> {
        let mut inst = self
            .instances
            .remove(&id)
            .context("no such instance")?;
        inst.transition(InstanceState::Retired)?;
        if self.active == Some(id) {
            self.active = None;
        }
        if back_to_standby && self.opts.pre_init {
            let mut standby = Instance::standby(
                inst.id,
                inst.proc,
                inst.parallel.clone(),
            );
            standby.boot = inst.boot;
            self.insert_standby(inst.parallel.label(), standby);
        }
        Ok(inst)
    }

    pub fn active_instance(&self) -> Option<&Instance> {
        self.active.and_then(|id| self.instances.get(&id))
    }

    pub fn standby_count(&self) -> usize {
        self.standby.len()
    }

    pub fn dram_warm_count(&self) -> usize {
        self.dram_warm.as_ref().map(|w| w.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn par(n: usize) -> ParallelConfig {
        ParallelConfig::standard(n / 2, 2, (0..n).collect()).unwrap()
    }

    fn imm() -> InstanceManager {
        InstanceManager::new(ImmOptions::default(), Timings::cloudmatrix())
    }

    #[test]
    fn standby_hit_is_free() {
        let mut m = imm();
        m.prepare_standby(par(6), 1);
        assert!(m.has_standby(&par(6)));
        let (inst, t) = m.acquire(&par(6), 2);
        assert_eq!(t, 0.0);
        assert_eq!(inst.parallel, par(6));
        assert!(!m.has_standby(&par(6)), "taken from cache");
    }

    #[test]
    fn standby_miss_pays_preinit_and_comm() {
        let mut m = imm();
        let (_, t) = m.acquire(&par(6), 1);
        assert!(t > 30.0, "miss should cost tens of seconds: {t}");
    }

    #[test]
    fn preinit_disabled_always_misses() {
        let mut m = InstanceManager::new(
            ImmOptions {
                pre_init: false,
                lru_cap: 4,
                dram_cap: 4,
            },
            Timings::cloudmatrix(),
        );
        m.prepare_standby(par(4), 1);
        let (_, t) = m.acquire(&par(4), 2);
        assert!(t > 30.0);
    }

    #[test]
    fn hot_eviction_demotes_to_dram_warm_instead_of_dropping() {
        let mut m = InstanceManager::new(
            ImmOptions {
                pre_init: true,
                lru_cap: 2,
                dram_cap: 2,
            },
            Timings::cloudmatrix(),
        );
        m.prepare_standby(par(2), 1);
        m.prepare_standby(par(4), 2);
        m.prepare_standby(par(6), 3); // evicts par(2) hot -> DRAM-warm
        assert!(!m.has_standby(&par(2)));
        assert!(m.has_dram_warm(&par(2)));
        assert_eq!(m.standby_count(), 2);
        assert_eq!(m.dram_warm_count(), 1);

        // A DRAM-warm acquire pays the host restore: cheap but not free,
        // and far under a cold pre-init miss.
        let (inst, t) = m.acquire(&par(2), 9);
        assert_eq!(inst.parallel, par(2));
        let restore = Timings::cloudmatrix().host_restore;
        assert_eq!(t, restore);
        assert!(t > 0.0 && t < 5.0);
        let (_, t_miss) = m.acquire(&par(8), 10);
        assert!(t_miss > t * 10.0, "miss {t_miss} vs warm {t}");
        assert_eq!(m.dram_warm_count(), 0);
    }

    #[test]
    fn dram_warm_disabled_drops_hot_evictions() {
        let mut m = InstanceManager::new(
            ImmOptions {
                pre_init: true,
                lru_cap: 1,
                dram_cap: 0,
            },
            Timings::cloudmatrix(),
        );
        m.prepare_standby(par(2), 1);
        m.prepare_standby(par(4), 2); // evicts par(2): gone
        assert!(!m.has_standby(&par(2)));
        assert!(!m.has_dram_warm(&par(2)));
        let (_, t) = m.acquire(&par(2), 3);
        assert!(t > 30.0, "dropped evictee cold-misses: {t}");
    }

    #[test]
    fn pinned_standby_survives_anticipation_churn() {
        let mut m = InstanceManager::new(
            ImmOptions {
                pre_init: true,
                lru_cap: 2,
                dram_cap: 0,
            },
            Timings::cloudmatrix(),
        );
        m.prepare_standby(par(6), 1);
        assert!(m.pin_standby(&par(6)));
        // Churn through more shapes than the cache holds.
        m.prepare_standby(par(2), 2);
        m.prepare_standby(par(4), 3);
        m.prepare_standby(par(8), 4);
        assert!(m.has_standby(&par(6)), "pinned shape must survive");
        let (_, t) = m.acquire(&par(6), 9);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn activation_flow_and_switchover() {
        let mut m = imm();
        let (inst, _) = m.acquire(&par(4), 1);
        let id = m.register_ready(inst, 0.0).unwrap();
        m.activate(id).unwrap();
        assert_eq!(m.active, Some(id));

        // Scale-up: prepare the 6-device instance, drain old, activate new.
        let (inst6, _) = m.acquire(&par(6), 2);
        let id6 = m.register_ready(inst6, 10.0).unwrap();
        let drained = m.drain_active().unwrap().unwrap();
        assert_eq!(drained, id);
        m.activate(id6).unwrap();
        let retired = m.retire(id, true).unwrap();
        assert_eq!(retired.state, InstanceState::Retired);
        // Old config cached for future scale-down.
        assert!(m.has_standby(&par(4)));
        assert_eq!(m.active, Some(id6));
    }
}
