//! Instance manager: tracks instance lifecycles, the standby LRU cache, and
//! the active-instance pointer; produces ready-to-attach instances for the
//! scaling choreography (§4.5).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::config::ParallelConfig;
use crate::device::Timings;

use super::instance::{Instance, InstanceId, InstanceState};
use super::lru::LruCache;

/// IMM policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct ImmOptions {
    /// Keep pre-initialised standby instances (the `-PreInit` ablation
    /// disables this: every acquisition pays full CPU pre-init).
    pub pre_init: bool,
    /// Standby cache capacity.
    pub lru_cap: usize,
}

impl Default for ImmOptions {
    fn default() -> Self {
        ImmOptions {
            pre_init: true,
            // One slot per anticipated configuration (ElasticMoE prepares
            // standbys for deltas -1/+1/+2/+4 and the current shape).
            lru_cap: 5,
        }
    }
}

/// The Inference Management Module.
pub struct InstanceManager {
    pub opts: ImmOptions,
    timings: Timings,
    next_id: InstanceId,
    standby: LruCache<String, Instance>,
    pub instances: BTreeMap<InstanceId, Instance>,
    pub active: Option<InstanceId>,
}

impl InstanceManager {
    pub fn new(opts: ImmOptions, timings: Timings) -> Self {
        InstanceManager {
            opts,
            timings,
            next_id: 1,
            standby: LruCache::new(opts.lru_cap.max(1)),
            instances: BTreeMap::new(),
            active: None,
        }
    }

    fn next_id(&mut self) -> InstanceId {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Pre-initialise a standby instance for an anticipated configuration
    /// (done in the background; no scale-time cost).
    pub fn prepare_standby(
        &mut self,
        parallel: ParallelConfig,
        proc: u32,
    ) -> InstanceId {
        let id = self.next_id();
        let inst = Instance::standby(id, proc, parallel.clone());
        self.standby.insert(parallel.label(), inst);
        id
    }

    /// Whether a standby instance exists for the configuration.
    pub fn has_standby(&self, parallel: &ParallelConfig) -> bool {
        self.standby.contains(&parallel.label())
    }

    /// Acquire an instance for `parallel`: an LRU hit costs nothing (the
    /// instance is pre-initialised, comm groups ready); a miss pays CPU
    /// pre-init + communication-group setup. Returns (instance, prep_time).
    pub fn acquire(
        &mut self,
        parallel: &ParallelConfig,
        proc: u32,
    ) -> (Instance, f64) {
        if self.opts.pre_init {
            if let Some(mut inst) = self.standby.take(&parallel.label()) {
                inst.proc = proc;
                return (inst, 0.0);
            }
        }
        let id = self.next_id();
        let inst = Instance::standby(id, proc, parallel.clone());
        let t = self.timings.preinit_cpu
            + self.timings.comm_init(parallel.n_devices());
        (inst, t)
    }

    /// Register a prepared instance and mark it Ready.
    pub fn register_ready(&mut self, mut inst: Instance, now: f64) -> Result<InstanceId> {
        inst.transition(InstanceState::Preparing)?;
        inst.transition(InstanceState::Ready)?;
        inst.ready_at = Some(now);
        let id = inst.id;
        self.instances.insert(id, inst);
        Ok(id)
    }

    /// Route traffic to an instance (switchover endpoint).
    pub fn activate(&mut self, id: InstanceId) -> Result<()> {
        let inst = self
            .instances
            .get_mut(&id)
            .context("no such instance")?;
        inst.transition(InstanceState::Active)?;
        self.active = Some(id);
        Ok(())
    }

    /// Stop routing new requests to the active instance (begin drain).
    pub fn drain_active(&mut self) -> Result<Option<InstanceId>> {
        let Some(id) = self.active.take() else {
            return Ok(None);
        };
        self.instances
            .get_mut(&id)
            .context("active instance missing")?
            .transition(InstanceState::Draining)?;
        Ok(Some(id))
    }

    /// Retire an instance; optionally return it to the standby cache for
    /// future reuse (scale-down keeps the config warm).
    pub fn retire(
        &mut self,
        id: InstanceId,
        back_to_standby: bool,
    ) -> Result<Instance> {
        let mut inst = self
            .instances
            .remove(&id)
            .context("no such instance")?;
        inst.transition(InstanceState::Retired)?;
        if self.active == Some(id) {
            self.active = None;
        }
        if back_to_standby && self.opts.pre_init {
            let mut standby = Instance::standby(
                inst.id,
                inst.proc,
                inst.parallel.clone(),
            );
            standby.boot = inst.boot;
            self.standby.insert(inst.parallel.label(), standby);
        }
        Ok(inst)
    }

    pub fn active_instance(&self) -> Option<&Instance> {
        self.active.and_then(|id| self.instances.get(&id))
    }

    pub fn standby_count(&self) -> usize {
        self.standby.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn par(n: usize) -> ParallelConfig {
        ParallelConfig::standard(n / 2, 2, (0..n).collect()).unwrap()
    }

    fn imm() -> InstanceManager {
        InstanceManager::new(ImmOptions::default(), Timings::cloudmatrix())
    }

    #[test]
    fn standby_hit_is_free() {
        let mut m = imm();
        m.prepare_standby(par(6), 1);
        assert!(m.has_standby(&par(6)));
        let (inst, t) = m.acquire(&par(6), 2);
        assert_eq!(t, 0.0);
        assert_eq!(inst.parallel, par(6));
        assert!(!m.has_standby(&par(6)), "taken from cache");
    }

    #[test]
    fn standby_miss_pays_preinit_and_comm() {
        let mut m = imm();
        let (_, t) = m.acquire(&par(6), 1);
        assert!(t > 30.0, "miss should cost tens of seconds: {t}");
    }

    #[test]
    fn preinit_disabled_always_misses() {
        let mut m = InstanceManager::new(
            ImmOptions {
                pre_init: false,
                lru_cap: 4,
            },
            Timings::cloudmatrix(),
        );
        m.prepare_standby(par(4), 1);
        let (_, t) = m.acquire(&par(4), 2);
        assert!(t > 30.0);
    }

    #[test]
    fn activation_flow_and_switchover() {
        let mut m = imm();
        let (inst, _) = m.acquire(&par(4), 1);
        let id = m.register_ready(inst, 0.0).unwrap();
        m.activate(id).unwrap();
        assert_eq!(m.active, Some(id));

        // Scale-up: prepare the 6-device instance, drain old, activate new.
        let (inst6, _) = m.acquire(&par(6), 2);
        let id6 = m.register_ready(inst6, 10.0).unwrap();
        let drained = m.drain_active().unwrap().unwrap();
        assert_eq!(drained, id);
        m.activate(id6).unwrap();
        let retired = m.retire(id, true).unwrap();
        assert_eq!(retired.state, InstanceState::Retired);
        // Old config cached for future scale-down.
        assert!(m.has_standby(&par(4)));
        assert_eq!(m.active, Some(id6));
    }
}
