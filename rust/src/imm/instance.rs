//! Inference-instance lifecycle: the paper's transient, selectively
//! activated serving processes (Fig 5 right).

use crate::config::ParallelConfig;
use crate::device::ipc::ProcId;
use crate::hmm::control::InstanceBinding;

/// Instance identifier within the IMM.
pub type InstanceId = u64;

/// Lifecycle states (§4.5 / §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// Pre-initialised on CPU, not bound to device memory ("ready-to-attach").
    Standby,
    /// Binding to HMM memory / warming up.
    Preparing,
    /// Fully initialised, can serve as soon as traffic is routed.
    Ready,
    /// Currently serving requests.
    Active,
    /// No longer receiving new requests; finishing in-flight work.
    Draining,
    /// Terminated; resources released.
    Retired,
}

/// Per-stage boot timing, the unit of Fig 4a and Fig 11.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BootBreakdown {
    /// Container/process start (cold boots only).
    pub container: f64,
    /// CPU-side engine pre-initialisation (skipped when standby).
    pub preinit: f64,
    /// Communication-group (HCCL) setup.
    pub comm_init: f64,
    /// Weight load: disk for cold boots, P2P for elastic provisioning.
    pub weight_load: f64,
    /// KV-cache allocation.
    pub kv_alloc: f64,
    /// Zero-copy attach of weight/KV handles.
    pub attach: f64,
    /// Model warmup (first forward, graph capture).
    pub warmup: f64,
}

impl BootBreakdown {
    pub fn total(&self) -> f64 {
        self.container
            + self.preinit
            + self.comm_init
            + self.weight_load
            + self.kv_alloc
            + self.attach
            + self.warmup
    }

    /// Named stages for reports.
    pub fn stages(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("container", self.container),
            ("preinit", self.preinit),
            ("comm_init", self.comm_init),
            ("weight_load", self.weight_load),
            ("kv_alloc", self.kv_alloc),
            ("attach", self.attach),
            ("warmup", self.warmup),
        ]
    }
}

/// One inference instance.
#[derive(Debug, Clone)]
pub struct Instance {
    pub id: InstanceId,
    pub proc: ProcId,
    pub parallel: ParallelConfig,
    pub state: InstanceState,
    /// Zero-copy references into HMM memory (None while standby).
    pub binding: Option<InstanceBinding>,
    /// Boot timing of the most recent preparation.
    pub boot: BootBreakdown,
    /// Simulated time the instance became ready (for metrics).
    pub ready_at: Option<f64>,
}

impl Instance {
    pub fn standby(id: InstanceId, proc: ProcId, parallel: ParallelConfig) -> Self {
        Instance {
            id,
            proc,
            parallel,
            state: InstanceState::Standby,
            binding: None,
            boot: BootBreakdown::default(),
            ready_at: None,
        }
    }

    pub fn label(&self) -> String {
        self.parallel.label()
    }

    pub fn is_serving(&self) -> bool {
        matches!(self.state, InstanceState::Active | InstanceState::Draining)
    }

    /// State transition with validity checking.
    pub fn transition(&mut self, to: InstanceState) -> anyhow::Result<()> {
        use InstanceState::*;
        let ok = matches!(
            (self.state, to),
            (Standby, Preparing)
                | (Preparing, Ready)
                | (Ready, Active)
                | (Active, Draining)
                | (Draining, Retired)
                | (Active, Retired)     // hard stop (cold restart baseline)
                | (Ready, Retired)
                | (Standby, Retired)
        );
        if !ok {
            anyhow::bail!("invalid transition {:?} -> {to:?}", self.state);
        }
        self.state = to;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst() -> Instance {
        let p = ParallelConfig::standard(2, 2, vec![0, 1, 2, 3]).unwrap();
        Instance::standby(1, 10, p)
    }

    #[test]
    fn lifecycle_happy_path() {
        let mut i = inst();
        assert_eq!(i.state, InstanceState::Standby);
        i.transition(InstanceState::Preparing).unwrap();
        i.transition(InstanceState::Ready).unwrap();
        i.transition(InstanceState::Active).unwrap();
        assert!(i.is_serving());
        i.transition(InstanceState::Draining).unwrap();
        assert!(i.is_serving());
        i.transition(InstanceState::Retired).unwrap();
        assert!(!i.is_serving());
    }

    #[test]
    fn invalid_transitions_rejected() {
        let mut i = inst();
        assert!(i.transition(InstanceState::Active).is_err());
        i.transition(InstanceState::Preparing).unwrap();
        assert!(i.transition(InstanceState::Draining).is_err());
    }

    #[test]
    fn boot_breakdown_totals() {
        let b = BootBreakdown {
            container: 18.0,
            preinit: 35.0,
            comm_init: 8.0,
            weight_load: 20.0,
            kv_alloc: 0.5,
            attach: 0.1,
            warmup: 4.2,
        };
        assert!((b.total() - 85.8).abs() < 1e-9);
        assert_eq!(b.stages().len(), 7);
    }
}
