//! Weight loaders: the ZeroCopyLoader (ElasticMoE path — attach to HMM
//! memory) vs the standard DiskLoader (vLLM-style baselines — every
//! instance loads its own private copy from disk).

use anyhow::Result;

use crate::config::{ModelConfig, ParallelConfig};
use crate::device::hbm::RegionKind;
use crate::device::ipc::ProcId;
use crate::device::{Cluster, DeviceId, RegionId};
use crate::hmm::control::{HmmControl, InstanceBinding};
use crate::hmm::weights::WeightLayout;

/// ZeroCopyLoader: attach the instance to HMM-managed tensors. Returns the
/// binding and the attach time (sub-second: handles only, no data).
pub fn zero_copy_attach(
    hmm: &mut HmmControl,
    proc: ProcId,
) -> Result<(InstanceBinding, f64)> {
    hmm.attach_instance(proc)
}

/// DiskLoader: the baseline cold-boot path. The instance allocates private
/// regions on every device and reads weights from disk — naively, i.e.
/// *per device*, without cross-device dedup (Appendix D.2 calls this out).
/// Also allocates a private KV cache. Returns (regions, time) where time is
/// the max over devices (parallel loading).
pub fn disk_loader_boot(
    cluster: &mut Cluster,
    model: &ModelConfig,
    parallel: &ParallelConfig,
    kv_bytes_per_device: u64,
    proc: ProcId,
) -> Result<(Vec<(DeviceId, RegionId)>, f64)> {
    let layout = WeightLayout::compute(model, parallel);
    let mut regions = Vec::new();
    let mut worst: f64 = 0.0;
    for &dev in &parallel.devices {
        let mut t = 0.0;
        let weight_bytes = layout.device_bytes(dev);
        let r = cluster.devices[dev].hbm.alloc(
            weight_bytes,
            RegionKind::AttnWeights,
            false,
            format!("diskloader:{proc}"),
        )?;
        regions.push((dev, r));
        t += cluster.disk.read(weight_bytes);
        let kv = cluster.devices[dev].hbm.alloc(
            kv_bytes_per_device,
            RegionKind::KvCache,
            false,
            format!("diskloader-kv:{proc}"),
        )?;
        regions.push((dev, kv));
        t += cluster.timings.kv_alloc(kv_bytes_per_device);
        worst = worst.max(t);
    }
    Ok((regions, worst))
}

/// Release a DiskLoader instance's private regions.
pub fn disk_loader_teardown(
    cluster: &mut Cluster,
    regions: &[(DeviceId, RegionId)],
) -> Result<()> {
    for &(dev, r) in regions {
        cluster.devices[dev].hbm.release(r)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;

    #[test]
    fn disk_loader_is_slow_and_private() {
        let mut c = Cluster::cloudmatrix(4);
        let m = dsv2_lite();
        let p = ParallelConfig::standard(2, 2, (0..4).collect()).unwrap();
        let (regions, t) =
            disk_loader_boot(&mut c, &m, &p, 4 << 30, 7).unwrap();
        // ~12 GB of weights per device at 1.5 GB/s: several seconds.
        assert!(t > 3.0, "disk boot too fast: {t}");
        assert!(c.devices[0].hbm.used() > 10 << 30);
        // Private: regions are not IPC-safe.
        let (dev, r) = regions[0];
        assert!(!c.devices[dev].hbm.region(r).unwrap().ipc_safe);
        disk_loader_teardown(&mut c, &regions).unwrap();
        assert_eq!(c.devices[0].hbm.used(), 0);
    }

    #[test]
    fn disk_loader_can_oom_on_small_devices() {
        // A 4 GB device cannot hold a DSv2-Lite shard: the colocated
        // baseline's failure mode must be a real error.
        let mut c = Cluster::new(2, 4, crate::device::Timings::cloudmatrix());
        let m = dsv2_lite();
        let p = ParallelConfig::standard(1, 2, vec![0, 1]).unwrap();
        assert!(disk_loader_boot(&mut c, &m, &p, 1 << 30, 1).is_err());
    }
}
