//! LRU cache of standby instances (§4.5: "idle instances ... tracked in an
//! LRU cache and remain ready to attach").

use std::collections::VecDeque;

/// A small ordered LRU: most-recently-used at the back.
#[derive(Debug, Clone)]
pub struct LruCache<K: PartialEq + Clone, V> {
    cap: usize,
    entries: VecDeque<(K, V)>,
}

impl<K: PartialEq + Clone, V> LruCache<K, V> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        LruCache {
            cap,
            entries: VecDeque::new(),
        }
    }

    /// Insert (or replace) a value; evicts the least-recently-used entry if
    /// over capacity, returning it.
    pub fn insert(&mut self, key: K, value: V) -> Option<(K, V)> {
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(pos);
        }
        self.entries.push_back((key, value));
        if self.entries.len() > self.cap {
            self.entries.pop_front()
        } else {
            None
        }
    }

    /// Remove and return the value for `key`, if cached (a standby hit).
    pub fn take(&mut self, key: &K) -> Option<V> {
        let pos = self.entries.iter().position(|(k, _)| k == key)?;
        self.entries.remove(pos).map(|(_, v)| v)
    }

    /// Peek without affecting recency.
    pub fn contains(&self, key: &K) -> bool {
        self.entries.iter().any(|(k, _)| k == key)
    }

    /// Touch an entry, marking it most-recently-used.
    pub fn touch(&mut self, key: &K) -> bool {
        if let Some(pos) = self.entries.iter().position(|(k, _)| k == key) {
            if let Some(e) = self.entries.remove(pos) {
                self.entries.push_back(e);
                return true;
            }
        }
        false
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    pub fn keys(&self) -> impl Iterator<Item = &K> {
        self.entries.iter().map(|(k, _)| k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_eviction_order() {
        let mut c = LruCache::new(2);
        assert!(c.insert("a", 1).is_none());
        assert!(c.insert("b", 2).is_none());
        let evicted = c.insert("c", 3).unwrap();
        assert_eq!(evicted, ("a", 1)); // least recently used
        assert!(c.contains(&"b") && c.contains(&"c"));
    }

    #[test]
    fn touch_protects_from_eviction() {
        let mut c = LruCache::new(2);
        c.insert("a", 1);
        c.insert("b", 2);
        assert!(c.touch(&"a"));
        let evicted = c.insert("c", 3).unwrap();
        assert_eq!(evicted.0, "b");
    }

    #[test]
    fn take_removes() {
        let mut c = LruCache::new(4);
        c.insert("a", 1);
        assert_eq!(c.take(&"a"), Some(1));
        assert_eq!(c.take(&"a"), None);
        assert!(c.is_empty());
    }

    #[test]
    fn reinsert_replaces() {
        let mut c = LruCache::new(2);
        c.insert("a", 1);
        c.insert("a", 9);
        assert_eq!(c.len(), 1);
        assert_eq!(c.take(&"a"), Some(9));
    }
}
