//! LRU cache of standby instances (§4.5: "idle instances ... tracked in an
//! LRU cache and remain ready to attach").
//!
//! Generalised for the tiered weight store: eviction no longer has to
//! mean *dropping* — [`crate::imm::InstanceManager`] chains two of these
//! (hot standby → DRAM-warm) so an entry evicted from the hot level
//! demotes a tier instead of dying, and entries mid-activation can be
//! [`LruCache::pin`]ned so churn can never evict the instance a scaling
//! event is about to attach.

use std::collections::VecDeque;

/// A small ordered LRU: most-recently-used at the back. Pinned entries
/// are skipped when choosing an eviction victim.
#[derive(Debug, Clone)]
pub struct LruCache<K: PartialEq + Clone, V> {
    cap: usize,
    /// (key, value, pinned), LRU order front→back.
    entries: VecDeque<(K, V, bool)>,
}

impl<K: PartialEq + Clone, V> LruCache<K, V> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        LruCache {
            cap,
            entries: VecDeque::new(),
        }
    }

    /// Insert (or replace) a value; evicts the least-recently-used
    /// *unpinned* entry if over capacity, returning it. Replacing a
    /// pinned key keeps its pin (re-preparing a protected shape must not
    /// silently unprotect it). When every candidate is pinned the cache
    /// temporarily exceeds its capacity rather than evict an in-use
    /// instance (the pin is a correctness guarantee, the capacity a
    /// performance target).
    pub fn insert(&mut self, key: K, value: V) -> Option<(K, V)> {
        let mut pinned = false;
        if let Some(pos) = self.entries.iter().position(|(k, _, _)| *k == key) {
            pinned = self
                .entries
                .remove(pos)
                .map(|(_, _, p)| p)
                .unwrap_or(false);
        }
        self.entries.push_back((key, value, pinned));
        if self.entries.len() > self.cap {
            // The newcomer is never its own victim: candidates are the
            // pre-existing entries, LRU first.
            let candidates = self.entries.len() - 1;
            if let Some(victim) = self
                .entries
                .iter()
                .take(candidates)
                .position(|(_, _, pinned)| !pinned)
            {
                return self.entries.remove(victim).map(|(k, v, _)| (k, v));
            }
        }
        None
    }

    /// Remove and return the value for `key`, if cached (a standby hit).
    /// Clears any pin — the entry leaves the cache entirely.
    pub fn take(&mut self, key: &K) -> Option<V> {
        let pos = self.entries.iter().position(|(k, _, _)| k == key)?;
        self.entries.remove(pos).map(|(_, v, _)| v)
    }

    /// Peek without affecting recency.
    pub fn contains(&self, key: &K) -> bool {
        self.entries.iter().any(|(k, _, _)| k == key)
    }

    /// Touch an entry, marking it most-recently-used.
    pub fn touch(&mut self, key: &K) -> bool {
        if let Some(pos) = self.entries.iter().position(|(k, _, _)| k == key) {
            if let Some(e) = self.entries.remove(pos) {
                self.entries.push_back(e);
                return true;
            }
        }
        false
    }

    /// Pin `key`: it will never be chosen as an eviction victim until
    /// unpinned or taken. Returns false when absent.
    pub fn pin(&mut self, key: &K) -> bool {
        match self.entries.iter_mut().find(|(k, _, _)| k == key) {
            Some(e) => {
                e.2 = true;
                true
            }
            None => false,
        }
    }

    /// Clear a pin. Returns false when absent.
    pub fn unpin(&mut self, key: &K) -> bool {
        match self.entries.iter_mut().find(|(k, _, _)| k == key) {
            Some(e) => {
                e.2 = false;
                true
            }
            None => false,
        }
    }

    pub fn is_pinned(&self, key: &K) -> bool {
        self.entries
            .iter()
            .any(|(k, _, pinned)| k == key && *pinned)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    pub fn cap(&self) -> usize {
        self.cap
    }
    pub fn keys(&self) -> impl Iterator<Item = &K> {
        self.entries.iter().map(|(k, _, _)| k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_eviction_order() {
        let mut c = LruCache::new(2);
        assert!(c.insert("a", 1).is_none());
        assert!(c.insert("b", 2).is_none());
        let evicted = c.insert("c", 3).unwrap();
        assert_eq!(evicted, ("a", 1)); // least recently used
        assert!(c.contains(&"b") && c.contains(&"c"));
    }

    #[test]
    fn touch_protects_from_eviction() {
        let mut c = LruCache::new(2);
        c.insert("a", 1);
        c.insert("b", 2);
        assert!(c.touch(&"a"));
        let evicted = c.insert("c", 3).unwrap();
        assert_eq!(evicted.0, "b");
    }

    #[test]
    fn take_removes() {
        let mut c = LruCache::new(4);
        c.insert("a", 1);
        assert_eq!(c.take(&"a"), Some(1));
        assert_eq!(c.take(&"a"), None);
        assert!(c.is_empty());
    }

    #[test]
    fn reinsert_replaces() {
        let mut c = LruCache::new(2);
        c.insert("a", 1);
        c.insert("a", 9);
        assert_eq!(c.len(), 1);
        assert_eq!(c.take(&"a"), Some(9));
    }

    #[test]
    fn pinned_entries_are_never_victims() {
        let mut c = LruCache::new(2);
        c.insert("a", 1);
        c.insert("b", 2);
        assert!(c.pin(&"a"));
        // "a" is the LRU but pinned: "b" goes instead.
        let evicted = c.insert("c", 3).unwrap();
        assert_eq!(evicted.0, "b");
        assert!(c.contains(&"a"));
        // Unpin restores normal victim selection.
        assert!(c.unpin(&"a"));
        let evicted = c.insert("d", 4).unwrap();
        assert_eq!(evicted.0, "a");
    }

    #[test]
    fn all_pinned_exceeds_capacity_instead_of_evicting() {
        let mut c = LruCache::new(2);
        c.insert("a", 1);
        c.insert("b", 2);
        c.pin(&"a");
        c.pin(&"b");
        assert!(c.insert("c", 3).is_none(), "no unpinned victim");
        assert_eq!(c.len(), 3, "temporarily over capacity");
        // Taking a pinned entry clears it out entirely.
        assert_eq!(c.take(&"a"), Some(1));
        assert!(!c.is_pinned(&"a"));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn reinsert_keeps_the_pin() {
        let mut c = LruCache::new(2);
        c.insert("a", 1);
        c.pin(&"a");
        c.insert("a", 2); // re-prepare the protected shape
        assert!(c.is_pinned(&"a"), "replacement must not unprotect");
        c.insert("b", 3);
        let evicted = c.insert("c", 4).unwrap();
        assert_eq!(evicted.0, "b", "pinned 'a' still protected");
    }

    #[test]
    fn pin_absent_key_is_false() {
        let mut c: LruCache<&str, i32> = LruCache::new(2);
        assert!(!c.pin(&"ghost"));
        assert!(!c.unpin(&"ghost"));
        assert!(!c.is_pinned(&"ghost"));
    }
}
