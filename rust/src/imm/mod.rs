//! Inference Management Module (IMM, §4.5): owns the inference instances,
//! keeps pre-initialised standby instances in an LRU cache, attaches the
//! active instance to HMM-managed memory through the zero-copy loader, and
//! orchestrates activation/draining/retirement around scaling events.

pub mod instance;
pub mod loader;
pub mod lru;
pub mod manager;

pub use instance::{BootBreakdown, Instance, InstanceId, InstanceState};
pub use loader::{disk_loader_boot, zero_copy_attach};
pub use lru::LruCache;
pub use manager::InstanceManager;
