//! The simulated supernode: a set of NPUs plus the shared fabric, disk and
//! IPC registry. One `Cluster` underlies a whole experiment; scaling methods
//! acquire/release device subsets from it.

use anyhow::{bail, Result};

use super::disk::Disk;
use super::hostmem::HostMem;
use super::interconnect::Interconnect;
use super::ipc::IpcRegistry;
use super::npu::Npu;
use super::timings::Timings;
use super::DeviceId;

/// Host DRAM per node, bytes (CloudMatrix-class hosts carry TB-scale
/// DRAM; 1 TiB leaves generous staging room for every paper model).
pub const HOST_DRAM_BYTES: u64 = 1 << 40;

/// Simulated CloudMatrix-style cluster.
#[derive(Debug)]
pub struct Cluster {
    pub devices: Vec<Npu>,
    pub interconnect: Interconnect,
    pub disk: Disk,
    /// Host-DRAM staging pool (the middle weight-residency tier).
    pub host: HostMem,
    pub ipc: IpcRegistry,
    pub timings: Timings,
}

impl Cluster {
    /// Build a cluster of `n` devices with `hbm_gb` each (910C: 64 GB) and
    /// 2 MB physical pages (the ACL virtual-memory granule).
    pub fn new(n: usize, hbm_gb: u64, timings: Timings) -> Self {
        let devices = (0..n)
            .map(|i| Npu::new(i, hbm_gb << 30, 2 << 20))
            .collect();
        Cluster {
            devices,
            interconnect: Interconnect::new(timings.clone()),
            disk: Disk::new(timings.clone()),
            host: HostMem::new(HOST_DRAM_BYTES),
            ipc: IpcRegistry::new(),
            timings,
        }
    }

    /// CloudMatrix384 defaults: 64 GB HBM per device.
    pub fn cloudmatrix(n: usize) -> Self {
        Cluster::new(n, 64, Timings::cloudmatrix())
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn device(&self, id: DeviceId) -> &Npu {
        &self.devices[id]
    }
    pub fn device_mut(&mut self, id: DeviceId) -> &mut Npu {
        &mut self.devices[id]
    }

    /// Grow the cluster (the `add-nodes` primitive, Appendix D.6). Returns
    /// the new device ids and the control-plane time charged (HCCL teardown
    /// + re-init over the enlarged world).
    pub fn add_devices(&mut self, count: usize) -> (Vec<DeviceId>, f64) {
        let start = self.devices.len();
        let hbm = self
            .devices
            .first()
            .map(|d| d.hbm.capacity())
            .unwrap_or(64 << 30);
        for i in 0..count {
            self.devices.push(Npu::new(start + i, hbm, 2 << 20));
        }
        let t = self.timings.comm_init(self.devices.len());
        ((start..start + count).collect(), t)
    }

    /// Aggregate used bytes over a device subset (the paper's "peak memory
    /// across all involved NPUs" denominator).
    pub fn used_over(&self, ids: &[DeviceId]) -> u64 {
        ids.iter().map(|&i| self.devices[i].hbm.used()).sum()
    }

    /// Aggregate peak bytes over a device subset.
    pub fn peak_over(&self, ids: &[DeviceId]) -> u64 {
        ids.iter().map(|&i| self.devices[i].hbm.peak()).sum()
    }

    /// Reset peak watermarks (start of a scaling-event measurement).
    pub fn reset_peaks(&mut self, ids: &[DeviceId]) {
        for &i in ids {
            self.devices[i].hbm.reset_peak();
        }
    }

    /// Validate that a device-id set exists and is disjoint-free.
    pub fn validate_ids(&self, ids: &[DeviceId]) -> Result<()> {
        let mut seen = vec![false; self.devices.len()];
        for &i in ids {
            if i >= self.devices.len() {
                bail!("device {i} out of range ({} devices)", self.devices.len());
            }
            if seen[i] {
                bail!("device {i} listed twice");
            }
            seen[i] = true;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::hbm::RegionKind;

    #[test]
    fn construction_and_aggregates() {
        let mut c = Cluster::cloudmatrix(4);
        assert_eq!(c.len(), 4);
        c.device_mut(1)
            .hbm
            .alloc(10 << 30, RegionKind::AttnWeights, true, "w")
            .unwrap();
        c.device_mut(2)
            .hbm
            .alloc(5 << 30, RegionKind::KvCache, true, "kv")
            .unwrap();
        assert_eq!(c.used_over(&[0, 1, 2, 3]), 15 << 30);
        assert_eq!(c.used_over(&[1]), 10 << 30);
        assert!(c.peak_over(&[1, 2]) >= 15 << 30);
    }

    #[test]
    fn add_devices_charges_comm_reinit() {
        let mut c = Cluster::cloudmatrix(4);
        let (ids, t) = c.add_devices(2);
        assert_eq!(ids, vec![4, 5]);
        assert_eq!(c.len(), 6);
        assert!(t >= c.timings.comm_init(6) - 1e-9);
    }

    #[test]
    fn id_validation() {
        let c = Cluster::cloudmatrix(2);
        assert!(c.validate_ids(&[0, 1]).is_ok());
        assert!(c.validate_ids(&[0, 0]).is_err());
        assert!(c.validate_ids(&[2]).is_err());
    }
}
