//! Simulated NPU cluster: the Ascend CloudMatrix384 substrate the paper runs
//! on, reproduced as a byte-accurate memory/interconnect/disk model
//! (DESIGN.md §1 documents the substitution argument).
//!
//! The simulator tracks *exactly the quantities the paper's metrics are made
//! of*: bytes resident per device (peak memory), bytes moved over which link
//! (scaling latency), and allocation lifetimes (downtime windows).

pub mod cluster;
pub mod disk;
pub mod hbm;
pub mod hostmem;
pub mod interconnect;
pub mod ipc;
pub mod npu;
pub mod timings;

pub use cluster::Cluster;
pub use disk::Disk;
pub use hbm::{Hbm, RegionId, RegionKind};
pub use hostmem::{HostMem, HostRegionId};
pub use interconnect::Interconnect;
pub use ipc::IpcRegistry;
pub use npu::Npu;
pub use timings::Timings;

/// Device identifier within a cluster.
pub type DeviceId = usize;
