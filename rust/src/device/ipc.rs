//! Ascend-IPC analogue: cross-process memory-handle registry.
//!
//! Models `rtIpcSetMemoryName` (export), `rtSetIpcMemPid` (whitelist) and
//! `rtIpcOpenMemory` (import) — the control plane of the paper's zero-copy
//! primitive (Appendix D.4). The actual refcount lives in [`super::hbm`];
//! this registry enforces the export/whitelist/open protocol.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::hbm::RegionId;
use super::DeviceId;

/// Logical process (inference instance / HMM daemon) identifier.
pub type ProcId = u32;

/// An exported memory handle.
#[derive(Debug, Clone)]
pub struct IpcHandle {
    pub name: String,
    pub device: DeviceId,
    pub region: RegionId,
    pub owner: ProcId,
    whitelist: Vec<ProcId>,
    pub open_count: u32,
}

/// Cluster-wide IPC handle registry (one per simulated node group).
#[derive(Debug, Default)]
pub struct IpcRegistry {
    handles: HashMap<String, IpcHandle>,
}

impl IpcRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// `rtIpcSetMemoryName`: publish a region under a name.
    pub fn export(
        &mut self,
        name: impl Into<String>,
        device: DeviceId,
        region: RegionId,
        owner: ProcId,
    ) -> Result<()> {
        let name = name.into();
        if self.handles.contains_key(&name) {
            bail!("IPC name '{name}' already exported");
        }
        self.handles.insert(
            name.clone(),
            IpcHandle {
                name,
                device,
                region,
                owner,
                whitelist: Vec::new(),
                open_count: 0,
            },
        );
        Ok(())
    }

    /// `rtSetIpcMemPid`: allow `pid` to open the handle.
    pub fn whitelist(&mut self, name: &str, pid: ProcId) -> Result<()> {
        let h = self
            .handles
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("no IPC handle '{name}'"))?;
        if !h.whitelist.contains(&pid) {
            h.whitelist.push(pid);
        }
        Ok(())
    }

    /// `rtIpcOpenMemory`: import the region into `pid`. Returns
    /// (device, region) for the caller to `share()` in the device's HBM.
    pub fn open(
        &mut self,
        name: &str,
        pid: ProcId,
    ) -> Result<(DeviceId, RegionId)> {
        let h = self
            .handles
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("no IPC handle '{name}'"))?;
        if h.owner != pid && !h.whitelist.contains(&pid) {
            bail!("process {pid} not whitelisted for IPC handle '{name}'");
        }
        h.open_count += 1;
        Ok((h.device, h.region))
    }

    /// Unpublish a handle (owner teardown).
    pub fn unexport(&mut self, name: &str) -> Result<IpcHandle> {
        self.handles
            .remove(name)
            .ok_or_else(|| anyhow::anyhow!("no IPC handle '{name}'"))
    }

    pub fn len(&self) -> usize {
        self.handles.len()
    }
    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }
    pub fn get(&self, name: &str) -> Option<&IpcHandle> {
        self.handles.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn export_whitelist_open_protocol() {
        let mut reg = IpcRegistry::new();
        reg.export("w:dev0:layer0.wq", 0, 7, 100).unwrap();
        // Not whitelisted yet.
        assert!(reg.open("w:dev0:layer0.wq", 200).is_err());
        reg.whitelist("w:dev0:layer0.wq", 200).unwrap();
        let (dev, region) = reg.open("w:dev0:layer0.wq", 200).unwrap();
        assert_eq!((dev, region), (0, 7));
        // Owner can always open.
        reg.open("w:dev0:layer0.wq", 100).unwrap();
        assert_eq!(reg.get("w:dev0:layer0.wq").unwrap().open_count, 2);
    }

    #[test]
    fn duplicate_export_rejected() {
        let mut reg = IpcRegistry::new();
        reg.export("x", 0, 1, 1).unwrap();
        assert!(reg.export("x", 0, 2, 1).is_err());
        reg.unexport("x").unwrap();
        reg.export("x", 0, 2, 1).unwrap();
    }

    #[test]
    fn open_unknown_fails() {
        let mut reg = IpcRegistry::new();
        assert!(reg.open("nope", 1).is_err());
        assert!(reg.unexport("nope").is_err());
    }
}
