//! Calibrated timing/bandwidth constants for the simulated substrate.
//!
//! Every constant is documented with its source. Absolute values need only
//! be *plausible* — the reproduction target is the paper's relative shape
//! (who wins, by what factor) — but we stay close to published
//! CloudMatrix384 / Ascend 910C numbers so magnitudes line up too.

/// Timing model for one cluster.
#[derive(Debug, Clone)]
pub struct Timings {
    /// Disk -> host -> HBM effective weight-load bandwidth, bytes/s.
    /// NVMe ~3 GB/s raw, but the paper's Fig 4a shows weight loading taking
    /// minutes (e.g. ~31 GB of DSv2-Lite in ~40 s/device when parallel);
    /// 1.5 GB/s effective per device matches vLLM-style loaders staging
    /// through host memory.
    pub disk_bw: f64,
    /// Unified-Bus peer-to-peer bandwidth per link, bytes/s. CloudMatrix384
    /// UB offers ~392 GB/s/die unidirectional; ~150 GB/s effective for
    /// tensor-sized sends matches the paper's "order of magnitude faster
    /// than disk I/O" (Appendix D.3).
    pub p2p_bw: f64,
    /// Per-transfer P2P setup latency (stream setup + aclrtMemcpyAsync
    /// launch), seconds.
    pub p2p_setup: f64,
    /// Host-DRAM -> HBM copy bandwidth per device, bytes/s (PCIe 4.0 x16
    /// class, pinned host buffers; ~25 GB/s effective). An order of
    /// magnitude above disk, an order below the UB fabric — the middle
    /// rung of the weight-residency ladder.
    pub h2d_bw: f64,
    /// HBM -> host-DRAM copy bandwidth per device, bytes/s (slightly
    /// below h2d on real parts; drives cold-expert demotion and park).
    pub d2h_bw: f64,
    /// CPU-state restore of a DRAM-warm standby instance (swap the
    /// pre-initialised engine state back in; comm groups were kept), s.
    /// Replaces the full `preinit_cpu` on the unpark fast path.
    pub host_restore: f64,
    /// HBM read bandwidth per device, bytes/s (910C: ~1.6 TB/s class HBM;
    /// we use 1.2 TB/s effective). Drives decode-step roofline.
    pub hbm_bw: f64,
    /// Dense compute throughput per device, FLOP/s (910C ~376 TFLOPs fp16;
    /// 120 TFLOPs effective for mixed serving kernels). Drives prefill.
    pub flops: f64,
    /// Zero-copy handle export+open cost, seconds per tensor handle
    /// (rtIpcSetMemoryName + rtIpcOpenMemory are sub-ms control-plane ops).
    pub zero_copy_per_handle: f64,
    /// Extra per-tensor cost when the allocator is NOT IPC-safe and tensors
    /// must be re-registered/staged for sharing (Table 1: -IPCAlloc adds
    /// ~0.7 s over ~100s of tensors).
    pub non_ipc_share_penalty: f64,
    /// Virtual-page remap cost per expert (aclrtMapMem of an existing
    /// physical page run — O(1) page-table update).
    pub vpage_remap_per_expert: f64,
    /// Buffer reallocation + memcpy bandwidth when vpage remap is NOT used
    /// and expert tensors must be rebuilt contiguously, bytes/s.
    pub realloc_bw: f64,
    /// Container/process cold start, seconds (paper Fig 4a "init" segment).
    pub container_start: f64,
    /// Communication-group (HCCL) initialisation: base + per-device,
    /// seconds. Grows with world size (Fig 4a).
    pub comm_init_base: f64,
    pub comm_init_per_device: f64,
    /// CPU-side instance pre-initialisation (worker spawn, graph build)
    /// when NOT already standby in the IMM cache, seconds.
    pub preinit_cpu: f64,
    /// Model warmup (first forward + capture), seconds. Fig 11 shows
    /// ~4.2 s dominating ElasticMoE's scale-up.
    pub warmup: f64,
    /// KV-cache allocation rate, bytes/s (mostly aclrtMalloc + memset).
    pub kv_alloc_bw: f64,
    /// HBM alloc/free control-plane cost per region, seconds.
    pub alloc_per_region: f64,
    /// EP all-to-all dispatch/combine latency per decode step per hop,
    /// seconds (UB all-to-all is near-uniform; ~30 us per stage).
    pub dispatch_latency: f64,
    /// Coordinator switchover (traffic re-route + drain bookkeeping), s.
    pub switchover: f64,
}

impl Timings {
    /// CloudMatrix384 / Ascend 910C-class constants (see field docs).
    pub fn cloudmatrix() -> Self {
        Timings {
            disk_bw: 1.5e9,
            p2p_bw: 150e9,
            p2p_setup: 2e-3,
            h2d_bw: 25e9,
            d2h_bw: 22e9,
            host_restore: 1.5,
            hbm_bw: 1.2e12,
            flops: 120e12,
            zero_copy_per_handle: 50e-6,
            non_ipc_share_penalty: 5e-3,
            vpage_remap_per_expert: 20e-6,
            realloc_bw: 40e9,
            container_start: 18.0,
            comm_init_base: 6.0,
            comm_init_per_device: 0.9,
            preinit_cpu: 35.0,
            warmup: 4.2,
            kv_alloc_bw: 80e9,
            alloc_per_region: 0.05e-3,
            dispatch_latency: 30e-6,
            switchover: 0.05,
        }
    }

    /// Time to load `bytes` from disk into one device.
    pub fn disk_load(&self, bytes: u64) -> f64 {
        bytes as f64 / self.disk_bw
    }

    /// Time for one P2P transfer of `bytes` between two devices.
    pub fn p2p(&self, bytes: u64) -> f64 {
        self.p2p_setup + bytes as f64 / self.p2p_bw
    }

    /// Time to copy `bytes` from host DRAM into one device's HBM.
    pub fn h2d(&self, bytes: u64) -> f64 {
        bytes as f64 / self.h2d_bw
    }

    /// Time to copy `bytes` from one device's HBM out to host DRAM.
    pub fn d2h(&self, bytes: u64) -> f64 {
        bytes as f64 / self.d2h_bw
    }

    /// HCCL communication-group initialisation for `n` devices.
    pub fn comm_init(&self, n: usize) -> f64 {
        self.comm_init_base + self.comm_init_per_device * n as f64
    }

    /// KV cache allocation time for `bytes`.
    pub fn kv_alloc(&self, bytes: u64) -> f64 {
        bytes as f64 / self.kv_alloc_bw
    }

    /// Model warmup (first forward + graph capture) grows with depth:
    /// per-layer capture cost on top of a fixed base. Calibrated so
    /// Qwen3-30B (48 layers) lands at the paper's ~4.2 s (Fig 11) and
    /// DSv2-Lite (27 layers) at the ~2.4 s implied by Table 1.
    pub fn warmup_for(&self, n_layers: u64) -> f64 {
        0.3 + 0.08 * n_layers as f64
    }

    /// Contiguous reallocation + copy of `bytes` (the non-vpage path).
    pub fn realloc_copy(&self, bytes: u64) -> f64 {
        bytes as f64 / self.realloc_bw
    }
}

impl Default for Timings {
    fn default() -> Self {
        Timings::cloudmatrix()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_is_order_of_magnitude_faster_than_disk() {
        // Appendix D.3: "P2P transfers are typically an order of magnitude
        // faster than disk I/O" — our constants must preserve that shape.
        let t = Timings::cloudmatrix();
        let gb = 1u64 << 30;
        assert!(t.disk_load(gb) / t.p2p(gb) > 10.0);
    }

    #[test]
    fn tier_ladder_orders_bandwidths() {
        // The residency ladder only pays off if each rung is meaningfully
        // cheaper to reach than the one below: P2P > h2d > disk.
        let t = Timings::cloudmatrix();
        let gb = 1u64 << 30;
        assert!(t.disk_load(gb) / t.h2d(gb) > 10.0, "h2d must be 10x disk");
        assert!(t.h2d(gb) / t.p2p(gb) > 2.0, "fabric must beat PCIe");
        assert!(t.d2h(gb) > t.h2d(gb) * 0.9, "d2h in the same class as h2d");
        // DRAM-warm restore skips the tens-of-seconds CPU pre-init.
        assert!(t.host_restore < t.preinit_cpu / 10.0);
    }

    #[test]
    fn comm_init_grows_with_world_size() {
        let t = Timings::cloudmatrix();
        assert!(t.comm_init(32) > t.comm_init(4));
    }

    #[test]
    fn vpage_remap_is_cheaper_than_realloc() {
        let t = Timings::cloudmatrix();
        // One DSv2-Lite-class expert is ~17 MB: an O(1) page-table remap
        // must beat the O(bytes) realloc+copy by at least an order of
        // magnitude — and stay O(1) as the tensor grows.
        let expert = 17 * (1u64 << 20);
        assert!(t.realloc_copy(expert) / t.vpage_remap_per_expert > 10.0);
        assert!(
            t.realloc_copy(expert * 8) / t.vpage_remap_per_expert > 80.0,
            "remap cost must not scale with bytes"
        );
    }
}
