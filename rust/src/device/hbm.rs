//! Per-device HBM accounting: a page-granular allocator with region
//! refcounts (zero-copy shares), kind tagging, and peak-watermark tracking.
//!
//! This is the data structure behind every peak-memory number in the paper's
//! tables: regions are allocated/shared/freed by the HMM primitives and the
//! scaling baselines, and `peak()` reports the high-water mark.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Identifier of an allocated HBM region (unique per device).
pub type RegionId = u64;

/// What a region holds — used for per-kind accounting (Fig 4b splits weight
/// memory from KV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RegionKind {
    AttnWeights,
    ExpertWeights,
    KvCache,
    Activations,
    Scratch,
}

#[derive(Debug, Clone)]
pub struct Region {
    pub id: RegionId,
    pub bytes: u64,
    pub kind: RegionKind,
    /// Allocated via the IPC-safe allocator (sharable across processes).
    pub ipc_safe: bool,
    /// Number of instance handles referencing this region (zero-copy).
    pub refcount: u32,
    /// Owning logical tag, e.g. "layer3.w1.e5" — used by tests/debugging.
    pub tag: String,
}

/// One device's HBM.
#[derive(Debug, Clone)]
pub struct Hbm {
    capacity: u64,
    page_size: u64,
    used: u64,
    peak: u64,
    next_id: RegionId,
    regions: BTreeMap<RegionId, Region>,
}

impl Hbm {
    pub fn new(capacity: u64, page_size: u64) -> Self {
        assert!(page_size > 0);
        Hbm {
            capacity,
            page_size,
            used: 0,
            peak: 0,
            next_id: 1,
            regions: BTreeMap::new(),
        }
    }

    /// Round a byte count up to whole pages.
    pub fn page_round(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.page_size) * self.page_size
    }

    /// Allocate a region; fails on OOM (the paper's colocated baseline must
    /// actually be able to OOM).
    pub fn alloc(
        &mut self,
        bytes: u64,
        kind: RegionKind,
        ipc_safe: bool,
        tag: impl Into<String>,
    ) -> Result<RegionId> {
        let rounded = self.page_round(bytes);
        if self.used + rounded > self.capacity {
            bail!(
                "HBM OOM: need {} + {} > capacity {}",
                self.used,
                rounded,
                self.capacity
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        self.used += rounded;
        self.peak = self.peak.max(self.used);
        self.regions.insert(
            id,
            Region {
                id,
                bytes: rounded,
                kind,
                ipc_safe,
                refcount: 1,
                tag: tag.into(),
            },
        );
        Ok(id)
    }

    /// Add a zero-copy reference to an existing region. Only IPC-safe
    /// regions can be shared across processes.
    pub fn share(&mut self, id: RegionId) -> Result<()> {
        let r = self
            .regions
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("no such region {id}"))?;
        if !r.ipc_safe {
            bail!("region {id} ({}) is not IPC-safe", r.tag);
        }
        r.refcount += 1;
        Ok(())
    }

    /// Drop one reference; the region is freed when the count reaches zero.
    pub fn release(&mut self, id: RegionId) -> Result<()> {
        let r = self
            .regions
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("no such region {id}"))?;
        r.refcount -= 1;
        if r.refcount == 0 {
            let bytes = r.bytes;
            self.regions.remove(&id);
            self.used -= bytes;
        }
        Ok(())
    }

    pub fn used(&self) -> u64 {
        self.used
    }
    pub fn peak(&self) -> u64 {
        self.peak
    }
    pub fn capacity(&self) -> u64 {
        self.capacity
    }
    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.used
    }
    pub fn region(&self, id: RegionId) -> Option<&Region> {
        self.regions.get(&id)
    }
    pub fn region_count(&self) -> usize {
        self.regions.len()
    }

    /// Reset the peak watermark to current usage (start of a measurement).
    pub fn reset_peak(&mut self) {
        self.peak = self.used;
    }

    /// Total bytes of a given kind currently resident.
    pub fn used_by_kind(&self, kind: RegionKind) -> u64 {
        self.regions
            .values()
            .filter(|r| r.kind == kind)
            .map(|r| r.bytes)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hbm() -> Hbm {
        Hbm::new(1 << 30, 2 << 20) // 1 GB, 2 MB pages
    }

    #[test]
    fn alloc_free_accounting() {
        let mut h = hbm();
        let a = h
            .alloc(3 << 20, RegionKind::AttnWeights, true, "a")
            .unwrap();
        assert_eq!(h.used(), 4 << 20); // rounded to 2 pages
        let b = h.alloc(1, RegionKind::KvCache, true, "b").unwrap();
        assert_eq!(h.used(), 6 << 20);
        assert_eq!(h.peak(), 6 << 20);
        h.release(a).unwrap();
        assert_eq!(h.used(), 2 << 20);
        assert_eq!(h.peak(), 6 << 20); // watermark survives frees
        h.release(b).unwrap();
        assert_eq!(h.used(), 0);
        assert_eq!(h.region_count(), 0);
    }

    #[test]
    fn oom_is_an_error() {
        let mut h = hbm();
        h.alloc(900 << 20, RegionKind::ExpertWeights, true, "big")
            .unwrap();
        assert!(h
            .alloc(200 << 20, RegionKind::KvCache, true, "kv")
            .is_err());
        // Accounting unchanged after failed alloc.
        assert_eq!(h.used(), h.page_round(900 << 20));
    }

    #[test]
    fn zero_copy_share_counts_once() {
        let mut h = hbm();
        let w = h
            .alloc(100 << 20, RegionKind::AttnWeights, true, "w")
            .unwrap();
        let before = h.used();
        h.share(w).unwrap(); // second instance attaches
        assert_eq!(h.used(), before, "zero-copy must not grow usage");
        h.release(w).unwrap(); // old instance detaches
        assert_eq!(h.used(), before, "still referenced by new instance");
        h.release(w).unwrap();
        assert_eq!(h.used(), 0);
    }

    #[test]
    fn non_ipc_regions_cannot_be_shared() {
        let mut h = hbm();
        let w = h
            .alloc(1 << 20, RegionKind::AttnWeights, false, "w")
            .unwrap();
        assert!(h.share(w).is_err());
    }

    #[test]
    fn kind_accounting() {
        let mut h = hbm();
        h.alloc(10 << 20, RegionKind::ExpertWeights, true, "e").unwrap();
        h.alloc(20 << 20, RegionKind::KvCache, true, "kv").unwrap();
        assert_eq!(h.used_by_kind(RegionKind::ExpertWeights), 10 << 20);
        assert_eq!(h.used_by_kind(RegionKind::KvCache), 20 << 20);
        assert_eq!(h.used_by_kind(RegionKind::Scratch), 0);
    }

    #[test]
    fn reset_peak() {
        let mut h = hbm();
        let a = h.alloc(500 << 20, RegionKind::Scratch, true, "s").unwrap();
        h.release(a).unwrap();
        assert_eq!(h.peak(), h.page_round(500 << 20));
        h.reset_peak();
        assert_eq!(h.peak(), 0);
    }
}
