//! One simulated NPU: HBM + identity. Compute/communication timing lives in
//! [`super::timings`]; data-plane payloads live in the HMM's weight store.

use super::hbm::Hbm;
use super::DeviceId;

/// A simulated Ascend-class accelerator.
#[derive(Debug, Clone)]
pub struct Npu {
    pub id: DeviceId,
    pub hbm: Hbm,
}

impl Npu {
    pub fn new(id: DeviceId, hbm_capacity: u64, page_size: u64) -> Self {
        Npu {
            id,
            hbm: Hbm::new(hbm_capacity, page_size),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction() {
        let n = Npu::new(3, 64 << 30, 2 << 20);
        assert_eq!(n.id, 3);
        assert_eq!(n.hbm.capacity(), 64 << 30);
        assert_eq!(n.hbm.used(), 0);
    }
}
