//! Host-DRAM staging pool: the middle tier of the weight residency
//! hierarchy (HBM → host DRAM → shared disk).
//!
//! One pool serves the whole node. Weights staged here are an h2d copy
//! away from serving (tens of GB/s over PCIe) instead of a disk cold
//! read (~1.5 GB/s effective), which is what makes DRAM-warm standby
//! instances and park/unpark scale-to-zero cheap. Accounting mirrors
//! [`super::hbm::Hbm`] (used/peak/capacity), minus pages and refcounts —
//! host allocations are single-owner malloc-class buffers.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Identifier of a host-DRAM region (unique per pool).
pub type HostRegionId = u64;

/// One staged buffer.
#[derive(Debug, Clone)]
pub struct HostRegion {
    pub id: HostRegionId,
    pub bytes: u64,
    /// Logical tag, e.g. "layer3.expert5" — the residency map's key.
    pub tag: String,
}

/// The node's host-DRAM staging pool.
#[derive(Debug, Clone)]
pub struct HostMem {
    capacity: u64,
    used: u64,
    peak: u64,
    next_id: HostRegionId,
    regions: BTreeMap<HostRegionId, HostRegion>,
}

impl HostMem {
    pub fn new(capacity: u64) -> Self {
        HostMem {
            capacity,
            used: 0,
            peak: 0,
            next_id: 1,
            regions: BTreeMap::new(),
        }
    }

    /// Allocate a staging buffer; fails when the pool is exhausted (host
    /// DRAM is big, not infinite — cold-expert offload must budget it).
    pub fn alloc(&mut self, bytes: u64, tag: impl Into<String>) -> Result<HostRegionId> {
        if self.used + bytes > self.capacity {
            bail!(
                "host DRAM exhausted: need {} + {bytes} > capacity {}",
                self.used,
                self.capacity
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        self.regions.insert(
            id,
            HostRegion {
                id,
                bytes,
                tag: tag.into(),
            },
        );
        Ok(id)
    }

    /// Free a staging buffer, returning its byte count.
    pub fn release(&mut self, id: HostRegionId) -> Result<u64> {
        let r = self
            .regions
            .remove(&id)
            .ok_or_else(|| anyhow::anyhow!("no such host region {id}"))?;
        self.used -= r.bytes;
        Ok(r.bytes)
    }

    pub fn used(&self) -> u64 {
        self.used
    }
    pub fn peak(&self) -> u64 {
        self.peak
    }
    pub fn capacity(&self) -> u64 {
        self.capacity
    }
    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.used
    }
    pub fn region(&self, id: HostRegionId) -> Option<&HostRegion> {
        self.regions.get(&id)
    }
    pub fn region_count(&self) -> usize {
        self.regions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_accounting() {
        let mut h = HostMem::new(1 << 30);
        let a = h.alloc(100 << 20, "w").unwrap();
        let b = h.alloc(50 << 20, "e").unwrap();
        assert_eq!(h.used(), 150 << 20);
        assert_eq!(h.peak(), 150 << 20);
        assert_eq!(h.release(a).unwrap(), 100 << 20);
        assert_eq!(h.used(), 50 << 20);
        assert_eq!(h.peak(), 150 << 20, "watermark survives frees");
        h.release(b).unwrap();
        assert_eq!(h.used(), 0);
        assert_eq!(h.region_count(), 0);
        assert!(h.release(a).is_err(), "double free is an error");
    }

    #[test]
    fn exhaustion_is_an_error() {
        let mut h = HostMem::new(1 << 20);
        h.alloc(1 << 20, "full").unwrap();
        assert!(h.alloc(1, "over").is_err());
        assert_eq!(h.used(), 1 << 20, "failed alloc changes nothing");
    }
}
