//! Unified-Bus fabric model: all-to-all, near-uniform point-to-point
//! bandwidth (CloudMatrix384's defining property), with per-device
//! serialization of concurrent incoming transfers.

use super::timings::Timings;
use super::DeviceId;

/// Bandwidth/latency model of the UB fabric.
#[derive(Debug, Clone)]
pub struct Interconnect {
    timings: Timings,
}

impl Interconnect {
    pub fn new(timings: Timings) -> Self {
        Interconnect { timings }
    }

    /// Time for a single point-to-point transfer.
    pub fn p2p_time(&self, bytes: u64) -> f64 {
        self.timings.p2p(bytes)
    }

    /// Completion time of a set of transfers `(src, dst, bytes)` started
    /// simultaneously: transfers sharing an endpoint serialize on that
    /// endpoint's link; disjoint pairs run fully in parallel (non-blocking
    /// all-to-all fabric).
    pub fn parallel_transfers(
        &self,
        transfers: &[(DeviceId, DeviceId, u64)],
    ) -> f64 {
        if transfers.is_empty() {
            return 0.0;
        }
        let max_dev = transfers
            .iter()
            .map(|&(s, d, _)| s.max(d))
            .max()
            .unwrap();
        // Per-endpoint accumulated busy time.
        let mut busy = vec![0.0f64; max_dev + 1];
        for &(src, dst, bytes) in transfers {
            let t = self.p2p_time(bytes);
            busy[src] += t;
            busy[dst] += t;
        }
        busy.into_iter().fold(0.0, f64::max)
    }

    /// One-to-many broadcast of `bytes` to `n_dst` receivers (tree-based:
    /// log2 rounds over the non-blocking fabric).
    pub fn broadcast_time(&self, bytes: u64, n_dst: usize) -> f64 {
        if n_dst == 0 {
            return 0.0;
        }
        let rounds = (n_dst as f64 + 1.0).log2().ceil();
        self.p2p_time(bytes) * rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ic() -> Interconnect {
        Interconnect::new(Timings::cloudmatrix())
    }

    #[test]
    fn disjoint_transfers_parallelize() {
        let ic = ic();
        let one = ic.parallel_transfers(&[(0, 4, 1 << 30)]);
        let disjoint =
            ic.parallel_transfers(&[(0, 4, 1 << 30), (1, 5, 1 << 30)]);
        assert!((disjoint - one).abs() < 1e-9, "{disjoint} vs {one}");
    }

    #[test]
    fn shared_endpoint_serializes() {
        let ic = ic();
        let one = ic.parallel_transfers(&[(0, 4, 1 << 30)]);
        let fanout =
            ic.parallel_transfers(&[(0, 4, 1 << 30), (0, 5, 1 << 30)]);
        assert!(fanout > one * 1.9, "{fanout} vs {one}");
    }

    #[test]
    fn broadcast_is_logarithmic() {
        let ic = ic();
        let b2 = ic.broadcast_time(1 << 30, 1);
        let b8 = ic.broadcast_time(1 << 30, 7);
        assert!(b8 <= b2 * 3.0 + 1e-9);
        assert!(ic.broadcast_time(1 << 30, 0) == 0.0);
    }
}
