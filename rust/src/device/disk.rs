//! Shared-storage model: the NVMe/NFS weight store that cold boots read
//! from. Tracks per-tensor read dedup (the `disk_copy` primitive loads each
//! tensor at most once — Appendix D.2).
//!
//! Byte accounting contract: every accounted read path —
//! [`Disk::read`] and [`Disk::read_dedup`] — decomposes its requested
//! bytes into exactly one of the two counters, so
//! `total_bytes_read + deduped_bytes == total requested bytes`
//! ([`Disk::total_requested_bytes`]) at all times.

use std::collections::HashSet;

use super::timings::Timings;

/// The weight store and its bandwidth model.
#[derive(Debug, Clone)]
pub struct Disk {
    timings: Timings,
    reads_seen: HashSet<String>,
    /// Bytes actually read from the medium (dedup misses + plain reads).
    pub total_bytes_read: u64,
    /// Bytes requested but served from the dedup cache for free (hits).
    pub deduped_bytes: u64,
}

impl Disk {
    pub fn new(timings: Timings) -> Self {
        Disk {
            timings,
            reads_seen: HashSet::new(),
            total_bytes_read: 0,
            deduped_bytes: 0,
        }
    }

    /// Time to read `bytes` — a pure query, no accounting. Use
    /// [`Self::read`] when the read actually happens.
    pub fn read_time(&self, bytes: u64) -> f64 {
        self.timings.disk_load(bytes)
    }

    /// Accounted plain read: `bytes` hit the medium (no dedup — the naive
    /// per-device loader path). Credits `total_bytes_read` so the
    /// decomposition invariant covers every loader, not just `disk_copy`.
    pub fn read(&mut self, bytes: u64) -> f64 {
        self.total_bytes_read += bytes;
        self.read_time(bytes)
    }

    /// Deduplicated read: the first read of `tensor_tag` costs disk time
    /// and is credited to `total_bytes_read`; repeats are free (served
    /// from the already-loaded copy via P2P by the caller) and credited
    /// to `deduped_bytes`. Either way the requested bytes land in exactly
    /// one counter. Returns the time charged.
    pub fn read_dedup(&mut self, tensor_tag: &str, bytes: u64) -> f64 {
        if self.reads_seen.insert(tensor_tag.to_string()) {
            self.total_bytes_read += bytes;
            self.read_time(bytes)
        } else {
            self.deduped_bytes += bytes;
            0.0
        }
    }

    /// All bytes ever requested through the accounted read paths:
    /// `total_bytes_read` (hit the medium) + `deduped_bytes` (served
    /// free). The two fields decompose this total by construction.
    pub fn total_requested_bytes(&self) -> u64 {
        self.total_bytes_read + self.deduped_bytes
    }

    /// Forget dedup history (e.g. a fresh cold boot with no warm source).
    /// Byte counters survive: they are run-cumulative.
    pub fn reset_dedup(&mut self) {
        self.reads_seen.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_charges_once() {
        let mut d = Disk::new(Timings::cloudmatrix());
        let t1 = d.read_dedup("layer0.wq", 1 << 30);
        assert!(t1 > 0.0);
        let t2 = d.read_dedup("layer0.wq", 1 << 30);
        assert_eq!(t2, 0.0);
        assert_eq!(d.total_bytes_read, 1 << 30);
        assert_eq!(d.deduped_bytes, 1 << 30);
        d.reset_dedup();
        assert!(d.read_dedup("layer0.wq", 1 << 30) > 0.0);
    }

    #[test]
    fn counters_decompose_total_requested_bytes() {
        // Mixed plain / miss / hit sequence: at every step the two fields
        // must partition the running total of requested bytes.
        let mut d = Disk::new(Timings::cloudmatrix());
        let mut requested = 0u64;
        let ops: &[(&str, u64, bool)] = &[
            ("a", 100, true),  // dedup miss
            ("a", 100, true),  // dedup hit
            ("b", 250, true),  // dedup miss
            ("", 500, false),  // plain accounted read
            ("a", 100, true),  // dedup hit again
            ("b", 250, true),  // dedup hit
        ];
        for &(tag, bytes, dedup) in ops {
            if dedup {
                d.read_dedup(tag, bytes);
            } else {
                d.read(bytes);
            }
            requested += bytes;
            assert_eq!(
                d.total_bytes_read + d.deduped_bytes,
                requested,
                "decomposition broken after ({tag}, {bytes})"
            );
            assert_eq!(d.total_requested_bytes(), requested);
        }
        assert_eq!(d.total_bytes_read, 100 + 250 + 500);
        assert_eq!(d.deduped_bytes, 100 + 100 + 250);
    }

    #[test]
    fn plain_read_is_accounted_and_timed_like_read_time() {
        let mut d = Disk::new(Timings::cloudmatrix());
        let t_query = d.read_time(1 << 30);
        let t_read = d.read(1 << 30);
        assert_eq!(t_query, t_read, "accounting must not change the time");
        assert_eq!(d.total_bytes_read, 1 << 30);
        assert_eq!(d.deduped_bytes, 0);
    }
}
