//! Shared-storage model: the NVMe/NFS weight store that cold boots read
//! from. Tracks per-tensor read dedup (the `disk_copy` primitive loads each
//! tensor at most once — Appendix D.2).

use std::collections::HashSet;

use super::timings::Timings;

/// The weight store and its bandwidth model.
#[derive(Debug, Clone)]
pub struct Disk {
    timings: Timings,
    reads_seen: HashSet<String>,
    pub total_bytes_read: u64,
    pub deduped_bytes: u64,
}

impl Disk {
    pub fn new(timings: Timings) -> Self {
        Disk {
            timings,
            reads_seen: HashSet::new(),
            total_bytes_read: 0,
            deduped_bytes: 0,
        }
    }

    /// Time to read `bytes` (no dedup bookkeeping).
    pub fn read_time(&self, bytes: u64) -> f64 {
        self.timings.disk_load(bytes)
    }

    /// Deduplicated read: the first read of `tensor_tag` costs disk time,
    /// repeats are free (served from the already-loaded copy via P2P by the
    /// caller). Returns the time charged.
    pub fn read_dedup(&mut self, tensor_tag: &str, bytes: u64) -> f64 {
        if self.reads_seen.insert(tensor_tag.to_string()) {
            self.total_bytes_read += bytes;
            self.read_time(bytes)
        } else {
            self.deduped_bytes += bytes;
            0.0
        }
    }

    /// Forget dedup history (e.g. a fresh cold boot with no warm source).
    pub fn reset_dedup(&mut self) {
        self.reads_seen.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_charges_once() {
        let mut d = Disk::new(Timings::cloudmatrix());
        let t1 = d.read_dedup("layer0.wq", 1 << 30);
        assert!(t1 > 0.0);
        let t2 = d.read_dedup("layer0.wq", 1 << 30);
        assert_eq!(t2, 0.0);
        assert_eq!(d.total_bytes_read, 1 << 30);
        assert_eq!(d.deduped_bytes, 1 << 30);
        d.reset_dedup();
        assert!(d.read_dedup("layer0.wq", 1 << 30) > 0.0);
    }
}
