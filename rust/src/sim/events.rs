//! Discrete-event queue for the serving simulator (arrivals, step
//! completions, scaling stage completions).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled event carrying a payload `T`.
#[derive(Debug, Clone)]
pub struct Event<T> {
    pub at: f64,
    /// Monotonic sequence number: ties in `at` are processed FIFO, keeping
    /// the simulation deterministic.
    pub seq: u64,
    pub payload: T,
}

impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Event<T> {}

impl<T> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics on BinaryHeap (max-heap).
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue ordered by time then insertion order.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Event<T>>,
    next_seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
        }
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at: f64, payload: T) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { at, seq, payload });
    }

    pub fn pop(&mut self) -> Option<Event<T>> {
        self.heap.pop()
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(2.0, "b");
        q.push(1.0, "a1");
        q.push(1.0, "a2");
        q.push(0.5, "first");
        assert_eq!(q.len(), 4);
        assert_eq!(q.pop().unwrap().payload, "first");
        let e1 = q.pop().unwrap();
        let e2 = q.pop().unwrap();
        assert_eq!((e1.payload, e2.payload), ("a1", "a2")); // FIFO at ties
        assert_eq!(q.pop().unwrap().payload, "b");
        assert!(q.pop().is_none());
    }

    #[test]
    fn peek_does_not_remove() {
        let mut q = EventQueue::new();
        q.push(3.0, 1u32);
        assert_eq!(q.peek_time(), Some(3.0));
        assert_eq!(q.len(), 1);
    }
}
