//! Discrete-event queue: the spine of both simulators.
//!
//! [`crate::coordinator::ServingSim`] and [`crate::coordinator::FleetSim`]
//! schedule every future state transition — arrivals, estimator window
//! ticks, scaling stage boundaries (pause open/close, downtime end,
//! switchover readiness), manual command times — as typed events on an
//! [`EventQueue`], and advance the clock by popping the earliest one
//! instead of polling fixed windows. Determinism contract: events pop in
//! strict `(at, seq)` order, where `seq` is the insertion ordinal — ties
//! in time are FIFO, so two runs that push the same events in the same
//! order pop them in the same order (property-tested in
//! `rust/tests/properties.rs`, hashed end-to-end by
//! [`crate::sim::StateHash`]).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled event carrying a payload `T`.
#[derive(Debug, Clone)]
pub struct Event<T> {
    /// Absolute simulated time the event is due.
    pub at: f64,
    /// Monotonic sequence number: ties in `at` are processed FIFO, keeping
    /// the simulation deterministic.
    pub seq: u64,
    /// The caller's event payload.
    pub payload: T,
}

impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Event<T> {}

impl<T> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics on BinaryHeap (max-heap).
        // `total_cmp` (not `partial_cmp`) so the order is total even for
        // pathological floats: a NaN would otherwise compare Equal to
        // everything and silently scramble the heap. NaN is additionally
        // rejected at `push`, so it can never enter the queue.
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue ordered by time then insertion order.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Event<T>>,
    next_seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
        }
    }
}

impl<T> EventQueue<T> {
    /// An empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty queue pre-sized for `cap` events (the simulators seed one
    /// event per arrival up front; pre-sizing avoids rehashing the heap's
    /// backing buffer on the hot path).
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            next_seq: 0,
        }
    }

    /// Schedule `payload` at absolute time `at`.
    ///
    /// # Panics
    ///
    /// Panics if `at` is NaN: a NaN timestamp has no place in the time
    /// order and would make pop order depend on heap internals — the
    /// simulators' determinism guarantee (same seed ⇒ same
    /// [`crate::sim::StateHash`]) forbids it.
    pub fn push(&mut self, at: f64, payload: T) {
        assert!(!at.is_nan(), "event scheduled at NaN");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { at, seq, payload });
    }

    /// Remove and return the earliest event (FIFO among ties).
    pub fn pop(&mut self) -> Option<Event<T>> {
        self.heap.pop()
    }

    /// The earliest scheduled time, without removing the event.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.at)
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are scheduled.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(2.0, "b");
        q.push(1.0, "a1");
        q.push(1.0, "a2");
        q.push(0.5, "first");
        assert_eq!(q.len(), 4);
        assert_eq!(q.pop().unwrap().payload, "first");
        let e1 = q.pop().unwrap();
        let e2 = q.pop().unwrap();
        assert_eq!((e1.payload, e2.payload), ("a1", "a2")); // FIFO at ties
        assert_eq!(q.pop().unwrap().payload, "b");
        assert!(q.pop().is_none());
    }

    #[test]
    fn peek_does_not_remove() {
        let mut q = EventQueue::new();
        q.push(3.0, 1u32);
        assert_eq!(q.peek_time(), Some(3.0));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn orders_infinities_and_zeroes_totally() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, "inf");
        q.push(0.0, "zero");
        q.push(-0.0, "negzero");
        q.push(f64::NEG_INFINITY, "neginf");
        assert_eq!(q.pop().unwrap().payload, "neginf");
        // total_cmp orders -0.0 before 0.0; both before any positive.
        assert_eq!(q.pop().unwrap().payload, "negzero");
        assert_eq!(q.pop().unwrap().payload, "zero");
        assert_eq!(q.pop().unwrap().payload, "inf");
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn rejects_nan_timestamps() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut q = EventQueue::with_capacity(8);
        q.push(1.0, 1u32);
        q.push(0.5, 2u32);
        assert_eq!(q.pop().unwrap().payload, 2);
        assert_eq!(q.pop().unwrap().payload, 1);
        assert!(q.is_empty());
    }
}
