//! Incremental state hashing for determinism checks.
//!
//! [`StateHash`] is a 64-bit FNV-1a accumulator folded over every state
//! transition a simulator makes: engine step outcomes, queue occupancy,
//! KV block ownership, tier residency shifts, scaling plan/undo entries,
//! and the full chaos [`crate::chaos::Trace`]. Two runs from the same
//! seed must produce the same final digest — exposed as
//! `SimOutput::state_hash` / `FleetOutput::state_hash` — so determinism
//! is a testable property (`rust/tests/determinism.rs`), and any
//! divergence bisects to the first transition whose fold differs.
//!
//! FNV-1a was chosen over a cryptographic hash because the digest guards
//! against *accidental* nondeterminism (HashMap iteration order, float
//! environment differences, reordered events), not adversaries, and the
//! crate takes no new dependencies. Floats are folded via
//! [`f64::to_bits`], so the digest is exactly as strict as bit equality.

/// 64-bit FNV-1a offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// 64-bit FNV-1a prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incrementally-folded FNV-1a digest over a simulation's state
/// transitions.
///
/// ```
/// use elastic_moe::sim::StateHash;
/// let mut a = StateHash::new();
/// let mut b = StateHash::new();
/// for h in [&mut a, &mut b] {
///     h.fold_u64(7);
///     h.fold_f64(0.25);
///     h.fold_bytes(b"switchover");
/// }
/// assert_eq!(a.value(), b.value());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StateHash {
    state: u64,
}

impl Default for StateHash {
    fn default() -> Self {
        StateHash { state: FNV_OFFSET }
    }
}

impl StateHash {
    /// A fresh digest at the FNV offset basis.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold raw bytes.
    pub fn fold_bytes(&mut self, bytes: &[u8]) {
        let mut h = self.state;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.state = h;
    }

    /// Fold a `u64` (little-endian bytes).
    pub fn fold_u64(&mut self, v: u64) {
        self.fold_bytes(&v.to_le_bytes());
    }

    /// Fold a `usize` (widened to `u64` so the digest is
    /// pointer-width-independent).
    pub fn fold_usize(&mut self, v: usize) {
        self.fold_u64(v as u64);
    }

    /// Fold an `f64` by its IEEE-754 bit pattern. Bit-exact: `0.1 + 0.2`
    /// and `0.3` fold differently, which is the point — the digest
    /// certifies bit-identical trajectories, not approximate ones.
    pub fn fold_f64(&mut self, v: f64) {
        self.fold_u64(v.to_bits());
    }

    /// Fold a bool as a single byte.
    pub fn fold_bool(&mut self, v: bool) {
        self.fold_bytes(&[v as u8]);
    }

    /// Fold a string's UTF-8 bytes, length-prefixed so `("ab","c")` and
    /// `("a","bc")` fold differently.
    pub fn fold_str(&mut self, s: &str) {
        self.fold_usize(s.len());
        self.fold_bytes(s.as_bytes());
    }

    /// The current digest.
    pub fn value(&self) -> u64 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_fnv1a_vectors() {
        // Classic FNV-1a 64 test vectors.
        let mut h = StateHash::new();
        assert_eq!(h.value(), 0xcbf29ce484222325); // empty input
        h.fold_bytes(b"a");
        assert_eq!(h.value(), 0xaf63dc4c8601ec8c);
        let mut h2 = StateHash::new();
        h2.fold_bytes(b"foobar");
        assert_eq!(h2.value(), 0x85944171f73967e8);
    }

    #[test]
    fn same_folds_same_digest() {
        let mut a = StateHash::new();
        let mut b = StateHash::new();
        for h in [&mut a, &mut b] {
            h.fold_u64(42);
            h.fold_f64(1.5);
            h.fold_bool(true);
            h.fold_str("pause");
            h.fold_usize(9);
        }
        assert_eq!(a.value(), b.value());
    }

    #[test]
    fn order_and_content_sensitive() {
        let mut a = StateHash::new();
        a.fold_u64(1);
        a.fold_u64(2);
        let mut b = StateHash::new();
        b.fold_u64(2);
        b.fold_u64(1);
        assert_ne!(a.value(), b.value());

        let mut c = StateHash::new();
        c.fold_f64(0.1 + 0.2);
        let mut d = StateHash::new();
        d.fold_f64(0.3);
        assert_ne!(c.value(), d.value(), "digest must be bit-exact");
    }

    #[test]
    fn length_prefix_disambiguates_strings() {
        let mut a = StateHash::new();
        a.fold_str("ab");
        a.fold_str("c");
        let mut b = StateHash::new();
        b.fold_str("a");
        b.fold_str("bc");
        assert_ne!(a.value(), b.value());
    }
}
