//! Clock abstraction: simulated (discrete-event) vs wall time.

use std::cell::Cell;
use std::rc::Rc;
use std::time::Instant;

/// Time source for the serving stack. Seconds as f64 since an arbitrary
/// epoch (simulation start / process start).
pub trait Clock {
    fn now(&self) -> f64;
    /// Advance time by `dt` seconds. For [`SimClock`] this is instantaneous
    /// bookkeeping; for [`RealClock`] it sleeps.
    fn advance(&self, dt: f64);
}

/// Shared simulated clock. Cloning shares the underlying time cell, so every
/// component observes the same simulated instant.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    now: Rc<Cell<f64>>,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Jump directly to an absolute time. Clamped to be monotonic: a target
    /// in the past leaves the clock unchanged (concurrent phases may report
    /// completion times out of order).
    pub fn advance_to(&self, t: f64) {
        self.now.set(self.now.get().max(t));
    }
}

impl Clock for SimClock {
    fn now(&self) -> f64 {
        self.now.get()
    }

    fn advance(&self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative advance {dt}");
        self.now.set(self.now.get() + dt);
    }
}

/// Wall-clock time (used by the end-to-end example).
#[derive(Debug, Clone)]
pub struct RealClock {
    epoch: Instant,
}

impl Default for RealClock {
    fn default() -> Self {
        RealClock {
            epoch: Instant::now(),
        }
    }
}

impl RealClock {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Clock for RealClock {
    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn advance(&self, _dt: f64) {
        // No-op: under wall time the work being accounted for has already
        // taken its real duration (backends measure with Instant). Sleeping
        // here would double-count. Real-time waits (e.g. for the next
        // arrival) are explicit `std::thread::sleep`s in the caller.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_is_shared() {
        let c1 = SimClock::new();
        let c2 = c1.clone();
        c1.advance(1.5);
        assert_eq!(c2.now(), 1.5);
        c2.advance_to(3.0);
        assert_eq!(c1.now(), 3.0);
        // advance_to never moves backwards
        c2.advance_to(2.0);
        assert_eq!(c1.now(), 3.0);
    }

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }
}
