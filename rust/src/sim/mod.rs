//! Simulated / real time: the whole serving stack is generic over [`Clock`],
//! so the paper experiments run deterministically under [`SimClock`]
//! (discrete-event time) while the end-to-end example runs the *same code*
//! under [`RealClock`] wall time with real PJRT compute.

pub mod clock;
pub mod events;
pub mod hash;

pub use clock::{Clock, RealClock, SimClock};
pub use events::{Event, EventQueue};
pub use hash::StateHash;
