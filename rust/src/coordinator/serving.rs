//! The serving simulation loop: a Coordinator routing a request stream
//! into the engine while a scaling method executes transitions beneath it.
//! Drives Figs 9/10, Table 2 and the SLO experiments.
//!
//! # Event-driven core
//!
//! The loop runs on a [`crate::sim::EventQueue`] of typed [`SimEvent`]
//! wake markers — arrivals, estimator window ticks, manual command
//! times, and every stage boundary of the pending scaling event (pause
//! open/close, downtime end, switchover readiness). Engine step
//! completions are the implicit continuation: a step advances the shared
//! [`SimClock`] by its duration and control returns synchronously, so
//! the "step done" event is the loop's next turn at the post-step clock.
//! When the engine is idle the clock jumps straight to the next queued
//! event instead of polling fixed windows. Every state transition folds
//! into a [`StateHash`] exposed as [`SimOutput::state_hash`]; see
//! `docs/architecture/07-event-core.md` for the determinism contract.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use anyhow::Result;

use crate::chaos::{FaultInjector, Trace, TraceEvent};
use crate::config::{ParallelConfig, SloConfig};
use crate::engine::{
    BatcherConfig, CostModel, CostModelBackend, PagedKv, ServeEngine,
    StepKind,
};
use crate::kvmigrate::{HandoffDisposition, KvHandoffStats, KvSnapshot};
use crate::metrics::MetricsRecorder;
use crate::obs::{ReplicaSample, Telemetry};
use crate::scaling::{ScalingMethod, ScalingOutcome};
use crate::sim::{Clock, EventQueue, SimClock, StateHash};
use crate::workload::{Request, RequestState};

use super::estimator::{LoadEstimator, ScaleDecision};

/// Typed wake marker on the serving simulator's event queue. The marker
/// names the state transition due at its timestamp; the loop applies
/// transitions with condition-based handlers at the current clock, so a
/// marker firing late (because an engine step overshot it) is handled at
/// the post-step clock — exactly where a synchronous serving system
/// would observe it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SimEvent {
    /// A request reaches the coordinator inbox.
    Arrival,
    /// Estimator observation boundary; self-reschedules every `window`.
    WindowTick,
    /// A manually scheduled scale command becomes due.
    Command,
    /// The pending scaling event's switchover becomes ready.
    ScaleReady,
    /// The pending event's declared intake-pause window opens.
    PauseOpen,
    /// The pending event's declared intake-pause window closes.
    PauseClose,
    /// The pending event's downtime window ends (cold restart path).
    DowntimeEnd,
}

/// Schedule wake markers for every stage boundary of a freshly issued
/// scaling event.
fn schedule_transition(
    queue: &mut EventQueue<SimEvent>,
    now: f64,
    outcome: &ScalingOutcome,
) {
    queue.push(now + outcome.ready_after, SimEvent::ScaleReady);
    if let Some((a, b)) = outcome.intake_pause {
        if a > 0.0 {
            queue.push(now + a, SimEvent::PauseOpen);
        }
        queue.push(now + b, SimEvent::PauseClose);
    }
    if let Some((_, b)) = outcome.downtime {
        queue.push(now + b, SimEvent::DowntimeEnd);
    }
}

/// When scaling happens.
pub enum Trigger {
    /// Fire at fixed times toward fixed targets (paper §7.5/§7.6 issue the
    /// command at a known instant).
    Manual(Vec<(f64, ParallelConfig)>),
    /// SLO-driven: the estimator picks the moment; `up`/`down` map the
    /// current config to the next one (None = can't scale that way).
    Auto {
        estimator: LoadEstimator,
        up: Box<dyn Fn(&ParallelConfig) -> Option<ParallelConfig>>,
        down: Box<dyn Fn(&ParallelConfig) -> Option<ParallelConfig>>,
    },
}

/// Output of a serving simulation.
pub struct SimOutput {
    pub recorder: MetricsRecorder,
    pub scaling_events: Vec<ScalingOutcome>,
    pub end_time: f64,
    /// (time, n_devices) timeline of the active configuration.
    pub device_timeline: Vec<(f64, usize)>,
    /// What happened to in-flight sequences across every switchover of
    /// the run: adopted (remap/copy) vs restarted, with the token bill.
    pub handoff: KvHandoffStats,
    /// Structured event trace of the run (arrivals, scale commands, plan
    /// audits, pause edges, suspend/resume, dispositions, finishes) — the
    /// record the [`crate::chaos::invariants`] checkers run over.
    pub trace: Trace,
    /// FNV-1a digest folded incrementally over every state transition of
    /// the run: each engine step's kind, duration, KV block occupancy,
    /// batch/queue lengths and preemptions, plus the full event trace.
    /// Two runs from the same seed must produce equal digests
    /// (`rust/tests/determinism.rs`); any divergence bisects to the first
    /// mismatching transition.
    pub state_hash: u64,
    /// Telemetry registry of the run (gauges, histograms, time series,
    /// scaling-event span timelines). `Some` iff [`ServingSim::obs`] was
    /// set; never feeds back into simulation state, so `state_hash` is
    /// bit-identical either way.
    pub telemetry: Option<Telemetry>,
}

/// A scaling event in flight: the outcome timeline plus its absolute
/// issue time. Shared by [`ServingSim`] and [`super::FleetSim`].
pub(crate) struct PendingScale {
    pub(crate) outcome: ScalingOutcome,
    pub(crate) started: f64,
    /// The per-sequence suspend of the KV-handoff window has been applied
    /// (it fires once, when the intake-pause window opens).
    pub(crate) suspended_applied: bool,
    /// Run-wide scaling-event ordinal (trace correlation).
    pub(crate) event: usize,
    /// The intake pause is currently enacted on the engine (tracked so
    /// the trace records exactly one pause/resume edge pair per event).
    pub(crate) pause_open: bool,
}

impl PendingScale {
    pub(crate) fn new(
        outcome: ScalingOutcome,
        started: f64,
        event: usize,
        pause_open: bool,
    ) -> Self {
        PendingScale {
            outcome,
            started,
            suspended_applied: false,
            event,
            pause_open,
        }
    }
}

/// Build a [`ServeEngine`] for one instance of `parallel` under the given
/// cost model. Shared by the single-instance [`ServingSim`] and the
/// fleet-level [`super::FleetSim`] so both simulators serve through
/// identically provisioned engines.
pub(crate) fn build_engine(
    cost: &CostModel,
    hbm_per_device: u64,
    max_batch_cap: usize,
    parallel: &ParallelConfig,
    kv_factor: f64,
    batch_factor: f64,
) -> ServeEngine {
    let kv_budget =
        (cost.kv_budget(parallel, hbm_per_device) as f64 * kv_factor) as u64;
    let bytes_per_token =
        (cost.model.kv_bytes_per_token() / parallel.tp as u64).max(1);
    let kv = PagedKv::from_bytes(
        kv_budget * parallel.dp as u64,
        bytes_per_token,
        16,
    )
    .expect("per-instance KV budget must hold at least one block");
    let backend = CostModelBackend::new(cost.clone(), parallel.clone());
    let max_batch = ((max_batch_cap
        .min(cost.max_batch(parallel, kv_budget, 2600).max(1)))
        as f64
        * batch_factor)
        .max(1.0) as usize;
    ServeEngine::new(
        BatcherConfig {
            max_batch,
            max_prefill_tokens: 16384,
        },
        kv,
        Box::new(backend),
    )
}

/// Complete a transition: build the successor engine for
/// `outcome.new_parallel` and migrate the old engine's work into it.
/// Every drained in-flight sequence (running *and* suspended) is disposed
/// of exactly once: adopted with its decode progress when its KV crossed
/// the event (remap or p2p copy, per the outcome's
/// [`crate::kvmigrate::KvHandoff`] — or the blanket `preserves_inflight`
/// when no per-sequence plan exists), restarted from scratch otherwise;
/// queued requests transfer as-is. Returns the successor and the handoff
/// tally. Shared by [`ServingSim`] and [`super::FleetSim`] so switchover
/// semantics cannot diverge.
#[allow(clippy::too_many_arguments)]
pub(crate) fn switchover_engine(
    cost: &CostModel,
    hbm_per_device: u64,
    max_batch_cap: usize,
    outcome: &ScalingOutcome,
    old: Option<ServeEngine>,
    kv_factor: f64,
    batch_factor: f64,
    trace: &mut Trace,
    now: f64,
    event: usize,
) -> (ServeEngine, KvHandoffStats) {
    let mut fresh = build_engine(
        cost,
        hbm_per_device,
        max_batch_cap,
        &outcome.new_parallel,
        kv_factor,
        batch_factor,
    );
    let mut stats = KvHandoffStats::default();
    if let Some(mut old) = old {
        let (running, waiting) = old.drain();
        for mut r in running {
            // `blanket` marks adoption without a per-sequence plan: the
            // method keeps in-flight work alive but models no KV
            // movement, so it must not count as a zero-copy remap.
            let (disposition, blanket) = match &outcome.kv_handoff {
                Some(h) => (h.disposition(r.id), false),
                None if outcome.preserves_inflight => {
                    (HandoffDisposition::Remap, true)
                }
                None => (HandoffDisposition::Recompute, false),
            };
            let adopt = disposition != HandoffDisposition::Recompute
                && fresh.kv.can_admit(r.total_tokens());
            if adopt {
                // KV carried across the event: progress kept.
                fresh.kv.admit(r.id, r.current_len()).ok();
                r.state = RequestState::Decoding;
                trace.push(TraceEvent::Adopted {
                    t: now,
                    event,
                    id: r.id,
                    remap: disposition == HandoffDisposition::Remap,
                });
                if blanket {
                    stats.adopted_blanket += 1;
                } else {
                    match disposition {
                        HandoffDisposition::Remap => stats.remapped += 1,
                        _ => stats.copied += 1,
                    }
                }
                stats.adopted_tokens += r.generated as u64;
                fresh.batcher_adopt(r);
            } else {
                // Restart from scratch (same fields the preemption
                // restart path preserves: tenant and live-path prompt).
                trace.push(TraceEvent::Restarted { t: now, event, id: r.id });
                stats.recomputed += 1;
                stats.recompute_tokens += r.prompt_len as u64;
                stats.lost_decode_tokens += r.generated as u64;
                let mut restart = Request::new(
                    r.id,
                    r.arrival,
                    r.prompt_len,
                    r.max_new_tokens,
                )
                .with_tenant(r.tenant);
                restart.prompt_ids = r.prompt_ids.clone();
                fresh.submit(restart);
            }
        }
        for w in waiting {
            fresh.submit(w);
        }
    }
    (fresh, stats)
}

/// Enact the instantaneous effects of a freshly issued scaling event on
/// the active engine: pause intake if the pause window opens at the
/// command itself (a later window is enacted by the serving loop when it
/// opens), and derate throughput for the transition. Returns whether the
/// pause was enacted here (the caller tracks the open edge for the
/// trace).
pub(crate) fn begin_transition_on(
    outcome: &ScalingOutcome,
    engine: Option<&mut ServeEngine>,
    trace: &mut Trace,
    now: f64,
    event: usize,
) -> bool {
    let mut paused = false;
    if let Some(eng) = engine {
        if let Some((a, _)) = outcome.intake_pause {
            if a <= 0.0 {
                eng.batcher.pause_intake();
                trace.push(TraceEvent::IntakePaused { t: now, event });
                paused = true;
            }
        }
        if outcome.transition_derate < 1.0 {
            eng.backend.set_derate(outcome.transition_derate);
        }
    }
    paused
}

/// Complete a pending scaling event against the active engine. On a
/// successful event, switch over to a fresh engine and return the new
/// configuration; on an aborted (rolled-back) event, keep the old
/// engine — reopen intake, clear the transition derate, resume the
/// suspended sequences in place — and return `None`. Emits the
/// completion trace events and pushes the outcome into `events`.
/// Shared by [`ServingSim`] and [`super::FleetSim`] so the
/// completion/abort choreography cannot diverge.
#[allow(clippy::too_many_arguments)]
pub(crate) fn complete_pending(
    cost: &CostModel,
    hbm_per_device: u64,
    max_batch_cap: usize,
    p: PendingScale,
    engine: &mut Option<ServeEngine>,
    kv_factor: f64,
    batch_factor: f64,
    handoff: &mut KvHandoffStats,
    events: &mut Vec<ScalingOutcome>,
    trace: &mut Trace,
    now: f64,
) -> Option<ParallelConfig> {
    if let Some(ab) = &p.outcome.aborted {
        // Aborted + rolled back: the old engine keeps serving — not a
        // single in-flight request is dropped.
        if let Some(eng) = engine.as_mut() {
            if p.pause_open {
                eng.batcher.resume_intake();
                trace.push(TraceEvent::IntakeResumed {
                    t: now,
                    event: p.event,
                });
            }
            // The derate dies with the abandoned transition (the kept
            // engine must not stay throttled forever).
            eng.backend.set_derate(1.0);
            for id in eng.resume_suspended() {
                trace.push(TraceEvent::Resumed {
                    t: now,
                    event: p.event,
                    id,
                });
            }
        }
        trace.push(TraceEvent::ScaleAborted {
            t: now,
            event: p.event,
            rolled_back: ab.rolled_back,
            reason: ab.reason.clone(),
        });
        events.push(p.outcome);
        return None;
    }
    let (fresh, ho) = switchover_engine(
        cost,
        hbm_per_device,
        max_batch_cap,
        &p.outcome,
        engine.take(),
        kv_factor,
        batch_factor,
        trace,
        now,
        p.event,
    );
    if p.pause_open {
        trace.push(TraceEvent::IntakeResumed {
            t: now,
            event: p.event,
        });
    }
    handoff.merge(&ho);
    *engine = Some(fresh);
    let new_parallel = p.outcome.new_parallel.clone();
    trace.push(TraceEvent::ScaleCompleted {
        t: now,
        event: p.event,
        devices: new_parallel.n_devices(),
    });
    events.push(p.outcome);
    Some(new_parallel)
}

/// Keep the active engine's admission gate in sync with the pending
/// event's pause window, suspending the KV-handoff plan's copy
/// sequences exactly once when the window opens (their blocks are in
/// flight and must stay byte-stable until switchover or abort). Shared
/// by [`ServingSim`] and [`super::FleetSim`].
pub(crate) fn sync_pause_window(
    p: &mut PendingScale,
    eng: &mut ServeEngine,
    intake_open: bool,
    trace: &mut Trace,
    now: f64,
) {
    if intake_open {
        if p.pause_open {
            eng.batcher.resume_intake();
            trace.push(TraceEvent::IntakeResumed {
                t: now,
                event: p.event,
            });
            p.pause_open = false;
        }
    } else {
        if !p.pause_open {
            eng.batcher.pause_intake();
            trace.push(TraceEvent::IntakePaused {
                t: now,
                event: p.event,
            });
            p.pause_open = true;
        }
        if !p.suspended_applied {
            p.suspended_applied = true;
            if let Some(h) = &p.outcome.kv_handoff {
                for id in eng.suspend_sequences(h.suspend_ids()) {
                    trace.push(TraceEvent::Suspended {
                        t: now,
                        event: p.event,
                        id,
                    });
                }
            }
        }
    }
}

/// Emit the command-time trace events of a freshly issued scaling event:
/// the command itself (with its declared pause window in absolute time),
/// the plan audit, and any chaos faults that fired while the method
/// executed the plan. When telemetry is on, also derive the event's span
/// timeline and fault instants — the outcome is fully resolved at the
/// command, so this adds no simulator events. Shared by [`ServingSim`]
/// and [`super::FleetSim`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn log_command(
    trace: &mut Trace,
    tel: Option<&mut Telemetry>,
    replica: usize,
    injector: Option<&Rc<RefCell<FaultInjector>>>,
    now: f64,
    event: usize,
    from_devices: usize,
    outcome: &ScalingOutcome,
) {
    trace.push(TraceEvent::ScaleCommand {
        t: now,
        event,
        from_devices,
        to_devices: outcome.new_parallel.n_devices(),
        declared_pause: outcome
            .intake_pause
            .map(|(a, b)| (now + a, now + b)),
    });
    if let Some(audit) = outcome.plan_audit {
        trace.push(TraceEvent::PlanAudited {
            t: now,
            event,
            audit,
        });
    }
    let mut tel = tel;
    if let Some(t) = tel.as_deref_mut() {
        t.inc("scale_commands", 1);
        if outcome.aborted.is_some() {
            t.inc("scale_aborts", 1);
        }
        t.observe("scale_latency_s", outcome.ready_after);
        t.spans.scaling_event(replica, event, now, outcome);
    }
    if let Some(inj) = injector {
        for rec in inj.borrow_mut().take_fired() {
            trace.push(TraceEvent::FaultFired {
                t: now,
                event,
                fault: rec.kind,
            });
            if let Some(t) = tel.as_deref_mut() {
                t.inc("faults_fired", 1);
                t.spans.instant(
                    replica,
                    format!("scale{event}/fault: {:?}", rec.kind),
                    now,
                );
            }
        }
    }
}

/// Snapshot the active engine + scaling-method state into a telemetry
/// gauge sample. Shared by [`ServingSim`] (replica 0) and
/// [`super::FleetSim`] (one call per live replica on each policy tick).
pub(crate) fn replica_gauges(
    engine: Option<&ServeEngine>,
    method: &dyn ScalingMethod,
    devices: usize,
    coordinator_queue: usize,
    parked: bool,
) -> ReplicaSample {
    let mut s = ReplicaSample {
        queue_depth: coordinator_queue,
        devices,
        hbm_used: method.hbm_used_bytes(),
        hbm_peak: method.hbm_peak_bytes(),
        dram_used: method.dram_resident_bytes(),
        parked,
        ..Default::default()
    };
    if let Some(e) = engine {
        s.queue_depth += e.batcher.queue_len();
        s.running = e.batcher.running_len();
        s.suspended = e.batcher.suspended_len();
        s.kv_blocks = e.kv.used_blocks();
        s.intake_paused = e.batcher.intake_paused();
    }
    s
}

/// The coordinator-driven serving simulator.
pub struct ServingSim {
    pub cost: CostModel,
    pub slo: SloConfig,
    pub hbm_per_device: u64,
    /// Estimator observation window (seconds).
    pub window: f64,
    pub max_batch: usize,
    /// Chaos hook, shared with the scaling method's HMM: the simulator
    /// drains its fired-fault records into the run trace at each scale
    /// command. `None` = no fault injection.
    pub injector: Option<Rc<RefCell<FaultInjector>>>,
    /// Collect telemetry into [`SimOutput::telemetry`]. Off by default;
    /// determinism-neutral when on (sampling piggybacks on window ticks
    /// the event core already schedules, and nothing telemetry-side
    /// feeds back into simulation state or the run digest).
    pub obs: bool,
}

impl ServingSim {
    pub fn new(cost: CostModel, slo: SloConfig) -> Self {
        ServingSim {
            cost,
            slo,
            hbm_per_device: 64 << 30,
            window: 5.0,
            max_batch: 256,
            injector: None,
            obs: false,
        }
    }

    fn make_engine(
        &self,
        parallel: &ParallelConfig,
        kv_factor: f64,
        batch_factor: f64,
    ) -> ServeEngine {
        build_engine(
            &self.cost,
            self.hbm_per_device,
            self.max_batch,
            parallel,
            kv_factor,
            batch_factor,
        )
    }

    /// Run the loop until `horizon` (plus drain of whatever remains, up to
    /// `horizon * 2`).
    pub fn run(
        &self,
        method: &mut dyn ScalingMethod,
        initial: &ParallelConfig,
        mut arrivals: Vec<Request>,
        mut trigger: Trigger,
        horizon: f64,
    ) -> Result<SimOutput> {
        let clock = SimClock::new();
        method.boot(initial)?;
        let kv_factor = method.steady_kv_factor();
        let batch_factor = method.steady_batch_factor();
        let mut engine = Some(self.make_engine(initial, kv_factor, batch_factor));
        let mut current = initial.clone();
        let mut recorder = MetricsRecorder::with_capacity(arrivals.len());
        let mut events: Vec<ScalingOutcome> = Vec::new();
        let mut device_timeline = vec![(0.0, initial.n_devices())];
        let mut handoff = KvHandoffStats::default();
        let mut trace = Trace::new();
        let mut shash = StateHash::new();
        let mut event_seq = 0usize;
        let mut tel: Option<Telemetry> = if self.obs {
            Some(Telemetry::new())
        } else {
            None
        };

        arrivals.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        // Seed the event spine: one marker per arrival, the first
        // estimator tick, and every manual command time. Scaling stage
        // boundaries join the queue when their command is issued.
        let mut queue: EventQueue<SimEvent> =
            EventQueue::with_capacity(arrivals.len() + 8);
        for r in &arrivals {
            trace.push(TraceEvent::Arrival {
                t: r.arrival,
                id: r.id,
                tokens: r.max_new_tokens,
            });
            queue.push(r.arrival, SimEvent::Arrival);
        }
        queue.push(self.window, SimEvent::WindowTick);
        if let Trigger::Manual(list) = &trigger {
            for (t, _) in list {
                queue.push(*t, SimEvent::Command);
            }
        }
        let mut arrivals: VecDeque<Request> = arrivals.into();
        let mut inbox: VecDeque<Request> = VecDeque::new();
        let mut pending: Option<PendingScale> = None;
        let hard_stop = horizon * 2.0 + 300.0;

        loop {
            let now = clock.now();
            if now >= hard_stop {
                break;
            }

            // 0) Consume every wake marker that is due. Markers carry no
            // state — the transitions they announce are applied by the
            // condition-based handlers below at the current clock — so an
            // engine step overshooting a marker is handled at the
            // post-step clock, never replayed into the past. Overdue
            // window ticks coalesce into one estimator observation.
            let mut window_tick = false;
            while queue.peek_time().map(|t| t <= now).unwrap_or(false) {
                let ev = queue.pop().unwrap();
                if ev.payload == SimEvent::WindowTick {
                    window_tick = true;
                    queue.push(ev.at + self.window, SimEvent::WindowTick);
                }
            }

            // 1) Deliver arrivals up to `now` into the coordinator inbox.
            while arrivals
                .front()
                .map(|r| r.arrival <= now)
                .unwrap_or(false)
            {
                inbox.push_back(arrivals.pop_front().unwrap());
            }

            // 2) Complete a pending scaling event. An aborted event
            // (fault + rollback) keeps the old engine: intake reopens and
            // the suspended sequences resume on their origin replica.
            if let Some(p) = &pending {
                if now >= p.started + p.outcome.ready_after {
                    let p = pending.take().unwrap();
                    if let Some(t) = tel.as_mut() {
                        t.inc(
                            if p.outcome.aborted.is_some() {
                                "scale_rollbacks"
                            } else {
                                "scale_completions"
                            },
                            1,
                        );
                    }
                    if let Some(new_parallel) = complete_pending(
                        &self.cost,
                        self.hbm_per_device,
                        self.max_batch,
                        p,
                        &mut engine,
                        kv_factor,
                        batch_factor,
                        &mut handoff,
                        &mut events,
                        &mut trace,
                        now,
                    ) {
                        current = new_parallel;
                        device_timeline.push((now, current.n_devices()));
                    }
                }
            }

            // 3) Downtime / intake handling.
            let in_downtime = pending
                .as_ref()
                .map(|p| p.outcome.in_downtime(p.started, now))
                .unwrap_or(false);
            let intake_open = pending
                .as_ref()
                .map(|p| p.outcome.intake_open(p.started, now))
                .unwrap_or(true);

            // Feed the engine from the inbox when intake is open, and keep
            // the batcher's admission gate in sync with the pause window
            // (the window may start mid-transition: ElasticMoE only pauses
            // for the final switchover, not the concurrent HMM/IMM phase).
            // When the pause window opens, the KV-handoff plan's copy
            // sequences are suspended — their blocks are in flight to the
            // new owner and must stay byte-stable until switchover.
            if let Some(eng) = engine.as_mut() {
                if let Some(p) = pending.as_mut() {
                    sync_pause_window(p, eng, intake_open, &mut trace, now);
                }
                if intake_open && !in_downtime {
                    while let Some(r) = inbox.pop_front() {
                        eng.submit(r);
                    }
                }
            }

            // 4) Estimator tick (woken by the self-rescheduling
            // `WindowTick` marker). Telemetry samples on the same wakeup
            // — the tick was already scheduled, so sampling adds no
            // queue entries.
            if window_tick {
                if let Some(t) = tel.as_mut() {
                    let s = replica_gauges(
                        engine.as_ref(),
                        &*method,
                        current.n_devices(),
                        inbox.len(),
                        false,
                    );
                    t.sample_replica(now, 0, &s);
                }
                if let Trigger::Auto {
                    estimator,
                    up,
                    down,
                } = &mut trigger
                {
                    if pending.is_none() {
                        let att = recorder.attainment_by_arrival(
                            now - self.window,
                            now,
                            &self.slo,
                        );
                        let (occ, depth) = engine
                            .as_ref()
                            .map(|e| {
                                (
                                    e.batcher.running_len() as f64
                                        / e.batcher.cfg.max_batch.max(1)
                                            as f64,
                                    e.batcher.queue_len() + inbox.len(),
                                )
                            })
                            .unwrap_or((1.0, inbox.len()));
                        // Pre-observe estimator state, for the explain
                        // record (observe may consume either).
                        let cooling = estimator.is_cooling(now);
                        let rearmed = estimator.rearmed().is_some();
                        let decision =
                            estimator.observe(now, att, occ, depth);
                        let target = match decision {
                            ScaleDecision::Up => up(&current),
                            ScaleDecision::Down => down(&current),
                            ScaleDecision::Hold => None,
                        };
                        // Explain the window's verdict in the trace
                        // (unconditional — never telemetry-gated — so
                        // state hashes stay obs-neutral). `vetoed`: the
                        // hysteresis fired but the vertical envelope had
                        // no step to give.
                        trace.push(TraceEvent::DecisionExplain {
                            t: now,
                            pool: "unified",
                            serving: 1,
                            attainment: if att.is_nan() { -1.0 } else { att },
                            occupancy: occ,
                            queue: depth,
                            bad_windows: estimator.bad_windows() as usize,
                            good_windows: estimator.good_windows()
                                as usize,
                            cooling,
                            rearmed,
                            reburst: false,
                            decision: match decision {
                                ScaleDecision::Up => "up",
                                ScaleDecision::Down => "down",
                                ScaleDecision::Hold => "hold",
                            },
                            action: match &target {
                                Some(t) => {
                                    format!("scale->{}dev", t.n_devices())
                                }
                                None => "hold".to_string(),
                            },
                            vetoed: decision != ScaleDecision::Hold
                                && target.is_none(),
                        });
                        if let Some(target) = target {
                            // The live block tables become the ownership
                            // snapshot the KV-migration planner works on.
                            let outcome = match engine.as_ref() {
                                Some(e) => method.scale_with_kv(
                                    &target,
                                    &KvSnapshot::capture(&e.kv, &current),
                                )?,
                                None => method.scale(&target)?,
                            };
                            let ev = event_seq;
                            event_seq += 1;
                            log_command(
                                &mut trace,
                                tel.as_mut(),
                                0,
                                self.injector.as_ref(),
                                now,
                                ev,
                                current.n_devices(),
                                &outcome,
                            );
                            let paused = begin_transition_on(
                                &outcome,
                                engine.as_mut(),
                                &mut trace,
                                now,
                                ev,
                            );
                            schedule_transition(&mut queue, now, &outcome);
                            pending = Some(PendingScale::new(
                                outcome, now, ev, paused,
                            ));
                        }
                    }
                }
            }
            if let Trigger::Manual(list) = &mut trigger {
                if pending.is_none() {
                    if let Some((t, _)) = list.first() {
                        if now >= *t {
                            let (_, target) = list.remove(0);
                            let outcome = match engine.as_ref() {
                                Some(e) => method.scale_with_kv(
                                    &target,
                                    &KvSnapshot::capture(&e.kv, &current),
                                )?,
                                None => method.scale(&target)?,
                            };
                            let ev = event_seq;
                            event_seq += 1;
                            log_command(
                                &mut trace,
                                tel.as_mut(),
                                0,
                                self.injector.as_ref(),
                                now,
                                ev,
                                current.n_devices(),
                                &outcome,
                            );
                            let paused = begin_transition_on(
                                &outcome,
                                engine.as_mut(),
                                &mut trace,
                                now,
                                ev,
                            );
                            schedule_transition(&mut queue, now, &outcome);
                            pending = Some(PendingScale::new(
                                outcome, now, ev, paused,
                            ));
                        }
                    }
                }
            }

            // 5) Step the engine (unless downtime).
            let stepped = if in_downtime {
                false
            } else if let Some(eng) = engine.as_mut() {
                if eng.has_work() {
                    let out = eng.step(&clock)?;
                    // Fold the step completion — the implicit
                    // continuation event — into the run digest.
                    shash.fold_u64(match out.kind {
                        StepKind::Prefill => 0,
                        StepKind::Decode => 1,
                        StepKind::Idle => 2,
                    });
                    shash.fold_f64(out.duration);
                    shash.fold_usize(eng.kv.used_blocks());
                    shash.fold_usize(eng.batcher.running_len());
                    shash.fold_usize(eng.batcher.queue_len());
                    shash.fold_usize(out.preempted);
                    for r in out.finished {
                        trace.push(TraceEvent::Finished {
                            t: clock.now(),
                            id: r.id,
                            tokens: r.generated,
                        });
                        if let Some(t) = tel.as_mut() {
                            t.inc("requests_finished", 1);
                            t.inc("tokens_generated", r.generated as u64);
                            if let Some(ttft) = r.ttft() {
                                t.observe("ttft_s", ttft);
                            }
                            if let Some(tpot) = r.tpot() {
                                t.observe("tpot_s", tpot);
                            }
                        }
                        recorder.record(&r);
                    }
                    // An Idle step (e.g. intake paused with only queued
                    // work) advances nothing: fall through to the event
                    // jump below or the loop would spin at a frozen clock.
                    !matches!(out.kind, StepKind::Idle)
                } else {
                    false
                }
            } else {
                false
            };

            // 6) Idle: jump the clock to the next queued event.
            if !stepped {
                // All drained: stop regardless of the horizon (offline
                // runs use an effectively infinite horizon).
                if arrivals.is_empty()
                    && inbox.is_empty()
                    && engine
                        .as_ref()
                        .map(|e| !e.has_work())
                        .unwrap_or(true)
                    && pending.is_none()
                {
                    break;
                }
                let Some(next) = queue.peek_time() else {
                    break; // nothing left anywhere
                };
                clock.advance_to(next + 1e-9);
            }
        }

        // Seal the digest with the full event trace (arrivals, commands,
        // plan audits, pause edges, dispositions, finishes). Telemetry
        // is deliberately NOT folded in — the digest must be identical
        // with observability on or off.
        shash.fold_u64(trace.state_hash());
        shash.fold_usize(recorder.count());
        if let Some(t) = tel.as_mut() {
            t.spans.finish(clock.now());
            t.set_gauge("end_time_s", clock.now());
            t.set_gauge("requests_completed", recorder.count() as f64);
        }
        Ok(SimOutput {
            recorder,
            scaling_events: events,
            end_time: clock.now(),
            device_timeline,
            handoff,
            trace,
            state_hash: shash.value(),
            telemetry: tel,
        })
    }
}

impl ServeEngine {
    /// Adopt a request that keeps its decode progress (zero-copy KV reuse
    /// across switchover). KV must already be admitted by the caller.
    pub fn batcher_adopt(&mut self, r: Request) {
        self.batcher.adopt_running(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    use crate::config::model::dsv2_lite;
    use crate::device::{Cluster, Timings};
    use crate::hmm::control::{HmmControl, HmmOptions};
    use crate::imm::manager::{ImmOptions, InstanceManager};
    use crate::scaling::{ColdRestart, ElasticMoE};
    use crate::workload::{RateProfile, WorkloadGen, WorkloadSpec};

    fn par(n: usize) -> ParallelConfig {
        ParallelConfig::standard(n / 2, 2, (0..n).collect()).unwrap()
    }

    fn sim() -> ServingSim {
        ServingSim::new(
            CostModel::new(dsv2_lite(), Timings::cloudmatrix()),
            SloConfig::new(5.0, 1.5),
        )
    }

    fn elastic(n: usize) -> ElasticMoE {
        let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(n)));
        ElasticMoE::new(
            HmmControl::new(cluster, dsv2_lite(), HmmOptions::default()),
            InstanceManager::new(ImmOptions::default(), Timings::cloudmatrix()),
            8 << 30,
        )
    }

    fn workload(rps: f64, horizon: f64) -> Vec<Request> {
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 2000,
            decode_min: 100,
            decode_max: 150,
            profile: RateProfile::Fixed(rps),
            seed: 5,
        });
        g.arrivals_until(horizon)
    }

    #[test]
    fn steady_serving_completes_requests() {
        let s = sim();
        let mut m = elastic(4);
        let out = s
            .run(&mut m, &par(4), workload(1.0, 60.0), Trigger::Manual(vec![]), 60.0)
            .unwrap();
        assert!(out.recorder.count() > 30, "{}", out.recorder.count());
        let w = out.recorder.window(0.0, out.end_time, &s.slo);
        assert!(w.slo_attainment > 0.9, "{}", w.slo_attainment);
        assert!(out.scaling_events.is_empty());
    }

    #[test]
    fn manual_scale_up_mid_run_no_downtime() {
        let s = sim();
        let mut m = elastic(6);
        let out = s
            .run(
                &mut m,
                &par(4),
                workload(2.0, 120.0),
                Trigger::Manual(vec![(30.0, par(6))]),
                120.0,
            )
            .unwrap();
        assert_eq!(out.scaling_events.len(), 1);
        assert_eq!(out.scaling_events[0].metrics.downtime, 0.0);
        assert_eq!(out.device_timeline.last().unwrap().1, 6);
        // Every request eventually finishes.
        let total_arrived = workload(2.0, 120.0).len();
        assert_eq!(out.recorder.count(), total_arrived);
    }

    #[test]
    fn elastic_scale_up_adopts_inflight_with_zero_recompute() {
        // Long-context traffic so plenty of sequences are mid-decode at
        // the command. Scale-up 4->6: every device group survives, so the
        // handoff is pure remap — zero prefill recompute, no lost decode.
        let s = sim();
        let mut m = elastic(6);
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 4000,
            decode_min: 150,
            decode_max: 250,
            profile: RateProfile::Fixed(1.5),
            seed: 11,
        });
        let arrivals = g.arrivals_until(120.0);
        let n = arrivals.len();
        let out = s
            .run(
                &mut m,
                &par(4),
                arrivals,
                Trigger::Manual(vec![(30.0, par(6))]),
                120.0,
            )
            .unwrap();
        assert_eq!(out.recorder.count(), n, "every request finishes once");
        assert!(out.handoff.remapped > 0, "in-flight work was adopted");
        assert_eq!(out.handoff.recomputed, 0);
        assert_eq!(out.handoff.recompute_tokens, 0);
        assert_eq!(out.handoff.lost_decode_tokens, 0);
        assert!(out.handoff.adopted_tokens > 0);
    }

    #[test]
    fn telemetry_is_determinism_neutral_and_classifies_spans() {
        use crate::obs::spans::{CAT_CONCURRENT, CAT_SWITCHOVER};

        let run = |obs: bool| {
            let mut s = sim();
            s.obs = obs;
            let mut m = elastic(6);
            s.run(
                &mut m,
                &par(4),
                workload(2.0, 120.0),
                Trigger::Manual(vec![(30.0, par(6))]),
                120.0,
            )
            .unwrap()
        };
        let off = run(false);
        let on = run(true);
        // The determinism-neutrality contract: bit-identical digest.
        assert_eq!(off.state_hash, on.state_hash);
        assert!(off.telemetry.is_none());

        let tel = on.telemetry.as_ref().unwrap();
        assert_eq!(tel.counter("scale_commands"), 1);
        assert_eq!(tel.counter("scale_completions"), 1);
        assert_eq!(
            tel.counter("requests_finished") as usize,
            on.recorder.count()
        );
        assert!(tel.histogram("ttft_s").unwrap().count() > 0);
        assert!(tel.series("replica0/queue_depth").is_some());
        assert!(tel.series("replica0/hbm_used_bytes").is_some());

        // The §5.2 choreography, visible in the span timeline: the
        // concurrent phases (p2p, remap, kv_init, prep, warmup) all end
        // by the declared pause start; only the switchover-window phases
        // (kv handoff legs + reroute) sit inside the pause.
        let spans = tel.spans.for_event(0);
        let pause = spans
            .iter()
            .find(|s| s.name == "scale0/intake_pause")
            .expect("pause window span");
        let conc: Vec<_> =
            spans.iter().filter(|s| s.cat == CAT_CONCURRENT).collect();
        let sw: Vec<_> =
            spans.iter().filter(|s| s.cat == CAT_SWITCHOVER).collect();
        assert!(!conc.is_empty(), "no concurrent phases recorded");
        assert!(!sw.is_empty(), "no switchover-window phases recorded");
        for s in &conc {
            assert!(
                s.end <= pause.start + 1e-6,
                "{} overlaps the pause window",
                s.name
            );
        }
        for s in &sw {
            assert!(
                s.start >= pause.start - 1e-6 && s.end <= pause.end + 1e-6,
                "{} escapes the pause window",
                s.name
            );
        }
    }

    #[test]
    fn cold_restart_shows_downtime_gap() {
        let s = sim();
        let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(6)));
        let mut m = ColdRestart::new(cluster, dsv2_lite(), 8 << 30);
        let out = s
            .run(
                &mut m,
                &par(4),
                workload(2.0, 120.0),
                Trigger::Manual(vec![(30.0, par(6))]),
                120.0,
            )
            .unwrap();
        assert_eq!(out.scaling_events.len(), 1);
        let ev = &out.scaling_events[0];
        assert!(ev.metrics.downtime > 10.0, "{}", ev.metrics.downtime);
        // Requests arriving during downtime suffer: attainment in the
        // post-command window is worse than steady state.
        let before =
            out.recorder.attainment_by_arrival(0.0, 30.0, &s.slo);
        let during = out.recorder.attainment_by_arrival(
            30.0,
            30.0 + ev.ready_after,
            &s.slo,
        );
        assert!(
            during < before,
            "during {during} should be worse than before {before}"
        );
    }
}
