//! Declared-spec vs observed-state reconciler for the fleet control
//! plane.
//!
//! Each `PolicyTick`, [`crate::coordinator::FleetPolicy::decide`]
//! declares a [`FleetSpec`] and [`Reconciler::plan`] diffs it against
//! the observed [`ReplicaLoad`]s into a batch of idempotent
//! [`ReconcileStep`]s. The planner is **pure and stateless**: a step
//! interrupted by a crash or an aborted scale is simply re-derived from
//! observed state on the next tick — never replayed from a log — so
//! duplicate or stale enactment converges instead of compounding.
//!
//! The diff also owns the heartbeat/eviction lifecycle: a live,
//! non-parked replica whose `last_heartbeat` is staler than
//! [`Reconciler::heartbeat_deadline`] is suspect and gets an
//! [`ReconcileStep::Evict`]; its spec slot (now with no healthy
//! observed counterpart) is re-planned as an [`ReconcileStep::Add`] in
//! the same round.
//!
//! A round's *spec drift* is its planned step count — the distance
//! between declared and observed state. Replicas mid-transition
//! (`busy`) are converging, not drifted, and are skipped. See
//! `docs/architecture/09-control-plane.md`.

use super::policy::{FleetSpec, ReplicaLoad};

/// One idempotent reconcile step. Enactment must be guarded: a step
/// whose precondition no longer holds in observed state (already
/// applied, replica busy, pool exhausted) is a checked no-op, traced
/// with `applied: false`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReconcileStep {
    /// Scale `replica` vertically to `to_devices`.
    Resize { replica: usize, to_devices: usize },
    /// Park `replica` at zero devices (DRAM-warm scale-to-zero).
    Park { replica: usize },
    /// Wake parked `replica` at its pre-park footprint.
    Unpark { replica: usize },
    /// Boot a fresh replica for spec slot `slot` with `devices`
    /// devices (the simulator assigns the real replica id at boot).
    Add { slot: usize, devices: usize },
    /// Stop routing to `replica`; release its devices once drained.
    Drain { replica: usize },
    /// Redistribution-only event on `replica` (same devices, new
    /// expert placement).
    Rebalance { replica: usize },
    /// `replica`'s heartbeat staleness passed the deadline: retire it
    /// and re-home its queued/in-flight requests.
    Evict { replica: usize },
}

impl ReconcileStep {
    /// The replica (or spec slot) the step targets.
    pub fn replica(&self) -> usize {
        match self {
            ReconcileStep::Resize { replica, .. }
            | ReconcileStep::Park { replica }
            | ReconcileStep::Unpark { replica }
            | ReconcileStep::Drain { replica }
            | ReconcileStep::Rebalance { replica }
            | ReconcileStep::Evict { replica } => *replica,
            ReconcileStep::Add { slot, .. } => *slot,
        }
    }

    /// Stable description for trace rendering (the
    /// [`crate::chaos::TraceEvent::ReconcileStep`] `step` field).
    pub fn describe(&self) -> String {
        match self {
            ReconcileStep::Resize { to_devices, .. } => {
                format!("resize->{to_devices}")
            }
            ReconcileStep::Park { .. } => "park".to_string(),
            ReconcileStep::Unpark { .. } => "unpark".to_string(),
            ReconcileStep::Add { devices, .. } => {
                format!("add@{devices}")
            }
            ReconcileStep::Drain { .. } => "drain".to_string(),
            ReconcileStep::Rebalance { .. } => "rebalance".to_string(),
            ReconcileStep::Evict { .. } => "evict".to_string(),
        }
    }
}

/// Diffs a declared [`FleetSpec`] against observed [`ReplicaLoad`]s.
#[derive(Debug, Clone, Copy)]
pub struct Reconciler {
    /// Seconds without a heartbeat before a live, non-parked,
    /// already-booted replica is suspect and evicted.
    pub heartbeat_deadline: f64,
}

impl Reconciler {
    pub fn new(heartbeat_deadline: f64) -> Self {
        Reconciler { heartbeat_deadline }
    }

    /// Plan the steps that converge `observed` onto `spec` at `now`.
    ///
    /// Deterministic and pure: same inputs, same step batch, in a
    /// stable order (evictions first, then per-slot convergence in spec
    /// order, then drains in observed order, then the rebalance
    /// passthrough). The batch length is the round's spec drift.
    pub fn plan(
        &self,
        spec: &FleetSpec,
        observed: &[ReplicaLoad],
        now: f64,
    ) -> Vec<ReconcileStep> {
        let mut steps = Vec::new();

        // 1) Heartbeat staleness: evict suspects. Parked replicas beat
        // nothing by design; busy replicas (mid-scale or booting) are
        // left to finish their transition and re-checked next round.
        let mut evicted = Vec::new();
        for l in observed {
            if !l.parked
                && !l.draining
                && !l.busy
                && now - l.last_heartbeat > self.heartbeat_deadline
            {
                steps.push(ReconcileStep::Evict { replica: l.id });
                evicted.push(l.id);
            }
        }
        let healthy = |id: usize| -> Option<&ReplicaLoad> {
            if evicted.contains(&id) {
                return None;
            }
            observed.iter().find(|l| l.id == id && !l.draining)
        };

        // 2) Per-slot convergence, in spec order.
        for s in &spec.replicas {
            match healthy(s.id) {
                Some(l) => {
                    if l.busy {
                        // Converging, not drifted: a transition or boot
                        // is in flight toward (or away from) the spec.
                        continue;
                    }
                    if l.parked && !s.parked {
                        steps.push(ReconcileStep::Unpark { replica: s.id });
                    } else if !l.parked && s.parked {
                        steps.push(ReconcileStep::Park { replica: s.id });
                    } else if !l.parked
                        && s.devices > 0
                        && l.devices != s.devices
                    {
                        steps.push(ReconcileStep::Resize {
                            replica: s.id,
                            to_devices: s.devices,
                        });
                    }
                }
                // No healthy observed counterpart: boot the slot. A
                // parked or size-unspecified slot has nothing concrete
                // to boot and waits for the next projection.
                None => {
                    if !s.parked && s.devices > 0 {
                        steps.push(ReconcileStep::Add {
                            slot: s.id,
                            devices: s.devices,
                        });
                    }
                }
            }
        }

        // 3) Observed replicas absent from the spec drain out.
        for l in observed {
            if !l.draining
                && !evicted.contains(&l.id)
                && spec.slot(l.id).is_none()
            {
                steps.push(ReconcileStep::Drain { replica: l.id });
            }
        }

        // 4) One-shot rebalance passthrough.
        if let Some(r) = spec.rebalance {
            if let Some(l) = healthy(r) {
                if !l.busy && !l.parked {
                    steps.push(ReconcileStep::Rebalance { replica: r });
                }
            }
        }

        steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{PoolRole, ReplicaSpec};

    fn obs(id: usize, devices: usize, hb: f64) -> ReplicaLoad {
        ReplicaLoad {
            id,
            devices,
            occupancy: 0.5,
            queue_depth: 0,
            busy: false,
            booting: false,
            draining: false,
            parked: false,
            imbalance: 1.0,
            last_heartbeat: hb,
            role: PoolRole::Unified,
        }
    }

    fn slot(id: usize, devices: usize, parked: bool) -> ReplicaSpec {
        ReplicaSpec { id, devices, parked, role: PoolRole::Unified }
    }

    fn spec(slots: Vec<ReplicaSpec>) -> FleetSpec {
        FleetSpec { replicas: slots, rebalance: None }
    }

    fn rec() -> Reconciler {
        Reconciler::new(10.0)
    }

    #[test]
    fn converged_fleet_plans_nothing() {
        let s = spec(vec![slot(0, 4, false), slot(1, 2, false)]);
        let o = [obs(0, 4, 20.0), obs(1, 2, 20.0)];
        assert!(rec().plan(&s, &o, 21.0).is_empty());
    }

    #[test]
    fn device_mismatch_plans_a_resize() {
        let s = spec(vec![slot(0, 6, false)]);
        let o = [obs(0, 4, 20.0)];
        assert_eq!(
            rec().plan(&s, &o, 21.0),
            vec![ReconcileStep::Resize { replica: 0, to_devices: 6 }]
        );
    }

    #[test]
    fn busy_replicas_are_converging_not_drifted() {
        let s = spec(vec![slot(0, 6, false)]);
        let mut l = obs(0, 4, 20.0);
        l.busy = true;
        assert!(rec().plan(&s, &[l], 21.0).is_empty());
    }

    #[test]
    fn missing_slot_adds_and_extra_replica_drains() {
        let s = spec(vec![slot(0, 4, false), slot(2, 2, false)]);
        let o = [obs(0, 4, 20.0), obs(1, 2, 20.0)];
        assert_eq!(
            rec().plan(&s, &o, 21.0),
            vec![
                ReconcileStep::Add { slot: 2, devices: 2 },
                ReconcileStep::Drain { replica: 1 },
            ]
        );
        // An already-draining replica is not re-drained.
        let mut draining = obs(1, 2, 20.0);
        draining.draining = true;
        let o = [obs(0, 4, 20.0), draining];
        assert_eq!(
            rec().plan(&s, &o, 21.0),
            vec![ReconcileStep::Add { slot: 2, devices: 2 }]
        );
    }

    #[test]
    fn park_mismatches_plan_park_and_unpark() {
        let s = spec(vec![slot(0, 0, true)]);
        let o = [obs(0, 2, 20.0)];
        assert_eq!(
            rec().plan(&s, &o, 21.0),
            vec![ReconcileStep::Park { replica: 0 }]
        );
        let s = spec(vec![slot(0, 0, false)]);
        let mut parked = obs(0, 0, 0.0); // parked replicas beat nothing
        parked.parked = true;
        assert_eq!(
            rec().plan(&s, &[parked], 100.0),
            vec![ReconcileStep::Unpark { replica: 0 }],
            "parked replicas are heartbeat-exempt and wake on demand"
        );
    }

    #[test]
    fn stale_heartbeat_evicts_and_replans_the_slot() {
        let s = spec(vec![slot(0, 4, false), slot(1, 2, false)]);
        let o = [obs(0, 4, 20.0), obs(1, 2, 5.0)]; // 1 is 16 s stale
        assert_eq!(
            rec().plan(&s, &o, 21.0),
            vec![
                ReconcileStep::Evict { replica: 1 },
                ReconcileStep::Add { slot: 1, devices: 2 },
            ]
        );
    }

    #[test]
    fn plan_is_idempotent_on_the_converged_state() {
        // Applying the planned steps (modelled) yields a state the
        // planner has nothing left to say about.
        let s = spec(vec![slot(0, 6, false)]);
        let o = [obs(0, 4, 20.0)];
        let steps = rec().plan(&s, &o, 21.0);
        assert_eq!(steps.len(), 1);
        let after = [obs(0, 6, 20.0)]; // resize applied
        assert!(rec().plan(&s, &after, 21.0).is_empty());
    }

    #[test]
    fn rebalance_passes_through_only_when_enactable() {
        let mut s = spec(vec![slot(0, 4, false)]);
        s.rebalance = Some(0);
        assert_eq!(
            rec().plan(&s, &[obs(0, 4, 20.0)], 21.0),
            vec![ReconcileStep::Rebalance { replica: 0 }]
        );
        let mut busy = obs(0, 4, 20.0);
        busy.busy = true;
        assert!(rec().plan(&s, &[busy], 21.0).is_empty());
    }

    #[test]
    fn steps_describe_stably() {
        assert_eq!(
            ReconcileStep::Resize { replica: 1, to_devices: 4 }.describe(),
            "resize->4"
        );
        assert_eq!(
            ReconcileStep::Add { slot: 2, devices: 2 }.describe(),
            "add@2"
        );
        assert_eq!(ReconcileStep::Evict { replica: 0 }.describe(), "evict");
        assert_eq!(ReconcileStep::Evict { replica: 3 }.replica(), 3);
    }
}
