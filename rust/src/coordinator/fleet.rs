//! Fleet serving simulator: N replicas behind a pluggable router, each
//! wrapping its own [`ScalingMethod`], with a [`FleetPolicy`] deciding per
//! window between vertical steps (ElasticMoE's fast path), whole-replica
//! add/drain (horizontal, cold-boot priced), or holding.
//!
//! The single-instance [`super::ServingSim`] reproduces the paper's
//! experiments; `FleetSim` composes many of those instances the way a real
//! deployment would, so ElasticMoE's seconds-scale vertical steps can be
//! measured against replica-granular horizontal provisioning on the same
//! trace.
//!
//! # Event-driven co-simulation
//!
//! The fleet loop runs on a [`crate::sim::EventQueue`] of typed
//! [`FleetEvent`]s: each arrival is a `Route` event dispatched to a
//! replica inbox at its arrival instant, the self-rescheduling
//! `Heartbeat` stamps every serving replica's liveness, and the
//! self-rescheduling `PolicyTick` advances every replica's
//! discrete-event clock to the tick time, drains tier journals, retires
//! drained replicas, and runs one **reconcile round**: the
//! [`FleetPolicy`] declares a desired [`FleetSpec`], the
//! [`Reconciler`] diffs it against the observed loads into idempotent
//! [`ReconcileStep`]s, and each step is enacted behind a precondition
//! guard — a stale or duplicate step is a checked no-op traced with
//! `applied: false`, never a silent mutation. Steps are re-derived from
//! observed state every round, so an interrupted or aborted transition
//! resumes by re-planning, not by replaying a log. Replica-internal
//! stage boundaries (switchover readiness, pause windows, downtime,
//! boot/unpark `ready_at`) live on each replica's own timeline inside
//! [`FleetSim::advance_replica`], which jumps replica clocks
//! event-to-event rather than polling. Every transition folds into a
//! [`StateHash`] exposed as [`FleetOutput::state_hash`]; see
//! `docs/architecture/07-event-core.md` and
//! `docs/architecture/09-control-plane.md`.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::chaos::{FaultInjector, PlanAudit, Trace, TraceEvent};
use crate::config::{ParallelConfig, SloConfig};
use crate::engine::{CostModel, ServeEngine, StepKind};
use crate::kvmigrate::{
    home_rank, plan_kv_migration, KvHandoffStats, KvSeq, KvSnapshot,
};
use crate::metrics::MetricsRecorder;
use crate::obs::spans::CAT_LIFECYCLE;
use crate::obs::Telemetry;
use crate::scaling::{ScalingMethod, ScalingOutcome};
use crate::sim::{Clock, EventQueue, SimClock, StateHash};
use crate::workload::Request;

use super::estimator::ScaleDecision;
use super::policy::{FleetAction, FleetPolicy, PoolRole, ReplicaLoad};
use super::reconciler::{ReconcileStep, Reconciler};
use super::serving::{
    begin_transition_on, build_engine, complete_pending, log_command,
    replica_gauges, sync_pause_window, PendingScale,
};

/// Typed event on the fleet simulator's queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FleetEvent {
    /// A request reaches the fleet router (one marker per arrival; the
    /// handler routes every not-yet-routed arrival due at the marker's
    /// timestamp into a replica inbox).
    Route,
    /// Fleet policy boundary: advance all replicas to the tick, observe,
    /// reconcile. Self-reschedules every `window` until the trace is
    /// served.
    PolicyTick,
    /// Liveness beat: every serving replica stamps `last_heartbeat`
    /// (unless the fault injector swallows the beat). Self-reschedules
    /// every [`FleetSim::heartbeat_period`].
    Heartbeat,
}

/// How arrivals are spread across ready replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Router {
    /// Cycle through replicas in order.
    RoundRobin,
    /// Send each request to the replica with the fewest queued + running
    /// requests at routing time.
    JoinShortestQueue,
    /// Pin each tenant to a replica (sticky modulo the current fleet
    /// size), so a tenant's KV/prefix locality survives across requests.
    SessionAffinity,
}

impl Router {
    /// Pick a replica id from `eligible` `(id, backlog)` pairs.
    fn pick(
        &self,
        rr: &mut usize,
        tenant: u32,
        eligible: &[(usize, usize)],
    ) -> usize {
        debug_assert!(!eligible.is_empty());
        match self {
            Router::RoundRobin => {
                let id = eligible[*rr % eligible.len()].0;
                *rr += 1;
                id
            }
            Router::JoinShortestQueue => {
                eligible
                    .iter()
                    .min_by_key(|(id, backlog)| (*backlog, *id))
                    .unwrap()
                    .0
            }
            Router::SessionAffinity => {
                eligible[tenant as usize % eligible.len()].0
            }
        }
    }
}

/// One fleet member: an engine plus the scaling method that resizes it.
struct Replica {
    id: usize,
    method: Box<dyn ScalingMethod>,
    engine: Option<ServeEngine>,
    clock: SimClock,
    current: ParallelConfig,
    inbox: VecDeque<Request>,
    pending: Option<PendingScale>,
    /// Absolute time this replica starts serving (cold boot completes).
    ready_at: f64,
    draining: bool,
    retired: bool,
    /// Parked at zero devices (weights DRAM-warm in the method's tier
    /// store; engine gone, inbox kept so arrivals can queue while the
    /// policy wakes it).
    parked: bool,
    /// Last liveness beat this replica landed (absolute time). The
    /// reconciler evicts a serving replica whose staleness passes
    /// [`FleetSim::heartbeat_deadline`]; parked and booting replicas are
    /// exempt.
    last_heartbeat: f64,
    kv_factor: f64,
    batch_factor: f64,
    /// Which serving phase this replica is dedicated to. `Unified`
    /// everywhere unless [`FleetSim::initial_roles`] declares a
    /// disaggregated fleet; a replica keeps its role for life (it
    /// drains out rather than migrating pools).
    role: PoolRole,
    /// Prefill→decode handoffs in flight toward this (decode) replica:
    /// `(delivery time, request)`. The sequence's KV bytes are on the
    /// fabric until the delivery time, when the replica admits them and
    /// adopts the request with its decode progress intact (or falls
    /// back to recompute if its pool is full).
    adopt_inbox: VecDeque<(f64, Request)>,
    /// Sequences that completed prefill on this (prefill) replica and
    /// were pulled out of its running batch mid-window:
    /// `(prefill-done time, request)`. Handoff legs are planned for the
    /// whole stage at the next policy tick — the transfer clock still
    /// starts at the prefill-done time, the tick only does bookkeeping.
    stage: Vec<(f64, Request)>,
}

impl Replica {
    /// Devices this replica holds against the shared pool budget: the max
    /// of its current and pending-target footprint (a transition may
    /// momentarily reserve both).
    fn devices_reserved(&self) -> usize {
        if self.retired || self.parked {
            return 0;
        }
        let cur = self.current.n_devices();
        match &self.pending {
            Some(p) => cur.max(p.outcome.new_parallel.n_devices()),
            None => cur,
        }
    }

    fn backlog(&self) -> usize {
        let engine_q = self
            .engine
            .as_ref()
            .map(|e| e.batcher.queue_len() + e.batcher.running_len())
            .unwrap_or(0);
        self.inbox.len() + self.adopt_inbox.len() + self.stage.len()
            + engine_q
    }

    fn queue_depth(&self) -> usize {
        let engine_q = self
            .engine
            .as_ref()
            .map(|e| e.batcher.queue_len())
            .unwrap_or(0);
        self.inbox.len() + self.adopt_inbox.len() + self.stage.len()
            + engine_q
    }

    fn is_idle(&self) -> bool {
        self.inbox.is_empty()
            && self.adopt_inbox.is_empty()
            && self.stage.is_empty()
            && self.pending.is_none()
            && self
                .engine
                .as_ref()
                .map(|e| !e.has_work())
                .unwrap_or(true)
    }
}

/// Output of a fleet simulation.
pub struct FleetOutput {
    pub recorder: MetricsRecorder,
    /// Applied policy actions with their issue times (Hold is not logged).
    pub actions: Vec<(f64, FleetAction)>,
    /// Completed per-replica scaling transitions, in completion order.
    pub scaling_events: Vec<ScalingOutcome>,
    /// Whole-replica cold boots issued (0 = every burst was absorbed
    /// vertically).
    pub cold_boots: usize,
    /// Unpark boot times, in issue order: (issue time, boot seconds).
    /// DRAM-warm methods land seconds here; disk-cold park policies pay
    /// cold-boot-class waits.
    pub unpark_boots: Vec<(f64, f64)>,
    /// (time, serving devices) timeline across the fleet.
    pub device_timeline: Vec<(f64, usize)>,
    pub end_time: f64,
    /// Replicas alive (not retired) at the end.
    pub final_replicas: usize,
    /// Requests never served because the run hit its hard stop with a
    /// backlog. Non-zero means SLO-attainment figures are optimistic:
    /// unserved requests are absent from the attainment denominator, so
    /// compare policies on the same trace only when this is 0.
    pub truncated: usize,
    /// In-flight KV handoff tally across every replica switchover.
    pub handoff: KvHandoffStats,
    /// Prefill→decode pool handoff tally (disaggregated fleets only;
    /// all-zero for unified fleets). `recompute_tokens == 0` is the
    /// zero-recompute happy path: every handed-off sequence's KV
    /// crossed the fabric instead of being re-prefilled.
    pub pool_handoff: KvHandoffStats,
    /// Structured event trace of the run across all replicas (the record
    /// the [`crate::chaos::invariants`] checkers run over).
    pub trace: Trace,
    /// FNV-1a digest folded incrementally over every state transition of
    /// the run (engine steps, policy ticks, fleet actions, the full event
    /// trace). Two runs with the same seed and configuration must produce
    /// the same digest — `rust/tests/determinism.rs` enforces this.
    pub state_hash: u64,
    /// Telemetry registry for the run (time series sampled at every
    /// policy tick, scaling-event span timelines, counters/histograms).
    /// `Some` only when [`FleetSim::obs`] was set; never folded into
    /// `state_hash`.
    pub telemetry: Option<Telemetry>,
}

impl FleetOutput {
    /// Count of actions matching a predicate (test/report convenience).
    pub fn count_actions(&self, f: impl Fn(&FleetAction) -> bool) -> usize {
        self.actions.iter().filter(|(_, a)| f(a)).count()
    }

    /// Device-seconds of serving capacity held over the run: the
    /// integral of the device timeline to `end_time` ("HBM-hours" in
    /// device-seconds). Park/unpark policies win exactly here — parked
    /// replicas hold zero devices.
    pub fn device_seconds(&self) -> f64 {
        let mut total = 0.0;
        for w in self.device_timeline.windows(2) {
            total += (w[1].0 - w[0].0).max(0.0) * w[0].1 as f64;
        }
        if let Some(&(t, d)) = self.device_timeline.last() {
            total += (self.end_time - t).max(0.0) * d as f64;
        }
        total
    }
}

/// The fleet-level serving simulator.
pub struct FleetSim {
    pub cost: CostModel,
    pub slo: SloConfig,
    pub hbm_per_device: u64,
    /// Routing/policy window (seconds).
    pub window: f64,
    pub max_batch: usize,
    pub router: Router,
    /// Chaos hook, shared with the replicas' scaling methods: fired-fault
    /// records drain into the run trace at each scale command. `None` =
    /// no fault injection.
    pub injector: Option<Rc<RefCell<FaultInjector>>>,
    /// Collect telemetry (per-replica gauge series at every policy tick,
    /// scaling-event spans, counters/histograms) into
    /// [`FleetOutput::telemetry`]. Determinism-neutral: sampling
    /// piggybacks on existing `PolicyTick` events and never folds into
    /// the state hash.
    pub obs: bool,
    /// Liveness beat period (seconds) for the self-rescheduling
    /// `Heartbeat` event.
    pub heartbeat_period: f64,
    /// Staleness past which a serving replica is suspect and evicted by
    /// the reconciler. Several beat periods wide, so a single swallowed
    /// beat never evicts.
    pub heartbeat_deadline: f64,
    /// Pool role of each initial replica by boot index; missing entries
    /// default to [`PoolRole::Unified`]. Any non-unified role turns the
    /// run into a prefill/decode disaggregated deployment: arrivals
    /// route to the prefill pool, and every freshly prefilled sequence
    /// hands its KV to a decode replica over a planned transfer leg.
    pub initial_roles: Vec<PoolRole>,
    /// Migration-byte budget each prefill→decode handoff plan is drawn
    /// under. An exhausted budget (like an injected `KvCopyFail`) falls
    /// back to recompute-on-decode — the request is never lost.
    pub handoff_budget_bytes: u64,
}

impl FleetSim {
    pub fn new(cost: CostModel, slo: SloConfig, router: Router) -> Self {
        FleetSim {
            cost,
            slo,
            hbm_per_device: 64 << 30,
            window: 5.0,
            max_batch: 256,
            router,
            injector: None,
            obs: false,
            heartbeat_period: 2.5,
            heartbeat_deadline: 12.0,
            initial_roles: Vec::new(),
            handoff_budget_bytes: 8 << 30,
        }
    }

    /// Run the fleet until every arrival is served (bounded by
    /// `horizon * 2 + 600` seconds of simulated time).
    ///
    /// `factory` builds the scaling method for replica `i` — each replica
    /// needs its own simulated cluster, sized at least
    /// `policy.limits.replica_max` so vertical growth has somewhere to go.
    /// `initial_replicas` replicas of `policy.limits.replica_base` devices
    /// are booted before t = 0 (warm start, like the paper's experiments).
    pub fn run(
        &self,
        policy: &mut FleetPolicy,
        factory: &mut dyn FnMut(usize) -> Result<Box<dyn ScalingMethod>>,
        initial_replicas: usize,
        mut arrivals: Vec<Request>,
        horizon: f64,
    ) -> Result<FleetOutput> {
        let tp = self.cost.model.tp;
        let limits = policy.limits;
        if limits.replica_base % tp != 0 || limits.step % tp != 0 {
            bail!(
                "replica_base {} and step {} must be multiples of TP{tp}",
                limits.replica_base,
                limits.step
            );
        }
        if initial_replicas == 0 {
            bail!("fleet needs at least one initial replica");
        }
        let base_par = self.par(limits.replica_base)?;

        let mut replicas: Vec<Replica> = Vec::new();
        for i in 0..initial_replicas {
            let mut method = factory(i)?;
            method.boot(&base_par)?;
            let kv_factor = method.steady_kv_factor();
            let batch_factor = method.steady_batch_factor();
            let engine = build_engine(
                &self.cost,
                self.hbm_per_device,
                self.max_batch,
                &base_par,
                kv_factor,
                batch_factor,
            );
            replicas.push(Replica {
                id: i,
                method,
                engine: Some(engine),
                clock: SimClock::new(),
                current: base_par.clone(),
                inbox: VecDeque::new(),
                pending: None,
                ready_at: 0.0,
                draining: false,
                retired: false,
                parked: false,
                last_heartbeat: 0.0,
                kv_factor,
                batch_factor,
                role: self
                    .initial_roles
                    .get(i)
                    .copied()
                    .unwrap_or_default(),
                adopt_inbox: VecDeque::new(),
                stage: Vec::new(),
            });
        }

        arrivals.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let mut trace = Trace::new();
        let mut event_seq = 0usize;
        for r in &arrivals {
            trace.push(TraceEvent::Arrival {
                t: r.arrival,
                id: r.id,
                tokens: r.max_new_tokens,
            });
        }
        let mut next_arrival = 0usize;
        let mut recorder = MetricsRecorder::with_capacity(arrivals.len());
        let mut actions: Vec<(f64, FleetAction)> = Vec::new();
        let mut events: Vec<ScalingOutcome> = Vec::new();
        let mut handoff = KvHandoffStats::default();
        let mut pool_handoff = KvHandoffStats::default();
        let mut cold_boots = 0usize;
        let mut unpark_boots: Vec<(f64, f64)> = Vec::new();
        let serving0 = initial_replicas * limits.replica_base;
        let mut device_timeline = vec![(0.0, serving0)];
        let mut rr = 0usize;
        let hard_stop = horizon * 2.0 + 600.0;
        let mut shash = StateHash::new();
        let mut tel: Option<Telemetry> = if self.obs {
            Some(Telemetry::new())
        } else {
            None
        };

        // Seed the event spine: one `Route` marker per arrival plus the
        // first self-rescheduling `PolicyTick`. Route markers are seeded
        // before any tick, so an arrival landing exactly on a tick
        // boundary routes before the policy observes it.
        let mut queue = EventQueue::with_capacity(arrivals.len() + 1);
        for r in &arrivals {
            queue.push(r.arrival, FleetEvent::Route);
        }
        queue.push(self.window, FleetEvent::PolicyTick);
        queue.push(self.heartbeat_period, FleetEvent::Heartbeat);
        let reconciler = Reconciler::new(self.heartbeat_deadline);

        // Routing / policy scratch, reused across events so the hot path
        // stays allocation-free after warm-up. `prev_loads` keeps the
        // previous round's observation so a `StaleObservedState` fault
        // can hand the reconciler an old snapshot.
        let mut eligible: Vec<(usize, usize)> = Vec::new();
        let mut loads: Vec<ReplicaLoad> = Vec::new();
        let mut prev_loads: Vec<ReplicaLoad> = Vec::new();

        'sim: while let Some(ev) = queue.pop() {
            if ev.payload == FleetEvent::Route {
                // 1) Route every arrival due by this marker into a
                // replica inbox. Replica state only changes at
                // `PolicyTick`, so per-arrival routing here sees exactly
                // the state the old windowed loop saw at its boundary.
                self.route_due(
                    ev.at,
                    &arrivals,
                    &mut next_arrival,
                    &mut replicas,
                    &mut rr,
                    &mut eligible,
                )?;
                continue;
            }
            if ev.payload == FleetEvent::Heartbeat {
                // Liveness beats: every serving replica stamps its
                // `last_heartbeat`, unless the injector swallows the
                // beat (`HeartbeatLoss`). Parked and still-booting
                // replicas beat nothing — the reconciler exempts them.
                for rep in replicas.iter_mut() {
                    if rep.retired || rep.parked || rep.ready_at > ev.at {
                        continue;
                    }
                    let lost = self
                        .injector
                        .as_ref()
                        .map(|i| i.borrow_mut().on_heartbeat(rep.id))
                        .unwrap_or(false);
                    if lost {
                        trace.push(TraceEvent::HeartbeatMissed {
                            t: ev.at,
                            replica: rep.id,
                        });
                        if let Some(t) = tel.as_mut() {
                            t.inc("heartbeats_missed", 1);
                            t.spans.instant(
                                rep.id,
                                "heartbeat_missed",
                                ev.at,
                            );
                        }
                    } else {
                        rep.last_heartbeat = ev.at;
                    }
                }
                queue.push(
                    ev.at + self.heartbeat_period,
                    FleetEvent::Heartbeat,
                );
                continue;
            }

            // `PolicyTick`: advance the fleet to the tick boundary and
            // let the policy act on the window that just ended.
            let t_end = ev.at;
            let t_start = t_end - self.window;
            shash.fold_f64(t_end);

            // 2) Advance every replica to the tick boundary, then
            // drain each method's cross-tier journal into the trace
            // (with an allocator audit, so the conservation invariant
            // has an independent figure to reconcile against). A
            // disaggregated fleet advances its prefill pool first and
            // plans the window's prefill→decode handoff legs before the
            // decode pool steps, so a transfer that lands mid-window is
            // adopted inside the same tick.
            let disagg = replicas
                .iter()
                .any(|r| !r.retired && r.role == PoolRole::Prefill);
            if disagg {
                for rep in replicas.iter_mut() {
                    if rep.role != PoolRole::Prefill {
                        continue;
                    }
                    self.advance_replica(
                        rep,
                        t_end,
                        &mut recorder,
                        &mut events,
                        &mut handoff,
                        &mut pool_handoff,
                        &mut trace,
                        &mut shash,
                        tel.as_mut(),
                    )?;
                }
                self.plan_handoffs(
                    t_end,
                    &mut replicas,
                    &mut pool_handoff,
                    &mut trace,
                    &mut event_seq,
                    &mut shash,
                    tel.as_mut(),
                )?;
            }
            for rep in replicas.iter_mut() {
                if disagg && rep.role == PoolRole::Prefill {
                    continue;
                }
                self.advance_replica(
                    rep,
                    t_end,
                    &mut recorder,
                    &mut events,
                    &mut handoff,
                    &mut pool_handoff,
                    &mut trace,
                    &mut shash,
                    tel.as_mut(),
                )?;
            }
            for rep in replicas.iter_mut() {
                let shifts = rep.method.drain_tier_shifts();
                if !shifts.is_empty() {
                    for s in shifts {
                        trace.push(TraceEvent::TierShift {
                            t: t_end,
                            replica: rep.id,
                            tag: s.tag,
                            bytes: s.bytes,
                            from: s.from,
                            to: s.to,
                        });
                    }
                    trace.push(TraceEvent::TierAudit {
                        t: t_end,
                        replica: rep.id,
                        dram_bytes: rep.method.dram_resident_bytes(),
                    });
                }
            }

            // 3) Retire drained replicas and release their devices.
            for rep in replicas.iter_mut() {
                if rep.draining && !rep.retired && rep.is_idle() {
                    rep.retired = true;
                    rep.engine = None;
                }
            }

            // 4) Serving-capacity timeline.
            let serving_devices: usize = replicas
                .iter()
                .filter(|r| !r.retired && !r.parked && r.ready_at <= t_end)
                .map(|r| r.current.n_devices())
                .sum();
            if device_timeline
                .last()
                .map(|&(_, d)| d != serving_devices)
                .unwrap_or(true)
            {
                device_timeline.push((t_end, serving_devices));
            }

            // Telemetry snapshot at the tick boundary: per-replica gauge
            // series plus fleet-wide pool occupancy. Read-only over state
            // the tick already computed — nothing here feeds `shash`.
            if let Some(t) = tel.as_mut() {
                for rep in replicas.iter() {
                    if rep.retired {
                        continue;
                    }
                    let s = replica_gauges(
                        rep.engine.as_ref(),
                        rep.method.as_ref(),
                        rep.devices_reserved(),
                        rep.inbox.len(),
                        rep.parked,
                    );
                    t.sample_replica(t_end, rep.id, &s);
                }
                let live = replicas.iter().filter(|r| !r.retired).count();
                let reserved: usize =
                    replicas.iter().map(|r| r.devices_reserved()).sum();
                t.record_series("fleet/replicas_live", t_end, live as f64);
                t.record_series(
                    "fleet/devices_serving",
                    t_end,
                    serving_devices as f64,
                );
                t.record_series(
                    "pool/devices_reserved",
                    t_end,
                    reserved as f64,
                );
                t.record_series(
                    "pool/devices_free",
                    t_end,
                    limits.pool_devices.saturating_sub(reserved) as f64,
                );
            }

            // 5) Stop once the trace is fully served.
            if next_arrival >= arrivals.len()
                && replicas.iter().all(|r| r.retired || r.is_idle())
            {
                break 'sim;
            }
            if t_end >= hard_stop {
                break 'sim;
            }

            // 6) Reconcile round over the window that just ended: the
            // policy declares the desired spec, the reconciler diffs it
            // against the observed loads into idempotent steps, and
            // every step enacts behind a precondition guard (stale or
            // duplicate steps become traced no-ops).
            let attainment =
                recorder.attainment_by_arrival(t_start, t_end, &self.slo);
            loads.clear();
            loads.extend(
                replicas
                    .iter()
                    .filter(|r| !r.retired)
                    .map(|r| ReplicaLoad {
                        id: r.id,
                        role: r.role,
                        devices: r.devices_reserved(),
                        occupancy: r
                            .engine
                            .as_ref()
                            .map(|e| {
                                e.batcher.running_len() as f64
                                    / e.batcher.cfg.max_batch.max(1) as f64
                            })
                            .unwrap_or(0.0),
                        queue_depth: r.queue_depth(),
                        busy: !r.parked
                            && (r.pending.is_some() || r.ready_at > t_end),
                        booting: !r.parked && r.ready_at > t_end,
                        draining: r.draining,
                        parked: r.parked,
                        imbalance: r.method.placement_imbalance(),
                        // Boot completion counts as an implicit beat: a
                        // replica cannot have beaten before it was
                        // ready, and must not be evicted for that
                        // silence.
                        last_heartbeat: r.last_heartbeat.max(r.ready_at),
                    }),
            );
            for l in &loads {
                shash.fold_usize(l.id);
                shash.fold_usize(l.role as usize);
                shash.fold_usize(l.devices);
                shash.fold_f64(l.occupancy);
                shash.fold_usize(l.queue_depth);
                shash.fold_bool(l.busy);
                shash.fold_bool(l.booting);
                shash.fold_bool(l.draining);
                shash.fold_bool(l.parked);
                shash.fold_f64(l.imbalance);
                shash.fold_f64(l.last_heartbeat);
            }
            let reserved: usize =
                replicas.iter().map(|r| r.devices_reserved()).sum();
            let free = limits.pool_devices.saturating_sub(reserved);
            let spec = policy.decide(t_end, attainment, &loads, free);
            // Fold every explained decision into the trace (and thereby
            // the state hash). Unconditional — never gated on telemetry —
            // so the determinism-neutrality contract holds by
            // construction.
            for ex in policy.take_explains() {
                trace.push(TraceEvent::DecisionExplain {
                    t: ex.t,
                    pool: ex.pool,
                    serving: ex.serving,
                    attainment: ex.attainment,
                    occupancy: ex.occupancy,
                    queue: ex.queue,
                    bad_windows: ex.bad_windows,
                    good_windows: ex.good_windows,
                    cooling: ex.cooling,
                    rearmed: ex.rearmed,
                    reburst: ex.reburst,
                    decision: ex.decision,
                    action: ex.action,
                    vetoed: ex.vetoed,
                });
            }
            shash.fold_usize(spec.replicas.len());
            for s in &spec.replicas {
                shash.fold_usize(s.id);
                shash.fold_usize(s.role as usize);
                shash.fold_usize(s.devices);
                shash.fold_bool(s.parked);
            }
            shash.fold_bool(spec.rebalance.is_some());
            shash.fold_usize(spec.rebalance.unwrap_or(0));

            // Control-plane fault directives for this round; fault
            // records fired outside a scale command (swallowed beats,
            // the round directives themselves) drain into the trace
            // here so the convergence invariant can anchor on the last
            // fired fault.
            let round = self
                .injector
                .as_ref()
                .map(|i| i.borrow_mut().begin_round())
                .unwrap_or_default();
            if let Some(inj) = self.injector.as_ref() {
                for rec in inj.borrow_mut().take_fired() {
                    trace.push(TraceEvent::FaultFired {
                        t: t_end,
                        event: rec.event,
                        fault: rec.kind,
                    });
                }
            }
            shash.fold_bool(round.stale);
            shash.fold_bool(round.duplicate);

            // A `StaleObservedState` round reconciles against the
            // previous round's snapshot; the enactment guards keep the
            // resulting steps safe.
            let observed: &[ReplicaLoad] =
                if round.stale && !prev_loads.is_empty() {
                    &prev_loads
                } else {
                    &loads
                };
            let steps = reconciler.plan(&spec, observed, t_end);
            trace.push(TraceEvent::SpecDeclared {
                t: t_end,
                replicas: spec.replicas.len(),
                devices: spec.devices_total(),
                parked: spec.parked_count(),
                drift: steps.len(),
            });
            if let Some(t) = tel.as_mut() {
                t.record_series(
                    "fleet/spec_drift",
                    t_end,
                    steps.len() as f64,
                );
            }
            shash.fold_usize(steps.len());
            for s in &steps {
                match *s {
                    ReconcileStep::Resize { replica, to_devices } => {
                        shash.fold_usize(0);
                        shash.fold_usize(replica);
                        shash.fold_usize(to_devices);
                    }
                    ReconcileStep::Park { replica } => {
                        shash.fold_usize(1);
                        shash.fold_usize(replica);
                    }
                    ReconcileStep::Unpark { replica } => {
                        shash.fold_usize(2);
                        shash.fold_usize(replica);
                    }
                    ReconcileStep::Add { slot, devices } => {
                        shash.fold_usize(3);
                        shash.fold_usize(slot);
                        shash.fold_usize(devices);
                    }
                    ReconcileStep::Drain { replica } => {
                        shash.fold_usize(4);
                        shash.fold_usize(replica);
                    }
                    ReconcileStep::Rebalance { replica } => {
                        shash.fold_usize(5);
                        shash.fold_usize(replica);
                    }
                    ReconcileStep::Evict { replica } => {
                        shash.fold_usize(6);
                        shash.fold_usize(replica);
                    }
                }
            }

            // Enact. A `DuplicateCommand` round replays the whole step
            // batch a second time — the guards turn the replay into
            // traced no-ops, which is exactly what the fault tests.
            let passes = if round.duplicate { 2 } else { 1 };
            let mut added_slots: Vec<usize> = Vec::new();
            for pass in 0..passes {
                for step in &steps {
                    let applied = match *step {
                        ReconcileStep::Resize { replica, to_devices } => {
                            let to = to_devices;
                            let ok = replica < replicas.len() && {
                                let others: usize = replicas
                                    .iter()
                                    .filter(|r| r.id != replica)
                                    .map(|r| r.devices_reserved())
                                    .sum();
                                let rep = &replicas[replica];
                                !rep.retired
                                    && !rep.draining
                                    && !rep.parked
                                    && rep.pending.is_none()
                                    && rep.ready_at <= t_end
                                    && rep.current.n_devices() != to
                                    && others + rep.current.n_devices().max(to)
                                        <= limits.pool_devices
                            };
                            if ok {
                                let target = self.par(to)?;
                                let rep = &mut replicas[replica];
                                let from = rep.current.n_devices();
                                // Hand the replica's live block tables to
                                // the method so its KV-migration planner
                                // can carry them.
                                let outcome = match rep.engine.as_ref() {
                                    Some(e) => rep.method.scale_with_kv(
                                        &target,
                                        &KvSnapshot::capture(
                                            &e.kv,
                                            &rep.current,
                                        ),
                                    )?,
                                    None => rep.method.scale(&target)?,
                                };
                                let evn = event_seq;
                                event_seq += 1;
                                log_command(
                                    &mut trace,
                                    tel.as_mut(),
                                    replica,
                                    self.injector.as_ref(),
                                    t_end,
                                    evn,
                                    from,
                                    &outcome,
                                );
                                let paused = begin_transition_on(
                                    &outcome,
                                    rep.engine.as_mut(),
                                    &mut trace,
                                    t_end,
                                    evn,
                                );
                                rep.pending = Some(PendingScale::new(
                                    outcome, t_end, evn, paused,
                                ));
                                let act = if to > from {
                                    FleetAction::VerticalUp {
                                        replica,
                                        to_devices: to,
                                    }
                                } else {
                                    FleetAction::VerticalDown {
                                        replica,
                                        to_devices: to,
                                    }
                                };
                                actions.push((t_end, act));
                            }
                            ok
                        }
                        ReconcileStep::Park { replica } => {
                            let mut ok = false;
                            if replica < replicas.len()
                                && !replicas[replica].retired
                                && !replicas[replica].draining
                                && !replicas[replica].parked
                            {
                                // Only an idle replica parks (in-flight
                                // work or a mid-scale transition vetoes
                                // it here).
                                let rep = &mut replicas[replica];
                                let idle = rep.inbox.is_empty()
                                    && rep.adopt_inbox.is_empty()
                                    && rep.stage.is_empty()
                                    && rep.pending.is_none()
                                    && rep
                                        .engine
                                        .as_ref()
                                        .map(|e| !e.has_work())
                                        .unwrap_or(false);
                                if idle
                                    && matches!(rep.method.park()?, Some(_))
                                {
                                    // d2h staging runs in the background —
                                    // the replica already left the
                                    // rotation.
                                    rep.engine = None;
                                    rep.parked = true;
                                    if let Some(t) = tel.as_mut() {
                                        t.inc("parks", 1);
                                        t.spans.begin(
                                            replica, "parked", t_end,
                                        );
                                    }
                                    actions.push((
                                        t_end,
                                        FleetAction::Park { replica },
                                    ));
                                    ok = true;
                                } else if pass == 0 {
                                    // Vetoed (in-flight work raced the
                                    // policy's snapshot): hand the
                                    // consumed Down trigger and the
                                    // replica cooldown back so parking
                                    // retries next window.
                                    policy.clear_event(replica);
                                    policy
                                        .estimator
                                        .refund(ScaleDecision::Down);
                                }
                            }
                            ok
                        }
                        ReconcileStep::Unpark { replica } => {
                            let mut ok = false;
                            if replica < replicas.len() {
                                // Re-check the exact device footprint
                                // against the pool: the parked replica's
                                // devices went back to the budget at park
                                // and may have been granted away.
                                let reserved: usize = replicas
                                    .iter()
                                    .map(|r| r.devices_reserved())
                                    .sum();
                                let rep = &mut replicas[replica];
                                let fits = reserved
                                    + rep.current.n_devices()
                                    <= limits.pool_devices;
                                let was_parked = rep.parked;
                                let boot = if was_parked && fits {
                                    rep.method.unpark()?
                                } else {
                                    None
                                };
                                if let Some(boot_t) = boot {
                                    rep.parked = false;
                                    rep.engine = Some(build_engine(
                                        &self.cost,
                                        self.hbm_per_device,
                                        self.max_batch,
                                        &rep.current,
                                        rep.kv_factor,
                                        rep.batch_factor,
                                    ));
                                    rep.ready_at = t_end + boot_t;
                                    unpark_boots.push((t_end, boot_t));
                                    if let Some(t) = tel.as_mut() {
                                        t.inc("unparks", 1);
                                        t.spans.end(
                                            replica, "parked", t_end,
                                        );
                                        t.spans.span(
                                            replica,
                                            None,
                                            "unpark_boot",
                                            CAT_LIFECYCLE,
                                            t_end,
                                            t_end + boot_t,
                                        );
                                    }
                                    actions.push((
                                        t_end,
                                        FleetAction::Unpark { replica },
                                    ));
                                    ok = true;
                                } else if pass == 0 && was_parked {
                                    // Vetoed (pool exhausted): release
                                    // the cooldown so the wake-up
                                    // retries.
                                    policy.clear_event(replica);
                                }
                            }
                            ok
                        }
                        ReconcileStep::Add { slot, devices } => {
                            let reserved: usize = replicas
                                .iter()
                                .map(|r| r.devices_reserved())
                                .sum();
                            // `added_slots` makes a duplicated Add a
                            // no-op: the booted replica's id differs
                            // from the spec's placeholder slot, so the
                            // slot itself is the only reliable witness
                            // within the round.
                            let ok = !added_slots.contains(&slot)
                                && devices > 0
                                && reserved + devices
                                    <= limits.pool_devices;
                            if ok {
                                added_slots.push(slot);
                                let id = replicas.len();
                                let mut method = factory(id)?;
                                let par = self.par(devices)?;
                                let boot_t = method.boot(&par)?;
                                cold_boots += 1;
                                let kv_factor = method.steady_kv_factor();
                                let batch_factor =
                                    method.steady_batch_factor();
                                let engine = build_engine(
                                    &self.cost,
                                    self.hbm_per_device,
                                    self.max_batch,
                                    &par,
                                    kv_factor,
                                    batch_factor,
                                );
                                let clock = SimClock::new();
                                clock.advance_to(t_end);
                                replicas.push(Replica {
                                    id,
                                    method,
                                    engine: Some(engine),
                                    clock,
                                    current: par.clone(),
                                    inbox: VecDeque::new(),
                                    pending: None,
                                    ready_at: t_end + boot_t,
                                    draining: false,
                                    retired: false,
                                    parked: false,
                                    last_heartbeat: t_end,
                                    kv_factor,
                                    batch_factor,
                                    role: spec
                                        .slot(slot)
                                        .map(|s| s.role)
                                        .unwrap_or_default(),
                                    adopt_inbox: VecDeque::new(),
                                    stage: Vec::new(),
                                });
                                policy.note_event(id, t_end);
                                if let Some(t) = tel.as_mut() {
                                    t.inc("cold_boots", 1);
                                    t.spans.span(
                                        id,
                                        None,
                                        "cold_boot",
                                        CAT_LIFECYCLE,
                                        t_end,
                                        t_end + boot_t,
                                    );
                                }
                                actions
                                    .push((t_end, FleetAction::AddReplica));
                            }
                            ok
                        }
                        ReconcileStep::Drain { replica } => {
                            // Checked no-op on an already-draining (or
                            // retired, or parked) replica — draining was
                            // previously set unconditionally, silently
                            // re-draining under stale or duplicated
                            // commands.
                            let ok = replica < replicas.len() && {
                                let rep = &replicas[replica];
                                !rep.retired
                                    && !rep.draining
                                    && !rep.parked
                            };
                            if ok {
                                replicas[replica].draining = true;
                                if let Some(t) = tel.as_mut() {
                                    t.inc("drains", 1);
                                    t.spans.instant(
                                        replica, "drain", t_end,
                                    );
                                }
                                actions.push((
                                    t_end,
                                    FleetAction::DrainReplica { replica },
                                ));
                            }
                            ok
                        }
                        ReconcileStep::Rebalance { replica } => {
                            // Redistribution-only event: same devices,
                            // new expert placement. Methods without
                            // load-aware placement decline (None) and
                            // the step is a no-op; the replica's
                            // cooldown was still charged by the policy,
                            // which keeps a persistently declining
                            // method from being re-asked every window.
                            let mut ok = false;
                            if replica < replicas.len()
                                && !replicas[replica].retired
                                && !replicas[replica].draining
                                && !replicas[replica].parked
                                && replicas[replica].pending.is_none()
                                && replicas[replica].ready_at <= t_end
                            {
                                let rep = &mut replicas[replica];
                                if let Some(outcome) =
                                    rep.method.rebalance()?
                                {
                                    let evn = event_seq;
                                    event_seq += 1;
                                    log_command(
                                        &mut trace,
                                        tel.as_mut(),
                                        replica,
                                        self.injector.as_ref(),
                                        t_end,
                                        evn,
                                        rep.current.n_devices(),
                                        &outcome,
                                    );
                                    let paused = begin_transition_on(
                                        &outcome,
                                        rep.engine.as_mut(),
                                        &mut trace,
                                        t_end,
                                        evn,
                                    );
                                    rep.pending = Some(PendingScale::new(
                                        outcome, t_end, evn, paused,
                                    ));
                                    actions.push((
                                        t_end,
                                        FleetAction::Rebalance { replica },
                                    ));
                                    ok = true;
                                }
                            }
                            ok
                        }
                        ReconcileStep::Evict { replica } => {
                            let ok = replica < replicas.len()
                                && {
                                    let rep = &replicas[replica];
                                    !rep.retired
                                        && !rep.parked
                                        && rep.pending.is_none()
                                }
                                && replicas.iter().any(|r| {
                                    r.id != replica
                                        && !r.retired
                                        && !r.draining
                                        && !r.parked
                                        && r.engine.is_some()
                                });
                            if ok {
                                let mut orphans: Vec<Request> = Vec::new();
                                {
                                    let rep = &mut replicas[replica];
                                    while let Some(r) =
                                        rep.inbox.pop_front()
                                    {
                                        orphans.push(r);
                                    }
                                    // An in-flight handoff toward this
                                    // replica dies with it: disposition
                                    // it as a recompute so the planned
                                    // leg is never left dangling, then
                                    // re-home the request like any other
                                    // orphan. Staged (not-yet-planned)
                                    // prefill output just re-homes.
                                    while let Some((_, r)) =
                                        rep.adopt_inbox.pop_front()
                                    {
                                        trace.push(
                                            TraceEvent::HandoffDone {
                                                t: t_end,
                                                id: r.id,
                                                to_replica: replica,
                                                recompute: true,
                                            },
                                        );
                                        pool_handoff.recomputed += 1;
                                        pool_handoff.recompute_tokens +=
                                            r.prompt_len as u64;
                                        pool_handoff
                                            .lost_decode_tokens +=
                                            r.generated as u64;
                                        orphans.push(r);
                                    }
                                    for (_, r) in rep.stage.drain(..) {
                                        orphans.push(r);
                                    }
                                    if let Some(mut eng) = rep.engine.take()
                                    {
                                        let (running, waiting) =
                                            eng.drain();
                                        orphans.extend(running);
                                        orphans.extend(waiting);
                                    }
                                    rep.draining = false;
                                    rep.retired = true;
                                }
                                let requeued = orphans.len();
                                for r in orphans {
                                    // Restart-from-scratch re-homing:
                                    // the fresh request re-prefills and
                                    // generates its full budget, so
                                    // exactly-once finish and token
                                    // conservation survive the eviction.
                                    let mut fresh = Request::new(
                                        r.id,
                                        r.arrival,
                                        r.prompt_len,
                                        r.max_new_tokens,
                                    )
                                    .with_tenant(r.tenant);
                                    fresh.prompt_ids = r.prompt_ids;
                                    let target = replicas
                                        .iter()
                                        .filter(|c| {
                                            c.id != replica
                                                && !c.retired
                                                && !c.draining
                                                && !c.parked
                                                && c.engine.is_some()
                                        })
                                        .min_by_key(|c| {
                                            // Orphans restart from the
                                            // prompt, so in a disagg
                                            // fleet they re-home to a
                                            // prefill-capable replica
                                            // first.
                                            (
                                                c.role
                                                    == PoolRole::Decode,
                                                c.backlog(),
                                                c.id,
                                            )
                                        })
                                        .map(|c| c.id)
                                        .unwrap();
                                    replicas[target]
                                        .inbox
                                        .push_back(fresh);
                                }
                                trace.push(TraceEvent::ReplicaEvicted {
                                    t: t_end,
                                    replica,
                                    requeued,
                                });
                                if let Some(t) = tel.as_mut() {
                                    t.inc("evictions", 1);
                                    t.spans.instant(
                                        replica, "evicted", t_end,
                                    );
                                }
                            }
                            ok
                        }
                    };
                    trace.push(TraceEvent::ReconcileStep {
                        t: t_end,
                        replica: step.replica(),
                        step: step.describe(),
                        applied,
                    });
                    shash.fold_bool(applied);
                    if let Some(t) = tel.as_mut() {
                        t.inc("reconcile_steps", 1);
                        if !applied {
                            t.inc("reconcile_noops", 1);
                            t.spans.instant(
                                step.replica(),
                                "reconcile_noop",
                                t_end,
                            );
                        }
                    }
                }
            }
            prev_loads.clear();
            prev_loads.extend_from_slice(&loads);

            queue.push(t_end + self.window, FleetEvent::PolicyTick);
        }

        let end_time = replicas
            .iter()
            .map(|r| r.clock.now())
            .fold(0.0f64, f64::max);
        let truncated = arrivals.len().saturating_sub(recorder.count());
        shash.fold_u64(trace.state_hash());
        shash.fold_usize(recorder.count());
        if let Some(t) = tel.as_mut() {
            t.spans.finish(end_time);
            t.set_gauge("end_time_s", end_time);
            t.set_gauge("requests_completed", recorder.count() as f64);
            t.set_gauge(
                "replicas_final",
                replicas.iter().filter(|r| !r.retired).count() as f64,
            );
        }
        Ok(FleetOutput {
            recorder,
            actions,
            scaling_events: events,
            cold_boots,
            unpark_boots,
            device_timeline,
            end_time,
            final_replicas: replicas.iter().filter(|r| !r.retired).count(),
            truncated,
            handoff,
            pool_handoff,
            trace,
            state_hash: shash.value(),
            telemetry: tel,
        })
    }

    /// Route every not-yet-routed arrival due by `due` into a replica
    /// inbox (the `Route` event handler). `eligible` is caller-owned
    /// scratch, reused across calls so routing allocates nothing.
    fn route_due(
        &self,
        due: f64,
        arrivals: &[Request],
        next_arrival: &mut usize,
        replicas: &mut [Replica],
        rr: &mut usize,
        eligible: &mut Vec<(usize, usize)>,
    ) -> Result<()> {
        // In a disaggregated fleet, fresh arrivals only ever route to
        // prefill-capable replicas — the decode pool receives work via
        // KV handoff, not the front door.
        let disagg = replicas
            .iter()
            .any(|r| !r.retired && r.role == PoolRole::Prefill);
        while *next_arrival < arrivals.len()
            && arrivals[*next_arrival].arrival <= due
        {
            let r = arrivals[*next_arrival].clone();
            *next_arrival += 1;
            eligible.clear();
            eligible.extend(
                replicas
                    .iter()
                    .filter(|rep| {
                        !rep.retired
                            && !rep.draining
                            && rep.engine.is_some()
                            && rep.ready_at <= r.arrival
                            && (!disagg
                                || rep.role != PoolRole::Decode)
                    })
                    .map(|rep| (rep.id, rep.backlog())),
            );
            let target = if eligible.is_empty() {
                // Every replica is booting, draining, or parked: fall
                // back to any live one, else any non-retired (a parked
                // replica keeps its inbox — queued arrivals are the
                // policy's wake-up signal).
                replicas
                    .iter()
                    .find(|rep| {
                        !rep.retired
                            && rep.engine.is_some()
                            && (!disagg
                                || rep.role != PoolRole::Decode)
                    })
                    .or_else(|| {
                        replicas
                            .iter()
                            .find(|rep| !rep.retired && rep.engine.is_some())
                    })
                    .or_else(|| replicas.iter().find(|rep| !rep.retired))
                    .map(|rep| rep.id)
            } else {
                Some(self.router.pick(rr, r.tenant, eligible))
            };
            match target {
                Some(id) => replicas[id].inbox.push_back(r),
                None => bail!("no live replica to route to"),
            }
        }
        Ok(())
    }

    /// Plan this window's prefill→decode KV handoff legs (tick-time
    /// bookkeeping of a disaggregated fleet). Every sequence staged by a
    /// prefill replica is assigned a decode replica, its transfer is
    /// planned through the same KV-migration planner the vertical path
    /// uses (audited for block conservation and byte budget), and the
    /// request is posted to the target's adoption inbox with a delivery
    /// time that started at prefill completion. A `KvCopyFail` on any
    /// fabric leg, or a planner verdict of `Recompute` (budget
    /// exhaustion), aborts the transfer: the request restarts on the
    /// decode replica from its prompt — dispositioned immediately, never
    /// lost.
    #[allow(clippy::too_many_arguments)]
    fn plan_handoffs(
        &self,
        t_end: f64,
        replicas: &mut [Replica],
        pool_handoff: &mut KvHandoffStats,
        trace: &mut Trace,
        event_seq: &mut usize,
        shash: &mut StateHash,
        mut tel: Option<&mut Telemetry>,
    ) -> Result<()> {
        // Planning-only device-id namespace: each replica numbers its
        // local devices from 0, so a decode replica's ids collide with
        // the prefill replica's and `surviving_ranks` would see phantom
        // survivors (turning a cross-replica copy into a free remap).
        // Offsetting the destination ids guarantees disjoint namespaces;
        // transfer *time* always uses the real destination config.
        const DISAGG_NS: usize = 1 << 20;

        let mut staged: Vec<(usize, ParallelConfig, usize, f64, Request)> =
            Vec::new();
        for rep in replicas.iter_mut() {
            if rep.role != PoolRole::Prefill || rep.stage.is_empty() {
                continue;
            }
            let bt = rep
                .engine
                .as_ref()
                .map(|e| e.kv.block_tokens())
                .unwrap_or(16);
            for (t_done, r) in rep.stage.drain(..) {
                staged.push((rep.id, rep.current.clone(), bt, t_done, r));
            }
        }

        for (src, src_par, bt, t_done, r) in staged {
            // Least-loaded live decode replica; with no decode pool left
            // the sequence re-adopts where it prefilled (a self-handoff:
            // every block remaps in place, zero bytes cross the fabric).
            let dst = replicas
                .iter()
                .filter(|c| {
                    c.role == PoolRole::Decode
                        && !c.retired
                        && !c.draining
                        && !c.parked
                        && c.engine.is_some()
                })
                .min_by_key(|c| (c.backlog(), c.id))
                .map(|c| c.id)
                .unwrap_or(src);
            let to_real = replicas[dst].current.clone();
            let to_plan = if dst == src {
                src_par.clone()
            } else {
                ParallelConfig::standard(
                    to_real.dp,
                    to_real.tp,
                    to_real
                        .devices
                        .iter()
                        .map(|d| d + (dst + 1) * DISAGG_NS)
                        .collect(),
                )?
            };
            let len = r.current_len();
            let snap = KvSnapshot {
                block_tokens: bt,
                seqs: vec![KvSeq {
                    id: r.id,
                    len,
                    blocks: len.div_ceil(bt),
                    home_rank: home_rank(r.id, src_par.dp),
                }],
                from: src_par,
            };
            let (plan, _) = plan_kv_migration(
                &snap,
                &to_plan,
                &self.cost,
                self.handoff_budget_bytes,
            );
            let legs = plan.transfers();

            // Every fabric leg consults the injector; a fired
            // `KvCopyFail` aborts the whole transfer (the partial copy
            // is dropped — the planner's audit still balances, the
            // request falls back to recompute).
            let mut aborted = false;
            if let Some(inj) = self.injector.as_ref() {
                let mut inj = inj.borrow_mut();
                inj.begin_event();
                for &(s, d, _) in &legs {
                    if inj.on_kv_leg(s, d).is_some() {
                        aborted = true;
                        break;
                    }
                }
            }

            let evn = *event_seq;
            *event_seq += 1;
            trace.push(TraceEvent::HandoffPlanned {
                t: t_end,
                id: r.id,
                from_replica: src,
                to_replica: dst,
                bytes: plan.copied_bytes(),
                legs: legs.len(),
            });
            trace.push(TraceEvent::PlanAudited {
                t: t_end,
                event: evn,
                audit: PlanAudit {
                    snapshot_blocks: snap.total_blocks(),
                    kv_remapped_blocks: plan.remapped_blocks(),
                    kv_copied_blocks: plan.copied_blocks(),
                    kv_freed_blocks: plan.freed_blocks(),
                    kv_copied_bytes: plan.copied_bytes(),
                    migration_budget_bytes: self.handoff_budget_bytes,
                    expert_migration_bytes: 0,
                },
            });
            shash.fold_u64(r.id);
            shash.fold_usize(src);
            shash.fold_usize(dst);
            shash.fold_u64(plan.copied_bytes());
            shash.fold_usize(legs.len());
            shash.fold_bool(aborted);
            if let Some(t) = tel.as_deref_mut() {
                t.inc("handoffs_planned", 1);
                t.inc("handoff_bytes", plan.copied_bytes());
            }

            let transferable = !aborted && plan.recompute_tokens() == 0;
            if transferable {
                // KV lands after the P2P time for this sequence's bytes
                // (clock started at prefill completion, not at the
                // tick); the decode replica admits and adopts at the
                // delivery time. A zero-byte self-handoff lands at once.
                let due = if plan.copied_bytes() == 0 {
                    t_done
                } else {
                    t_done + self.cost.kv_transfer_time(&to_real, len)
                };
                replicas[dst].adopt_inbox.push_back((due, r));
            } else {
                // Recompute-on-decode: disposition now, restart the
                // request from its prompt on the decode replica.
                trace.push(TraceEvent::HandoffDone {
                    t: t_end,
                    id: r.id,
                    to_replica: dst,
                    recompute: true,
                });
                pool_handoff.recomputed += 1;
                pool_handoff.recompute_tokens += r.prompt_len as u64;
                pool_handoff.lost_decode_tokens += r.generated as u64;
                if let Some(t) = tel.as_deref_mut() {
                    t.inc("handoff_recomputes", 1);
                }
                let mut fresh = Request::new(
                    r.id,
                    r.arrival,
                    r.prompt_len,
                    r.max_new_tokens,
                )
                .with_tenant(r.tenant);
                fresh.prompt_ids = r.prompt_ids;
                replicas[dst].inbox.push_back(fresh);
            }
        }
        Ok(())
    }

    /// Standard layout over `n` local devices of one replica's cluster.
    fn par(&self, n: usize) -> Result<ParallelConfig> {
        let tp = self.cost.model.tp;
        if n == 0 || n % tp != 0 {
            bail!("{n} devices not divisible by TP{tp}");
        }
        Ok(ParallelConfig::standard(n / tp, tp, (0..n).collect())?)
    }

    /// Advance one replica's discrete-event loop to `t_end`, completing
    /// any pending transition, enforcing downtime/intake windows, and
    /// recording finished requests. Mirrors [`super::ServingSim::run`]'s
    /// inner loop at per-replica scope. Every executed engine step folds
    /// into `shash` so the fleet digest covers per-replica trajectories.
    #[allow(clippy::too_many_arguments)]
    fn advance_replica(
        &self,
        rep: &mut Replica,
        t_end: f64,
        recorder: &mut MetricsRecorder,
        events: &mut Vec<ScalingOutcome>,
        handoff: &mut KvHandoffStats,
        pool_handoff: &mut KvHandoffStats,
        trace: &mut Trace,
        shash: &mut StateHash,
        mut tel: Option<&mut Telemetry>,
    ) -> Result<()> {
        if rep.retired || rep.parked {
            // Parked replicas hold no devices and step nothing; their
            // inbox queues until the policy unparks them.
            rep.clock.advance_to(t_end);
            return Ok(());
        }
        loop {
            let now = rep.clock.now();
            if now >= t_end {
                break;
            }
            if now < rep.ready_at {
                rep.clock.advance_to(rep.ready_at.min(t_end));
                continue;
            }

            // Complete a pending transition: switch over to a fresh engine
            // for the new configuration, migrating in-flight work. An
            // aborted (rolled-back) event instead keeps the old engine:
            // intake reopens and suspended sequences resume in place.
            if let Some(p) = &rep.pending {
                if now >= p.started + p.outcome.ready_after {
                    let p = rep.pending.take().unwrap();
                    if let Some(t) = tel.as_deref_mut() {
                        if p.outcome.aborted.is_some() {
                            t.inc("scale_rollbacks", 1);
                        } else {
                            t.inc("scale_completions", 1);
                        }
                    }
                    if let Some(new_parallel) = complete_pending(
                        &self.cost,
                        self.hbm_per_device,
                        self.max_batch,
                        p,
                        &mut rep.engine,
                        rep.kv_factor,
                        rep.batch_factor,
                        handoff,
                        events,
                        trace,
                        now,
                    ) {
                        rep.current = new_parallel;
                    }
                    continue;
                }
            }

            // Downtime / intake windows of the in-flight transition.
            let in_downtime = rep
                .pending
                .as_ref()
                .map(|p| p.outcome.in_downtime(p.started, now))
                .unwrap_or(false);
            let intake_open = rep
                .pending
                .as_ref()
                .map(|p| p.outcome.intake_open(p.started, now))
                .unwrap_or(true);

            if let Some(eng) = rep.engine.as_mut() {
                if let Some(p) = rep.pending.as_mut() {
                    sync_pause_window(p, eng, intake_open, trace, now);
                }
                if intake_open && !in_downtime {
                    while rep
                        .inbox
                        .front()
                        .map(|r| r.arrival <= now)
                        .unwrap_or(false)
                    {
                        eng.submit(rep.inbox.pop_front().unwrap());
                    }
                    // Deliver due prefill→decode handoffs: admit the
                    // transferred KV and adopt the request with its
                    // decode progress intact, or disposition it as a
                    // recompute (fresh re-prefill here) when the pool
                    // cannot take the sequence.
                    let mut i = 0;
                    while i < rep.adopt_inbox.len() {
                        if rep.adopt_inbox[i].0 > now {
                            i += 1;
                            continue;
                        }
                        let (_, r) = rep.adopt_inbox.remove(i).unwrap();
                        if eng.kv.can_admit(r.total_tokens())
                            && eng.kv.admit(r.id, r.current_len()).is_ok()
                        {
                            trace.push(TraceEvent::HandoffDone {
                                t: now,
                                id: r.id,
                                to_replica: rep.id,
                                recompute: false,
                            });
                            pool_handoff.copied += 1;
                            pool_handoff.adopted_tokens +=
                                r.generated as u64;
                            if let Some(t) = tel.as_deref_mut() {
                                t.inc("handoff_adoptions", 1);
                            }
                            eng.batcher_adopt(r);
                        } else {
                            trace.push(TraceEvent::HandoffDone {
                                t: now,
                                id: r.id,
                                to_replica: rep.id,
                                recompute: true,
                            });
                            pool_handoff.recomputed += 1;
                            pool_handoff.recompute_tokens +=
                                r.prompt_len as u64;
                            pool_handoff.lost_decode_tokens +=
                                r.generated as u64;
                            if let Some(t) = tel.as_deref_mut() {
                                t.inc("handoff_recomputes", 1);
                            }
                            let mut fresh = Request::new(
                                r.id,
                                r.arrival,
                                r.prompt_len,
                                r.max_new_tokens,
                            )
                            .with_tenant(r.tenant);
                            fresh.prompt_ids = r.prompt_ids;
                            eng.submit(fresh);
                        }
                    }
                }
            }

            let stepped = if in_downtime {
                false
            } else if let Some(eng) = rep.engine.as_mut() {
                if eng.has_work() {
                    let out = eng.step(&rep.clock)?;
                    shash.fold_usize(rep.id);
                    shash.fold_usize(match out.kind {
                        StepKind::Prefill => 0,
                        StepKind::Decode => 1,
                        StepKind::Idle => 2,
                    });
                    shash.fold_f64(out.duration);
                    shash.fold_usize(out.preempted);
                    shash.fold_usize(eng.kv.used_blocks());
                    shash.fold_usize(eng.batcher.running_len());
                    shash.fold_usize(eng.batcher.queue_len());
                    for r in out.finished {
                        trace.push(TraceEvent::Finished {
                            t: rep.clock.now(),
                            id: r.id,
                            tokens: r.generated,
                        });
                        if let Some(t) = tel.as_deref_mut() {
                            t.inc("requests_finished", 1);
                            t.inc("tokens_generated", r.generated as u64);
                            if let Some(v) = r.ttft() {
                                t.observe("ttft_s", v);
                            }
                            if let Some(v) = r.tpot() {
                                t.observe("tpot_s", v);
                            }
                        }
                        recorder.record(&r);
                    }
                    // A prefill replica never decodes: pull every
                    // sequence that just produced its first token out of
                    // the running batch (KV released here) and stage it
                    // for handoff planning at the tick.
                    if rep.role == PoolRole::Prefill {
                        let now2 = rep.clock.now();
                        for r in
                            eng.batcher.take_decoding(&mut eng.kv)
                        {
                            rep.stage.push((now2, r));
                        }
                    }
                    !matches!(out.kind, StepKind::Idle)
                } else {
                    false
                }
            } else {
                false
            };

            if !stepped {
                // Jump to the next event strictly after `now` (bounded by
                // the window boundary, where the fleet loop takes over).
                let mut next = t_end;
                let mut consider = |t: f64| {
                    if t > now && t < next {
                        next = t;
                    }
                };
                if let Some(p) = &rep.pending {
                    consider(p.started + p.outcome.ready_after);
                    if let Some((_, b)) = p.outcome.downtime {
                        consider(p.started + b);
                    }
                    if let Some((_, b)) = p.outcome.intake_pause {
                        consider(p.started + b);
                    }
                }
                if let Some(r) = rep.inbox.front() {
                    consider(r.arrival);
                }
                for (due, _) in &rep.adopt_inbox {
                    consider(*due);
                }
                rep.clock.advance_to(next + 1e-9);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::{check_all, FaultEntry, FaultKind, FaultPlan};
    use crate::config::model::dsv2_lite;
    use crate::config::SloConfig;
    use crate::coordinator::policy::{FleetLimits, PolicyMode};
    use crate::device::Timings;
    use crate::experiments::common::{elastic_with_opts, KV_BYTES};
    use crate::hmm::control::HmmOptions;
    use crate::imm::manager::ImmOptions;
    use crate::scaling::ColdRestart;
    use crate::workload::{RateProfile, WorkloadGen, WorkloadSpec};
    use std::cell::RefCell;
    use std::rc::Rc;

    fn fleet(router: Router) -> FleetSim {
        FleetSim::new(
            CostModel::new(dsv2_lite(), Timings::cloudmatrix()),
            SloConfig::scale_up_demo(),
            router,
        )
    }

    fn limits(replica_max: usize) -> FleetLimits {
        FleetLimits {
            pool_devices: 12,
            replica_base: 2,
            replica_max,
            step: 2,
            min_replicas: 2,
        }
    }

    fn fast_policy(mode: PolicyMode, replica_max: usize) -> FleetPolicy {
        let mut p = FleetPolicy::new(
            mode,
            limits(replica_max),
            SloConfig::scale_up_demo(),
        );
        p.estimator.up_patience = 1;
        p.estimator.cooldown = 10.0;
        p.replica_cooldown = 10.0;
        p
    }

    /// Factory: each replica gets its own simulated cluster, big enough
    /// for the vertical ceiling.
    fn elastic_factory(
        replica_max: usize,
    ) -> impl FnMut(usize) -> Result<Box<dyn ScalingMethod>> {
        move |_i| {
            Ok(Box::new(elastic_with_opts(
                &dsv2_lite(),
                replica_max,
                HmmOptions::default(),
                ImmOptions::default(),
            )) as Box<dyn ScalingMethod>)
        }
    }

    fn cold_factory(
    ) -> impl FnMut(usize) -> Result<Box<dyn ScalingMethod>> {
        move |_i| {
            let c = Rc::new(RefCell::new(
                crate::device::Cluster::cloudmatrix(4),
            ));
            Ok(Box::new(ColdRestart::new(c, dsv2_lite(), KV_BYTES))
                as Box<dyn ScalingMethod>)
        }
    }

    fn burst_trace(horizon: f64) -> Vec<Request> {
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 2000,
            decode_min: 100,
            decode_max: 150,
            profile: RateProfile::Burst {
                base: 0.8,
                factor: 10.0,
                start: 60.0,
                len: 60.0,
            },
            seed: 17,
        });
        g.arrivals_until(horizon)
    }

    #[test]
    fn router_pick_policies() {
        let eligible = [(0usize, 5usize), (1, 1), (2, 9)];
        let mut rr = 0;
        assert_eq!(Router::RoundRobin.pick(&mut rr, 0, &eligible), 0);
        assert_eq!(Router::RoundRobin.pick(&mut rr, 0, &eligible), 1);
        assert_eq!(Router::RoundRobin.pick(&mut rr, 0, &eligible), 2);
        assert_eq!(Router::RoundRobin.pick(&mut rr, 0, &eligible), 0);
        assert_eq!(
            Router::JoinShortestQueue.pick(&mut rr, 0, &eligible),
            1
        );
        assert_eq!(Router::SessionAffinity.pick(&mut rr, 4, &eligible), 1);
        // Same tenant, same replica.
        assert_eq!(Router::SessionAffinity.pick(&mut rr, 4, &eligible), 1);
    }

    #[test]
    fn steady_fleet_serves_everything() {
        let sim = fleet(Router::JoinShortestQueue);
        let mut policy = fast_policy(PolicyMode::Hybrid, 8);
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 2000,
            decode_min: 100,
            decode_max: 150,
            profile: RateProfile::Fixed(0.8),
            seed: 5,
        });
        let arrivals = g.arrivals_until(90.0);
        let n = arrivals.len();
        let out = sim
            .run(&mut policy, &mut elastic_factory(8), 2, arrivals, 90.0)
            .unwrap();
        assert_eq!(out.recorder.count(), n);
        let att = out.recorder.attainment_by_arrival(0.0, 90.0, &sim.slo);
        assert!(att > 0.9, "steady fleet attainment {att}");
    }

    /// Telemetry is determinism-neutral at fleet scope: enabling it
    /// leaves the state hash bit-identical, and the registry carries
    /// per-replica gauge series, pool series, and span timelines for the
    /// burst's vertical scaling events.
    #[test]
    fn fleet_telemetry_is_determinism_neutral() {
        let horizon = 240.0;
        let run = |obs: bool| {
            let mut sim = fleet(Router::JoinShortestQueue);
            sim.obs = obs;
            let mut policy = fast_policy(PolicyMode::Hybrid, 8);
            sim.run(
                &mut policy,
                &mut elastic_factory(8),
                2,
                burst_trace(horizon),
                horizon,
            )
            .unwrap()
        };
        let off = run(false);
        let on = run(true);
        assert_eq!(
            off.state_hash, on.state_hash,
            "telemetry must not perturb the simulation"
        );
        assert!(off.telemetry.is_none());
        let tel = on.telemetry.unwrap();
        assert!(tel.counter("scale_commands") >= 1);
        assert_eq!(
            tel.counter("scale_completions"),
            tel.counter("scale_commands"),
            "every commanded event completes on this trace"
        );
        assert_eq!(
            tel.counter("requests_finished"),
            on.recorder.count() as u64
        );
        assert!(tel.histogram("ttft_s").map(|h| h.count()).unwrap_or(0) > 0);
        for r in 0..2 {
            for g in ["queue_depth", "hbm_used_bytes", "devices_active"] {
                let name = format!("replica{r}/{g}");
                assert!(
                    tel.series(&name).is_some(),
                    "missing series {name}"
                );
            }
        }
        assert!(tel.series("fleet/devices_serving").is_some());
        assert!(tel.series("pool/devices_free").is_some());
        // The vertical events carry phase timelines.
        assert!(tel
            .spans
            .spans()
            .iter()
            .any(|s| s.name.contains("intake_pause")));
    }

    /// Acceptance: under a flash crowd (Burst x10), the hybrid policy with
    /// ElasticMoE replicas absorbs the burst with vertical steps — no
    /// replica cold-boot — and beats a horizontal-only fleet on the same
    /// trace.
    #[test]
    fn flash_crowd_hybrid_beats_horizontal_only() {
        let horizon = 240.0;

        let sim = fleet(Router::JoinShortestQueue);
        let mut hybrid = fast_policy(PolicyMode::Hybrid, 8);
        let out_h = sim
            .run(
                &mut hybrid,
                &mut elastic_factory(8),
                2,
                burst_trace(horizon),
                horizon,
            )
            .unwrap();

        let mut horiz = fast_policy(PolicyMode::HorizontalOnly, 8);
        let out_x = sim
            .run(
                &mut horiz,
                &mut cold_factory(),
                2,
                burst_trace(horizon),
                horizon,
            )
            .unwrap();

        // Both runs fully drained: the attainment comparison is on the
        // complete trace, not a truncated one.
        assert_eq!(out_h.truncated, 0);
        assert_eq!(out_x.truncated, 0);
        // Vertical absorption: no cold boots, at least one vertical step.
        assert_eq!(out_h.cold_boots, 0, "hybrid must not cold-boot");
        let verticals = out_h.count_actions(|a| {
            matches!(a, FleetAction::VerticalUp { .. })
        });
        assert!(verticals >= 1, "burst must trigger vertical scaling");
        // The horizontal-only fleet had to cold-boot whole replicas.
        assert!(out_x.cold_boots >= 1, "horizontal must add a replica");

        let att_h =
            out_h.recorder.attainment_by_arrival(0.0, horizon, &sim.slo);
        let att_x =
            out_x.recorder.attainment_by_arrival(0.0, horizon, &sim.slo);
        assert!(
            att_h > att_x,
            "hybrid {att_h} must strictly beat horizontal-only {att_x}"
        );
    }

    /// Acceptance: a sustained ramp exhausts the per-replica vertical
    /// envelope and provably adds a whole replica.
    #[test]
    fn sustained_ramp_adds_a_replica() {
        let sim = fleet(Router::JoinShortestQueue);
        // Tight vertical ceiling: one step and a replica is maxed out.
        let mut policy = fast_policy(PolicyMode::Hybrid, 4);
        policy.limits.min_replicas = 1;
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 2000,
            decode_min: 100,
            decode_max: 150,
            profile: RateProfile::Ramp {
                from: 0.3,
                to: 6.0,
                duration: 150.0,
            },
            seed: 29,
        });
        let horizon = 200.0;
        let arrivals = g.arrivals_until(horizon);
        let out = sim
            .run(&mut policy, &mut elastic_factory(4), 1, arrivals, horizon)
            .unwrap();
        let verticals = out.count_actions(|a| {
            matches!(a, FleetAction::VerticalUp { .. })
        });
        let adds = out
            .count_actions(|a| matches!(a, FleetAction::AddReplica));
        assert!(
            verticals >= 1,
            "ramp should scale vertically first ({:?})",
            out.actions
        );
        assert!(
            adds >= 1,
            "sustained ramp must add a replica ({:?})",
            out.actions
        );
        assert!(out.cold_boots >= 1);
        assert!(out.final_replicas >= 2);
    }

    /// End-to-end redistribution-only event: replicas whose expert
    /// popularity is skewed (stats fed into the HMM before boot, as a
    /// routing-aware engine would) get a `Rebalance` action from the
    /// policy during quiet windows, execute it through the full scaling
    /// choreography, and come out balanced — same device count, no
    /// downtime, trace fully served.
    #[test]
    fn skewed_replicas_rebalance_through_the_fleet_loop() {
        let sim = fleet(Router::JoinShortestQueue);
        let mut policy = fast_policy(PolicyMode::Hybrid, 8);
        // Light steady traffic: estimator must hold. Disable down-scaling
        // so low occupancy cannot preempt the quiet-window rebalance.
        policy.estimator.down_occupancy = 0.0;
        let mut factory = |_i: usize| -> Result<Box<dyn ScalingMethod>> {
            let mut e = elastic_with_opts(
                &dsv2_lite(),
                8,
                HmmOptions::default(),
                ImmOptions::default(),
            );
            e.hmm.placement =
                crate::placement::PlacementConfig::load_aware();
            // Hot experts co-located on EP rank 1 of the 2-device boot
            // placement (e % 2 == 1): one device carries all the load.
            let n = e.hmm.model.n_experts as usize;
            let mut tokens_per_expert = vec![Vec::new(); n];
            for hot in [1usize, 3, 5, 7] {
                tokens_per_expert[hot] = (0..12).collect();
            }
            let routing = crate::engine::moe::Routing {
                n_tokens: 48,
                n_experts: n,
                tokens_per_expert,
            };
            for layer in 0..e.hmm.model.n_layers as usize {
                e.hmm.record_routing(layer, &routing);
            }
            Ok(Box::new(e) as Box<dyn ScalingMethod>)
        };
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 1000,
            decode_min: 20,
            decode_max: 40,
            profile: RateProfile::Fixed(0.3),
            seed: 3,
        });
        let horizon = 120.0;
        let arrivals = g.arrivals_until(horizon);
        let n = arrivals.len();
        let out = sim
            .run(&mut policy, &mut factory, 2, arrivals, horizon)
            .unwrap();
        let rebalances = out
            .count_actions(|a| matches!(a, FleetAction::Rebalance { .. }));
        assert!(rebalances >= 1, "skew must trigger a rebalance: {:?}", out.actions);
        // Redistribution-only: no capacity change, no downtime.
        for ev in &out.scaling_events {
            assert_eq!(ev.new_parallel.n_devices(), 2);
            assert_eq!(ev.metrics.downtime, 0.0);
        }
        assert_eq!(out.cold_boots, 0);
        assert_eq!(out.recorder.count(), n, "trace fully served");
    }

    /// Regression for the stale/duplicate-enactment bugfix: a
    /// `DuplicateCommand` round replays the whole step batch, and every
    /// replayed step (resize on a mid-transition replica, park on a
    /// parked one, drain on an already-draining one, ...) must be a
    /// checked no-op with an `applied: false` trace mark — never a
    /// silent second mutation.
    #[test]
    fn duplicate_command_replay_is_a_checked_noop() {
        let horizon = 240.0;
        let run = |dup: bool| {
            let mut sim = fleet(Router::JoinShortestQueue);
            if dup {
                // Duplicate every reachable round.
                let plan = FaultPlan {
                    entries: (0..200)
                        .map(|r| FaultEntry {
                            event: r,
                            kind: FaultKind::DuplicateCommand,
                        })
                        .collect(),
                };
                sim.injector =
                    Some(Rc::new(RefCell::new(FaultInjector::new(plan))));
            }
            let mut policy = fast_policy(PolicyMode::Hybrid, 8);
            sim.run(
                &mut policy,
                &mut elastic_factory(8),
                2,
                burst_trace(horizon),
                horizon,
            )
            .unwrap()
        };
        let baseline = run(false);
        let out = run(true);
        // The replay changed nothing the first pass had not already
        // done: the applied-action log matches the fault-free run.
        assert_eq!(out.actions, baseline.actions);
        assert_eq!(out.recorder.count(), baseline.recorder.count());
        let count = |want: bool, tr: &Trace| {
            tr.events
                .iter()
                .filter(|e| {
                    matches!(
                        e,
                        TraceEvent::ReconcileStep { applied, .. }
                            if *applied == want
                    )
                })
                .count()
        };
        let applied = count(true, &out.trace);
        let noops = count(false, &out.trace);
        assert!(applied >= 1, "burst must plan real steps");
        assert!(
            noops >= applied,
            "every applied step must replay as a checked no-op \
             ({applied} applied, {noops} no-ops)"
        );
        assert_eq!(count(false, &baseline.trace), 0);
        let v = check_all(&out.trace);
        assert!(v.is_empty(), "{v:?}");
    }

    /// Heartbeat loss past the staleness deadline evicts the (false-)
    /// suspect replica, re-homes its queued and in-flight work, and
    /// re-plans the spec slot — with every request still finishing
    /// exactly once on its full token budget.
    #[test]
    fn heartbeat_loss_evicts_and_rehomes_exactly_once() {
        // Replica 0 goes silent from its 4th beat: 12 swallowed beats
        // (t = 12.5 .. 40) push staleness past the 12 s deadline while
        // the replica keeps serving.
        let plan = FaultPlan::single(
            4,
            FaultKind::HeartbeatLoss { replica: 0, beats: 12 },
        );
        let mut sim = fleet(Router::JoinShortestQueue);
        sim.injector =
            Some(Rc::new(RefCell::new(FaultInjector::new(plan))));
        let mut policy = fast_policy(PolicyMode::Hybrid, 8);
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 2000,
            decode_min: 100,
            decode_max: 150,
            profile: RateProfile::Fixed(0.8),
            seed: 5,
        });
        let arrivals = g.arrivals_until(90.0);
        let n = arrivals.len();
        let out = sim
            .run(&mut policy, &mut elastic_factory(8), 2, arrivals, 90.0)
            .unwrap();
        let missed = out
            .trace
            .events
            .iter()
            .filter(|e| {
                matches!(
                    e,
                    TraceEvent::HeartbeatMissed { replica: 0, .. }
                )
            })
            .count();
        assert!(missed >= 1, "beats must be lost");
        let evictions = out
            .trace
            .events
            .iter()
            .filter(|e| matches!(e, TraceEvent::ReplicaEvicted { .. }))
            .count();
        assert_eq!(evictions, 1, "exactly one eviction");
        // The evicted slot was re-planned as a replacement boot.
        assert!(out.cold_boots >= 1, "slot must be re-planned");
        assert_eq!(out.recorder.count(), n, "trace fully served");
        let v = check_all(&out.trace);
        assert!(v.is_empty(), "{v:?}");
    }

    /// A reconciler fed a stale snapshot every round still converges:
    /// the guards turn snapshot-lag steps (resize against an old
    /// footprint, unpark on a no-longer-parked replica) into traced
    /// no-ops and the run serves everything with zero violations.
    #[test]
    fn stale_observed_state_converges_through_guards() {
        let horizon = 240.0;
        let plan = FaultPlan::single(
            1,
            FaultKind::StaleObservedState { ticks: 200 },
        );
        let mut sim = fleet(Router::JoinShortestQueue);
        sim.injector =
            Some(Rc::new(RefCell::new(FaultInjector::new(plan))));
        let mut policy = fast_policy(PolicyMode::Hybrid, 8);
        let out = sim
            .run(
                &mut policy,
                &mut elastic_factory(8),
                2,
                burst_trace(horizon),
                horizon,
            )
            .unwrap();
        assert_eq!(out.truncated, 0, "stale rounds must not lose work");
        let noops = out
            .trace
            .events
            .iter()
            .filter(|e| {
                matches!(
                    e,
                    TraceEvent::ReconcileStep { applied: false, .. }
                )
            })
            .count();
        assert!(noops >= 1, "snapshot lag must surface as checked no-ops");
        let v = check_all(&out.trace);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn session_affinity_keeps_tenants_sticky_and_reports_per_tenant() {
        use crate::workload::{MultiTenantGen, TenantSpec};
        let sim = fleet(Router::SessionAffinity);
        let mut policy = fast_policy(PolicyMode::Hybrid, 6);
        let spec = |rps: f64, seed: u64| WorkloadSpec {
            prompt_len: 1000,
            decode_min: 50,
            decode_max: 100,
            profile: RateProfile::Fixed(rps),
            seed,
        };
        let tenants = MultiTenantGen::new(vec![
            TenantSpec::new("chat", spec(0.6, 1), SloConfig::strict()),
            TenantSpec::new("agent", spec(0.6, 2), SloConfig::new(8.0, 2.0)),
        ]);
        let arrivals = tenants.arrivals_until(90.0);
        let n = arrivals.len();
        let out = sim
            .run(&mut policy, &mut elastic_factory(6), 2, arrivals, 90.0)
            .unwrap();
        assert_eq!(out.recorder.count(), n);
        for (i, t) in tenants.tenants.iter().enumerate() {
            let att =
                out.recorder.attainment_for_tenant(i as u32, &t.slo);
            assert!(!att.is_nan(), "tenant {i} must have traffic");
        }
    }

    /// A prefill/decode disaggregated fleet serves a long-prompt trace
    /// end-to-end: every sequence prefills in the prefill pool, crosses
    /// the fabric as a planned KV copy, and decodes to completion in the
    /// decode pool — zero recompute tokens on the happy path, full
    /// invariant conformance (including handoff disposition).
    #[test]
    fn disaggregated_fleet_hands_off_without_recompute() {
        let mut sim = fleet(Router::JoinShortestQueue);
        sim.initial_roles = vec![
            PoolRole::Prefill,
            PoolRole::Decode,
            PoolRole::Prefill,
            PoolRole::Decode,
        ];
        let mut policy = fast_policy(PolicyMode::Hybrid, 8);
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 4096,
            decode_min: 50,
            decode_max: 100,
            profile: RateProfile::Fixed(0.4),
            seed: 9,
        });
        let arrivals = g.arrivals_until(90.0);
        let n = arrivals.len();
        let out = sim
            .run(&mut policy, &mut elastic_factory(8), 4, arrivals, 90.0)
            .unwrap();
        assert_eq!(out.recorder.count(), n, "trace fully served");
        let planned = out
            .trace
            .events
            .iter()
            .filter(|e| matches!(e, TraceEvent::HandoffPlanned { .. }))
            .count();
        assert!(planned >= n, "every request hands off at least once");
        assert!(
            out.pool_handoff.copied >= n,
            "handoffs must adopt via KV copy ({} < {n})",
            out.pool_handoff.copied
        );
        assert_eq!(
            out.pool_handoff.recompute_tokens, 0,
            "happy-path handoff must re-prefill nothing"
        );
        assert!(out.pool_handoff.adopted_tokens >= n as u64);
        let v = check_all(&out.trace);
        assert!(v.is_empty(), "{v:?}");
    }

    /// A `KvCopyFail` on the first handoff's first fabric leg aborts the
    /// transfer: the sequence is dispositioned as recompute-on-decode —
    /// re-prefilled in the decode pool — and still finishes exactly
    /// once. The remaining handoffs copy normally.
    #[test]
    fn kv_copy_fail_mid_handoff_falls_back_to_recompute() {
        let plan = FaultPlan::single(
            0,
            FaultKind::KvCopyFail { after_legs: 1 },
        );
        let mut sim = fleet(Router::JoinShortestQueue);
        sim.injector =
            Some(Rc::new(RefCell::new(FaultInjector::new(plan))));
        sim.initial_roles = vec![PoolRole::Prefill, PoolRole::Decode];
        let mut policy = fast_policy(PolicyMode::Hybrid, 8);
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 4096,
            decode_min: 50,
            decode_max: 100,
            profile: RateProfile::Fixed(0.3),
            seed: 11,
        });
        let arrivals = g.arrivals_until(60.0);
        let n = arrivals.len();
        let out = sim
            .run(&mut policy, &mut elastic_factory(8), 2, arrivals, 60.0)
            .unwrap();
        assert_eq!(out.recorder.count(), n, "no request may be lost");
        assert_eq!(
            out.pool_handoff.recomputed, 1,
            "exactly the faulted handoff recomputes"
        );
        assert!(
            out.pool_handoff.recompute_tokens >= 4096,
            "the aborted transfer re-prefills its prompt"
        );
        assert!(
            out.trace.events.iter().any(|e| matches!(
                e,
                TraceEvent::HandoffDone { recompute: true, .. }
            )),
            "the abort must surface as a recompute disposition"
        );
        let v = check_all(&out.trace);
        assert!(v.is_empty(), "{v:?}");
    }
}
