//! Retained fixed-window reference serving core.
//!
//! [`super::ServingSim`] and [`super::FleetSim`] run on the typed
//! [`crate::sim::EventQueue`]; this module keeps the shape they replaced —
//! a fixed-`dt` polling loop — alive at minimal scope, for two jobs:
//!
//! 1. **Perf baseline.** `repro bench --json` runs [`compare_cores`] and
//!    writes `BENCH_hotpath.json`, so CI tracks events/sec of the event
//!    core against the windowed reference on the same trace. The event
//!    core must never lose: it executes the same engine steps and skips
//!    the idle polls.
//! 2. **Semantic cross-check.** Both cores must complete the same
//!    requests and emit the same tokens on the same trace (asserted in
//!    this module's tests); a divergence means the event refactor changed
//!    serving semantics, not just pacing.
//!
//! The reference intentionally stays serve-only (no scaling): the point
//! of comparison is the core loop discipline, and keeping a second full
//! scaling choreography alive would let the two drift apart.
//!
//! The module also hosts [`telemetry_overhead`]: the same timed-pair
//! shape applied to the full [`ServingSim`] with the telemetry registry
//! off vs on, so `BENCH_hotpath.json` tracks the observability tax and
//! CI can hold it under the 5% events/sec budget.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use anyhow::Result;

use crate::config::model::dsv2_lite;
use crate::config::{ParallelConfig, SloConfig};
use crate::device::{Cluster, Timings};
use crate::engine::{CostModel, StepKind};
use crate::hmm::control::{HmmControl, HmmOptions};
use crate::imm::manager::{ImmOptions, InstanceManager};
use crate::scaling::ElasticMoE;
use crate::sim::{Clock, EventQueue, SimClock};
use crate::util::bench::time_fn;
use crate::util::json::Json;
use crate::workload::{RateProfile, Request, WorkloadGen, WorkloadSpec};

use super::serving::build_engine;
use super::{ServingSim, SimOutput, Trigger};

/// What one core did with a trace.
#[derive(Debug, Clone, Copy)]
pub struct CoreRun {
    /// Requests run to completion.
    pub completed: usize,
    /// Total tokens emitted (prefill first tokens + decode).
    pub tokens: u64,
    /// Engine steps executed (the work both cores share).
    pub steps: u64,
    /// Loop turns taken. For the windowed reference this includes every
    /// idle poll; for the event core it is steps plus event-queue jumps.
    pub iterations: u64,
}

/// Timed comparison of the event core against the windowed reference on
/// one canonical sparse trace (see [`compare_cores`]).
#[derive(Debug, Clone, Copy)]
pub struct CoreComparison {
    /// Arrivals in the trace.
    pub arrivals: usize,
    /// Poll interval of the windowed reference (seconds).
    pub dt: f64,
    pub event: CoreRun,
    pub event_wall_s: f64,
    pub windowed: CoreRun,
    pub windowed_wall_s: f64,
}

impl CoreComparison {
    /// Simulation events (engine steps + arrivals) per wall-clock second
    /// for a run. Both cores process the same event set; the windowed
    /// reference just burns extra wall time polling between them.
    fn events_per_sec(&self, run: &CoreRun, wall_s: f64) -> f64 {
        (run.steps + self.arrivals as u64) as f64 / wall_s.max(1e-12)
    }

    pub fn event_events_per_sec(&self) -> f64 {
        self.events_per_sec(&self.event, self.event_wall_s)
    }

    pub fn windowed_events_per_sec(&self) -> f64 {
        self.events_per_sec(&self.windowed, self.windowed_wall_s)
    }

    /// Event-core speedup over the windowed reference (>1 = faster).
    pub fn speedup(&self) -> f64 {
        self.windowed_wall_s / self.event_wall_s.max(1e-12)
    }

    /// Both cores completed the same requests with the same token count.
    pub fn outputs_match(&self) -> bool {
        self.event.completed == self.windowed.completed
            && self.event.tokens == self.windowed.tokens
    }

    /// The `BENCH_hotpath.json` document body.
    pub fn to_json(&self) -> Json {
        let core = |run: &CoreRun, wall: f64, eps: f64| {
            Json::obj(vec![
                ("completed", Json::num(run.completed as f64)),
                ("events_per_sec", Json::num(eps)),
                ("iterations", Json::num(run.iterations as f64)),
                ("steps", Json::num(run.steps as f64)),
                ("wall_s", Json::num(wall)),
            ])
        };
        Json::obj(vec![
            ("arrivals", Json::num(self.arrivals as f64)),
            ("dt_s", Json::num(self.dt)),
            (
                "event_core",
                core(
                    &self.event,
                    self.event_wall_s,
                    self.event_events_per_sec(),
                ),
            ),
            ("outputs_match", Json::Bool(self.outputs_match())),
            ("speedup", Json::num(self.speedup())),
            (
                "windowed_reference",
                core(
                    &self.windowed,
                    self.windowed_wall_s,
                    self.windowed_events_per_sec(),
                ),
            ),
        ])
    }
}

/// Serve `arrivals` with the fixed-window reference loop: poll every
/// `dt` simulated seconds, delivering due arrivals and stepping the
/// engine when it has work.
pub fn run_windowed(
    cost: &CostModel,
    parallel: &ParallelConfig,
    arrivals: &[Request],
    dt: f64,
) -> Result<CoreRun> {
    let mut eng = build_engine(cost, 64 << 30, 256, parallel, 1.0, 1.0);
    let clock = SimClock::new();
    let mut pending: VecDeque<Request> = arrivals.iter().cloned().collect();
    let mut completed = 0usize;
    let mut steps = 0u64;
    let mut iterations = 0u64;
    loop {
        iterations += 1;
        let now = clock.now();
        while pending
            .front()
            .map(|r| r.arrival <= now)
            .unwrap_or(false)
        {
            eng.submit(pending.pop_front().unwrap());
        }
        if eng.has_work() {
            let out = eng.step(&clock)?;
            steps += 1;
            completed += out.finished.len();
            if matches!(out.kind, StepKind::Idle) {
                clock.advance(dt);
            }
        } else if pending.is_empty() {
            break;
        } else {
            // The poll the event core never pays: nothing due, advance
            // one fixed window and look again.
            clock.advance(dt);
        }
    }
    Ok(CoreRun {
        completed,
        tokens: eng.tokens_emitted,
        steps,
        iterations,
    })
}

/// Serve `arrivals` with the event-queue core: identical engine and
/// trace, but idle time is skipped by jumping the clock to the next
/// queued arrival.
pub fn run_event(
    cost: &CostModel,
    parallel: &ParallelConfig,
    arrivals: &[Request],
) -> Result<CoreRun> {
    let mut eng = build_engine(cost, 64 << 30, 256, parallel, 1.0, 1.0);
    let clock = SimClock::new();
    let mut queue = EventQueue::with_capacity(arrivals.len());
    for r in arrivals {
        queue.push(r.arrival, ());
    }
    let mut pending: VecDeque<Request> = arrivals.iter().cloned().collect();
    let mut completed = 0usize;
    let mut steps = 0u64;
    let mut iterations = 0u64;
    loop {
        iterations += 1;
        let now = clock.now();
        while queue.peek_time().map(|t| t <= now).unwrap_or(false) {
            queue.pop();
        }
        while pending
            .front()
            .map(|r| r.arrival <= now)
            .unwrap_or(false)
        {
            eng.submit(pending.pop_front().unwrap());
        }
        if eng.has_work() {
            let out = eng.step(&clock)?;
            steps += 1;
            completed += out.finished.len();
            if matches!(out.kind, StepKind::Idle) {
                // Engine refused the work (e.g. KV pressure): jump to
                // the next arrival instead of spinning a frozen clock.
                match queue.peek_time() {
                    Some(next) => clock.advance_to(next + 1e-9),
                    None => break,
                }
            }
        } else {
            let Some(next) = queue.peek_time() else {
                break;
            };
            clock.advance_to(next + 1e-9);
        }
    }
    Ok(CoreRun {
        completed,
        tokens: eng.tokens_emitted,
        steps,
        iterations,
    })
}

/// Run both cores on the canonical sparse trace and time them.
///
/// The trace is deliberately sparse (long idle gaps between requests)
/// with a fine poll interval: that is exactly the regime where a
/// fixed-window loop wastes its iterations and an event core does not.
/// `fast` shortens the horizon for CI.
pub fn compare_cores(fast: bool) -> Result<CoreComparison> {
    let cost = CostModel::new(dsv2_lite(), Timings::cloudmatrix());
    let parallel = ParallelConfig::standard(2, 2, (0..4).collect())?;
    let horizon = if fast { 240.0 } else { 600.0 };
    let dt = 0.001;
    let mut g = WorkloadGen::new(WorkloadSpec {
        prompt_len: 1000,
        decode_min: 50,
        decode_max: 100,
        profile: RateProfile::Fixed(0.25),
        seed: 42,
    });
    let arrivals = g.arrivals_until(horizon);
    let (windowed_wall_s, windowed) =
        time_fn(|| run_windowed(&cost, &parallel, &arrivals, dt));
    let windowed = windowed?;
    let (event_wall_s, event) =
        time_fn(|| run_event(&cost, &parallel, &arrivals));
    let event = event?;
    Ok(CoreComparison {
        arrivals: arrivals.len(),
        dt,
        event,
        event_wall_s,
        windowed,
        windowed_wall_s,
    })
}

/// Timed cost of the telemetry subsystem on the full serving simulator:
/// the identical seed/trace/scale-command run with the registry off and
/// on. The two runs must produce bit-identical state hashes — the
/// determinism-neutrality contract of [`crate::obs`].
#[derive(Debug, Clone, Copy)]
pub struct TelemetryOverhead {
    /// Requests completed (identical in both runs).
    pub completed: usize,
    pub off_wall_s: f64,
    pub on_wall_s: f64,
    /// State hashes of the two runs — equal iff telemetry is neutral.
    pub off_hash: u64,
    pub on_hash: u64,
}

impl TelemetryOverhead {
    /// Fractional wall-time cost of enabling telemetry (0.03 = 3%).
    /// Negative values (noise on a fast run) mean "free".
    pub fn overhead_frac(&self) -> f64 {
        (self.on_wall_s - self.off_wall_s) / self.off_wall_s.max(1e-12)
    }

    /// The determinism-neutrality contract held.
    pub fn neutral(&self) -> bool {
        self.off_hash == self.on_hash
    }

    /// The `telemetry_overhead` section of `BENCH_hotpath.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("completed", Json::num(self.completed as f64)),
            ("neutral", Json::Bool(self.neutral())),
            ("off_wall_s", Json::num(self.off_wall_s)),
            ("on_wall_s", Json::num(self.on_wall_s)),
            ("overhead_frac", Json::num(self.overhead_frac())),
        ])
    }
}

/// One canonical ServingSim run for the overhead pair: ElasticMoE on a
/// six-device cluster, one vertical 4→6 event a quarter into the trace.
fn overhead_run(obs: bool, horizon: f64) -> Result<SimOutput> {
    let mut sim = ServingSim::new(
        CostModel::new(dsv2_lite(), Timings::cloudmatrix()),
        SloConfig::new(5.0, 1.5),
    );
    sim.obs = obs;
    let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(6)));
    let mut m = ElasticMoE::new(
        HmmControl::new(cluster, dsv2_lite(), HmmOptions::default()),
        InstanceManager::new(ImmOptions::default(), Timings::cloudmatrix()),
        8 << 30,
    );
    let mut g = WorkloadGen::new(WorkloadSpec {
        prompt_len: 2000,
        decode_min: 100,
        decode_max: 150,
        profile: RateProfile::Fixed(2.0),
        seed: 11,
    });
    let par4 = ParallelConfig::standard(2, 2, (0..4).collect())?;
    let par6 = ParallelConfig::standard(3, 2, (0..6).collect())?;
    sim.run(
        &mut m,
        &par4,
        g.arrivals_until(horizon),
        Trigger::Manual(vec![(horizon * 0.25, par6)]),
        horizon,
    )
}

/// Measure the telemetry tax on the event core: one warm-up pass, then
/// the off/on pair timed back to back on the identical trace. `fast`
/// shortens the horizon for CI. The acceptance budget is a < 5%
/// events/sec regression; [`TelemetryOverhead::overhead_frac`] is that
/// figure (the event set is identical in both runs, so the wall-time
/// ratio is the events/sec ratio).
pub fn telemetry_overhead(fast: bool) -> Result<TelemetryOverhead> {
    let horizon = if fast { 120.0 } else { 480.0 };
    // Warm-up pass evens out allocator state before the timed pair.
    let _ = overhead_run(false, horizon)?;
    let (off_wall_s, off) = time_fn(|| overhead_run(false, horizon));
    let off = off?;
    let (on_wall_s, on) = time_fn(|| overhead_run(true, horizon));
    let on = on?;
    Ok(TelemetryOverhead {
        completed: off.recorder.count(),
        off_wall_s,
        on_wall_s,
        off_hash: off.state_hash,
        on_hash: on.state_hash,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_trace() -> Vec<Request> {
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 500,
            decode_min: 10,
            decode_max: 20,
            profile: RateProfile::Fixed(0.5),
            seed: 7,
        });
        g.arrivals_until(30.0)
    }

    #[test]
    fn cores_agree_on_completions_and_tokens() {
        let cost = CostModel::new(dsv2_lite(), Timings::cloudmatrix());
        let par = ParallelConfig::standard(2, 2, (0..4).collect()).unwrap();
        let trace = tiny_trace();
        let w = run_windowed(&cost, &par, &trace, 0.01).unwrap();
        let e = run_event(&cost, &par, &trace).unwrap();
        assert_eq!(w.completed, trace.len());
        assert_eq!(e.completed, w.completed);
        assert_eq!(e.tokens, w.tokens);
        // The whole point of the event core: far fewer loop turns on a
        // sparse trace.
        assert!(
            e.iterations < w.iterations,
            "event {} vs windowed {}",
            e.iterations,
            w.iterations
        );
    }

    #[test]
    fn telemetry_overhead_is_neutral() {
        let o = telemetry_overhead(true).unwrap();
        assert!(o.neutral(), "telemetry changed the state hash");
        assert!(o.completed > 0);
        let doc = o.to_json().to_string();
        assert!(doc.contains("\"overhead_frac\""), "{doc}");
        assert!(doc.contains("\"neutral\":true"), "{doc}");
    }

    #[test]
    fn comparison_json_has_both_cores() {
        let cmp = compare_cores(true).unwrap();
        assert!(cmp.outputs_match(), "{cmp:?}");
        let doc = cmp.to_json().to_string();
        assert!(doc.contains("\"event_core\""), "{doc}");
        assert!(doc.contains("\"windowed_reference\""), "{doc}");
        assert!(doc.contains("\"events_per_sec\""), "{doc}");
    }
}
