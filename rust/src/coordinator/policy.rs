//! Fleet-level scaling policy: per window, decide between (a) a vertical
//! step of one replica (ElasticMoE's fast path), (b) adding/draining a
//! whole replica (horizontal, replica-granular cold boot), or (c) holding.
//!
//! This generalises [`LoadEstimator`]'s hysteresis to fleet granularity:
//! one fleet-wide estimator debounces the *direction* (up/down/hold), then
//! the policy maps the direction to a concrete [`FleetAction`] under the
//! shared device-pool budget, the per-replica vertical envelope, and
//! per-replica cooldowns (so one hot replica cannot absorb every event
//! while others starve).
//!
//! The policy's public contract is declarative: [`FleetPolicy::decide`]
//! projects the chosen action onto the observed loads and returns a
//! [`FleetSpec`] — the desired fleet state — which the
//! [`super::reconciler::Reconciler`] diffs against observed state each
//! tick into idempotent steps. [`FleetPolicy::decide_action`] remains
//! the imperative kernel underneath (and the unit-test surface).

use std::collections::HashMap;

use crate::config::SloConfig;

use super::estimator::{LoadEstimator, ScaleDecision};

/// Which serving phase a replica is dedicated to (prefill/decode
/// disaggregation). `Unified` replicas run both phases — the classic
/// single-pool fleet, and the default everywhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PoolRole {
    /// Prompt-processing pool: requests prefill here, then hand their
    /// KV to a decode replica over a planned fabric leg.
    Prefill,
    /// Token-generation pool: adopts prefilled requests via KV handoff
    /// (or re-prefills them when the handoff leg aborts).
    Decode,
    /// Both phases on one replica (no disaggregation).
    #[default]
    Unified,
}

impl PoolRole {
    /// Short stable label for telemetry series and reports.
    pub fn label(&self) -> &'static str {
        match self {
            PoolRole::Prefill => "prefill",
            PoolRole::Decode => "decode",
            PoolRole::Unified => "unified",
        }
    }
}

/// A point-in-time load snapshot of one replica, as seen by the policy.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaLoad {
    pub id: usize,
    /// Devices the replica currently holds (or has reserved mid-scale).
    pub devices: usize,
    /// Running batch occupancy in `[0, 1]`.
    pub occupancy: f64,
    /// Queued requests (coordinator inbox + batcher queue).
    pub queue_depth: usize,
    /// A scaling transition or boot is in flight on this replica.
    pub busy: bool,
    /// The replica is still cold-booting (not serving yet). Implies
    /// `busy`; distinguishes "capacity arriving via horizontal add" from
    /// "live replica mid-vertical-step".
    pub booting: bool,
    /// The replica is draining out of the fleet.
    pub draining: bool,
    /// The replica is parked at zero devices (weights DRAM-resident,
    /// engine gone). It serves nothing until an
    /// [`FleetAction::Unpark`].
    pub parked: bool,
    /// Predicted max/mean expert token load across the replica's devices
    /// (1.0 = balanced or unknown; see
    /// [`crate::scaling::ScalingMethod::placement_imbalance`]).
    pub imbalance: f64,
    /// Absolute time of the replica's last received heartbeat. The
    /// reconciler marks a live replica suspect (and evicts it) once
    /// `now - last_heartbeat` passes its staleness deadline; parked and
    /// booting replicas are exempt.
    pub last_heartbeat: f64,
    /// The pool this replica serves in ([`PoolRole::Unified`] on
    /// non-disaggregated fleets).
    pub role: PoolRole,
}

/// Fleet sizing envelope and the shared device-pool budget.
#[derive(Debug, Clone, Copy)]
pub struct FleetLimits {
    /// Total devices the fleet may hold across all replicas.
    pub pool_devices: usize,
    /// Devices a freshly added replica boots with.
    pub replica_base: usize,
    /// Vertical ceiling per replica (devices).
    pub replica_max: usize,
    /// Vertical step size (usually the model's fixed TP).
    pub step: usize,
    /// The fleet never drains below this many replicas.
    pub min_replicas: usize,
}

/// How the fleet is allowed to scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyMode {
    /// Prefer fast vertical steps; fall back to whole replicas only when
    /// every replica's vertical headroom (or the pool) is exhausted.
    Hybrid,
    /// Replica-granular only: the horizontal-autoscaler baseline.
    HorizontalOnly,
    /// Vertical steps only (never changes the replica count).
    VerticalOnly,
}

/// One fleet scaling action for the simulator to apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetAction {
    Hold,
    /// Grow `replica` vertically to `to_devices` (ElasticMoE scale-up).
    VerticalUp { replica: usize, to_devices: usize },
    /// Shrink `replica` vertically to `to_devices`.
    VerticalDown { replica: usize, to_devices: usize },
    /// Cold-boot a whole new replica of `replica_base` devices.
    AddReplica,
    /// Stop routing to `replica`; release its devices once empty.
    DrainReplica { replica: usize },
    /// Redistribution-only event on `replica`: same devices, new expert
    /// placement (the answer to popularity skew, not load volume).
    Rebalance { replica: usize },
    /// Scale `replica` to zero devices, keeping its weights DRAM-warm
    /// (the tiered store's scale-to-zero). Chosen over
    /// [`FleetAction::DrainReplica`] when the estimator forecasts a
    /// re-burst within the park TTL; uniquely, park may take the fleet
    /// below `min_replicas` — unpark is fast enough to answer a burst.
    Park { replica: usize },
    /// Bring a parked replica back (DRAM-warm fast boot). Preferred over
    /// every other scale-up action: cheapest capacity in the fleet.
    Unpark { replica: usize },
}

impl FleetAction {
    /// Short stable description for the decision ledger and trace.
    pub fn describe(&self) -> String {
        match self {
            FleetAction::Hold => "hold".to_string(),
            FleetAction::VerticalUp { replica, to_devices } => {
                format!("grow r{replica}->{to_devices}dev")
            }
            FleetAction::VerticalDown { replica, to_devices } => {
                format!("shrink r{replica}->{to_devices}dev")
            }
            FleetAction::AddReplica => "add-replica".to_string(),
            FleetAction::DrainReplica { replica } => {
                format!("drain r{replica}")
            }
            FleetAction::Rebalance { replica } => {
                format!("rebalance r{replica}")
            }
            FleetAction::Park { replica } => format!("park r{replica}"),
            FleetAction::Unpark { replica } => format!("unpark r{replica}"),
        }
    }
}

/// One explained policy decision: everything [`FleetPolicy::decide_action`]
/// observed and concluded for a single window, in trace-foldable form.
/// Buffered on the policy and drained by the fleet simulator into the
/// event trace as [`crate::chaos::trace::TraceEvent::DecisionExplain`]
/// (state-hash folded, emitted unconditionally so the PR 7
/// determinism-neutrality contract holds by construction).
///
/// `attainment` is the estimator-fed value (after the queue-pressure
/// clamp), with NaN (no traffic finished this window) encoded as `-1.0`
/// so the record survives JSON. `vetoed` marks a window where the
/// hysteresis fired but no action was enactable (candidates busy or
/// cooling, pool budget exhausted, replica floor) — the estimator was
/// refunded and will retry.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionExplain {
    pub t: f64,
    /// Pool the decision was made for ([`PoolRole::label`]).
    pub pool: &'static str,
    /// Serving (non-draining, non-parked) replicas observed.
    pub serving: usize,
    /// Estimator-fed windowed attainment; `-1.0` encodes NaN.
    pub attainment: f64,
    /// Mean batch occupancy across serving replicas.
    pub occupancy: f64,
    /// Total queued requests across serving replicas.
    pub queue: usize,
    /// Estimator violation streak after this window.
    pub bad_windows: usize,
    /// Estimator comfortable streak after this window.
    pub good_windows: usize,
    /// The estimator's post-action cooldown was still running.
    pub cooling: bool,
    /// A refunded direction was armed to re-fire through the cooldown.
    pub rearmed: bool,
    /// The re-burst forecast (park-vs-teardown horizon) was warm.
    pub reburst: bool,
    /// Hysteresis verdict: `"up"`, `"down"`, `"hold"`, or `"wake"`
    /// (scale-from-zero path, no estimator consulted).
    pub decision: &'static str,
    /// The concrete action chosen ([`FleetAction::describe`]).
    pub action: String,
    /// The verdict fired but nothing was enactable (trigger refunded).
    pub vetoed: bool,
}

/// Desired state of one replica slot in a [`FleetSpec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaSpec {
    /// Replica id the slot binds to. Slots for not-yet-booted replicas
    /// carry a placeholder id (max observed + 1); the simulator assigns
    /// the real id at boot and the next round's projection re-binds.
    pub id: usize,
    /// Devices the slot should hold while serving. `0` on a non-parked
    /// slot means "keep the replica's current footprint" — used when
    /// unparking, where the pre-park size is simulator state the policy
    /// cannot observe. Parked slots always carry 0.
    pub devices: usize,
    /// The slot is parked at zero devices (weights DRAM-warm).
    pub parked: bool,
    /// The pool the slot belongs to. A replica booted for this slot
    /// inherits the role; the reconciler treats role as immutable (a
    /// replica never migrates between pools — it drains out instead).
    pub role: PoolRole,
}

/// The policy's declared desired fleet state for one reconcile round:
/// one slot per replica that should exist. Observed replicas absent
/// from the spec are drained out of the fleet; spec slots with no
/// observed counterpart are booted. The
/// [`super::reconciler::Reconciler`] diffs this against observed state
/// into idempotent [`super::reconciler::ReconcileStep`]s.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FleetSpec {
    pub replicas: Vec<ReplicaSpec>,
    /// Redistribution-only request on one replica this round (same
    /// devices, new expert placement). Not part of the persistent
    /// desired state: a rebalance is a one-shot event, not a condition
    /// to converge on.
    pub rebalance: Option<usize>,
}

impl FleetSpec {
    /// The slot for replica `id`, if the spec wants it to exist.
    pub fn slot(&self, id: usize) -> Option<&ReplicaSpec> {
        self.replicas.iter().find(|s| s.id == id)
    }

    /// Devices the spec asks for across all slots.
    pub fn devices_total(&self) -> usize {
        self.replicas.iter().map(|s| s.devices).sum()
    }

    /// Parked slots in the spec.
    pub fn parked_count(&self) -> usize {
        self.replicas.iter().filter(|s| s.parked).count()
    }
}

/// The identity spec over observed loads: every non-draining replica
/// keeps its footprint, park state and pool role.
fn identity_spec(loads: &[ReplicaLoad]) -> FleetSpec {
    FleetSpec {
        replicas: loads
            .iter()
            .filter(|l| !l.draining)
            .map(|l| ReplicaSpec {
                id: l.id,
                devices: l.devices,
                parked: l.parked,
                role: l.role,
            })
            .collect(),
        rebalance: None,
    }
}

/// The fleet policy: fleet-wide hysteresis plus action selection.
pub struct FleetPolicy {
    pub mode: PolicyMode,
    pub limits: FleetLimits,
    /// Fleet-wide up/down/hold debouncing (windowed SLO + queue pressure).
    pub estimator: LoadEstimator,
    /// Minimum seconds between successive events on the same replica.
    pub replica_cooldown: f64,
    /// Fleet queue depth at which the window counts as violating even if
    /// the finished-request attainment still looks healthy (during a burst
    /// the backlog grows before any late request has *finished* and pulled
    /// the windowed attainment down).
    pub pressure_queue: usize,
    /// Expert-placement imbalance (max/mean token load) at which a
    /// replica earns a redistribution-only event when the fleet is
    /// otherwise holding.
    pub rebalance_threshold: f64,
    /// Allow park/unpark (scale-to-zero with DRAM-resident weights).
    /// Off by default: only methods with a tiered weight store can
    /// enact it, and the always-on baseline must stay measurable.
    pub park_enabled: bool,
    /// Re-burst horizon: an idle replica parks (instead of draining)
    /// when traffic was seen within this many seconds — the serverless
    /// keep-warm window.
    pub park_ttl: f64,
    /// Per-pool direction debouncing for disaggregated fleets: when the
    /// observed loads carry [`PoolRole::Prefill`] / [`PoolRole::Decode`]
    /// roles, each pool's windows feed its own estimator (swapped into
    /// the shared decision kernel per pool), so a long-prompt burst
    /// scales prefill without burning the decode pool's hysteresis.
    pub prefill_estimator: LoadEstimator,
    pub decode_estimator: LoadEstimator,
    last_event: HashMap<usize, f64>,
    /// One [`DecisionExplain`] per [`Self::decide_action`] call since the
    /// last [`Self::take_explains`] drain.
    explains: Vec<DecisionExplain>,
    /// Pool context the next `decide_action` call explains under (set by
    /// [`Self::decide_pools`] around each per-pool kernel invocation).
    explain_pool: PoolRole,
}

impl FleetPolicy {
    pub fn new(mode: PolicyMode, limits: FleetLimits, slo: SloConfig) -> Self {
        FleetPolicy {
            mode,
            limits,
            estimator: LoadEstimator::new(slo),
            replica_cooldown: 20.0,
            pressure_queue: 8,
            rebalance_threshold: 1.5,
            park_enabled: false,
            park_ttl: 150.0,
            prefill_estimator: LoadEstimator::new(slo),
            decode_estimator: LoadEstimator::new(slo),
            last_event: HashMap::new(),
            explains: Vec::new(),
            explain_pool: PoolRole::Unified,
        }
    }

    /// Drain the decision explanations buffered since the last call (one
    /// per [`Self::decide_action`] invocation, in decision order).
    pub fn take_explains(&mut self) -> Vec<DecisionExplain> {
        std::mem::take(&mut self.explains)
    }

    /// Record that `replica` was touched at `now` (starts its cooldown).
    pub fn note_event(&mut self, replica: usize, now: f64) {
        self.last_event.insert(replica, now);
    }

    /// Give back a replica's cooldown after the simulator vetoed the
    /// issued action (e.g. a park that raced in-flight work): the next
    /// window may retry instead of waiting out a full cooldown cycle.
    /// Pair with [`LoadEstimator::refund`] when an estimator decision
    /// was consumed.
    pub fn clear_event(&mut self, replica: usize) {
        self.last_event.remove(&replica);
    }

    fn cooled_down(&self, replica: usize, now: f64) -> bool {
        self.last_event
            .get(&replica)
            .map(|&t| now - t >= self.replica_cooldown)
            .unwrap_or(true)
    }

    /// Declare the desired fleet state for the window ending at `now`:
    /// observe the fleet exactly as [`Self::decide_action`] does, then
    /// project the chosen action onto the observed loads as a
    /// [`FleetSpec`] for the reconciler to converge on. Disaggregated
    /// fleets (any non-[`PoolRole::Unified`] load) converge each pool
    /// independently via [`Self::decide_pools`].
    pub fn decide(
        &mut self,
        now: f64,
        attainment: f64,
        loads: &[ReplicaLoad],
        free_devices: usize,
    ) -> FleetSpec {
        if loads.iter().any(|l| l.role != PoolRole::Unified) {
            return self.decide_pools(now, attainment, loads, free_devices);
        }
        let action = self.decide_action(now, attainment, loads, free_devices);
        self.project(action, loads)
    }

    /// Per-pool projection for disaggregated fleets: each role subset is
    /// observed through its own estimator (swapped into the shared
    /// decision kernel), contributes at most one slot delta to the joint
    /// spec, and draws from the shared pool budget in role order. The
    /// fleet-wide attainment is attributed only to pools showing
    /// pressure (queued work or near-saturated batches) — an unloaded
    /// pool observes a healthy window instead of scaling on the other
    /// pool's pain, which is what lets long-prompt bursts grow prefill
    /// while decode holds (and vice versa for long-generation traffic).
    fn decide_pools(
        &mut self,
        now: f64,
        attainment: f64,
        loads: &[ReplicaLoad],
        free_devices: usize,
    ) -> FleetSpec {
        let mut spec = identity_spec(loads);
        let mut free = free_devices;
        let next_id = loads.iter().map(|l| l.id + 1).max().unwrap_or(0);
        for role in [PoolRole::Prefill, PoolRole::Decode, PoolRole::Unified]
        {
            let pool: Vec<ReplicaLoad> = loads
                .iter()
                .filter(|l| l.role == role)
                .copied()
                .collect();
            if pool.is_empty() {
                continue;
            }
            let serving: Vec<&ReplicaLoad> = pool
                .iter()
                .filter(|l| !l.draining && !l.parked)
                .collect();
            let queue: usize =
                serving.iter().map(|l| l.queue_depth).sum();
            let occ = if serving.is_empty() {
                0.0
            } else {
                serving.iter().map(|l| l.occupancy).sum::<f64>()
                    / serving.len() as f64
            };
            let pressured = queue > 0 || occ > 0.85;
            let att = if pressured || attainment.is_nan() {
                attainment
            } else {
                1.0
            };
            self.swap_pool_estimator(role);
            self.explain_pool = role;
            let action = self.decide_action(now, att, &pool, free);
            self.explain_pool = PoolRole::Unified;
            self.swap_pool_estimator(role);
            // Account the action's draw against the shared budget before
            // the next pool decides (freed devices return only after the
            // simulator enacts the step, not within this round).
            let drawn = match action {
                FleetAction::VerticalUp { replica, to_devices } => {
                    to_devices.saturating_sub(
                        pool.iter()
                            .find(|l| l.id == replica)
                            .map(|l| l.devices)
                            .unwrap_or(0),
                    )
                }
                FleetAction::AddReplica => self.limits.replica_base,
                FleetAction::Unpark { .. } => self.limits.replica_base,
                _ => 0,
            };
            free = free.saturating_sub(drawn);
            self.apply_action(&mut spec, action, next_id, role);
        }
        spec
    }

    /// Swap the given pool's estimator into the shared kernel slot
    /// (self-inverse; [`PoolRole::Unified`] uses the shared estimator
    /// directly).
    fn swap_pool_estimator(&mut self, role: PoolRole) {
        match role {
            PoolRole::Prefill => std::mem::swap(
                &mut self.estimator,
                &mut self.prefill_estimator,
            ),
            PoolRole::Decode => std::mem::swap(
                &mut self.estimator,
                &mut self.decode_estimator,
            ),
            PoolRole::Unified => {}
        }
    }

    /// Project one imperative action onto the observed loads: the
    /// identity spec (every non-draining replica keeps its footprint)
    /// with the action's one-slot delta applied.
    pub fn project(
        &self,
        action: FleetAction,
        loads: &[ReplicaLoad],
    ) -> FleetSpec {
        let mut spec = identity_spec(loads);
        let next_id = loads.iter().map(|l| l.id + 1).max().unwrap_or(0);
        self.apply_action(&mut spec, action, next_id, PoolRole::Unified);
        spec
    }

    /// Apply one action's slot delta to `spec`. `next_id` is the id a
    /// freshly added slot binds to (global max + 1 — pool subsets must
    /// not reuse a live id from another pool); `new_role` is the pool
    /// the added slot serves in.
    fn apply_action(
        &self,
        spec: &mut FleetSpec,
        action: FleetAction,
        next_id: usize,
        new_role: PoolRole,
    ) {
        let slot = |spec: &mut FleetSpec, id: usize| {
            spec.replicas.iter_mut().find(|s| s.id == id)
        };
        match action {
            FleetAction::Hold => {}
            FleetAction::VerticalUp { replica, to_devices }
            | FleetAction::VerticalDown { replica, to_devices } => {
                if let Some(s) = slot(spec, replica) {
                    s.devices = to_devices;
                }
            }
            FleetAction::Park { replica } => {
                if let Some(s) = slot(spec, replica) {
                    s.parked = true;
                    s.devices = 0;
                }
            }
            FleetAction::Unpark { replica } => {
                // devices stays 0: the replica resumes at its pre-park
                // size, which only the simulator knows.
                if let Some(s) = slot(spec, replica) {
                    s.parked = false;
                }
            }
            FleetAction::AddReplica => {
                spec.replicas.push(ReplicaSpec {
                    id: next_id,
                    devices: self.limits.replica_base,
                    parked: false,
                    role: new_role,
                });
            }
            FleetAction::DrainReplica { replica } => {
                spec.replicas.retain(|s| s.id != replica);
            }
            FleetAction::Rebalance { replica } => {
                spec.rebalance = Some(replica);
            }
        }
    }

    /// Decide the fleet action for the window ending at `now`.
    ///
    /// `attainment` is the fleet-wide windowed SLO attainment (NaN when no
    /// traffic finished), `loads` the per-replica snapshots, and
    /// `free_devices` what remains of the shared pool budget.
    pub fn decide_action(
        &mut self,
        now: f64,
        attainment: f64,
        loads: &[ReplicaLoad],
        free_devices: usize,
    ) -> FleetAction {
        let serving: Vec<&ReplicaLoad> = loads
            .iter()
            .filter(|l| !l.draining && !l.parked)
            .collect();
        let parked: Vec<&ReplicaLoad> =
            loads.iter().filter(|l| l.parked).collect();
        if serving.is_empty() {
            // Scale-from-zero: with every replica parked, queued
            // arrivals are the wake-up signal (there is no attainment to
            // observe — nothing is finishing).
            let queue: usize = loads.iter().map(|l| l.queue_depth).sum();
            let mut action = FleetAction::Hold;
            if self.park_enabled
                && free_devices >= self.limits.replica_base
                && queue > 0
            {
                if let Some(l) =
                    parked.iter().find(|l| self.cooled_down(l.id, now))
                {
                    self.note_event(l.id, now);
                    action = FleetAction::Unpark { replica: l.id };
                }
            }
            self.explains.push(DecisionExplain {
                t: now,
                pool: self.explain_pool.label(),
                serving: 0,
                attainment: if attainment.is_nan() { -1.0 } else { attainment },
                occupancy: 0.0,
                queue,
                bad_windows: self.estimator.bad_windows() as usize,
                good_windows: self.estimator.good_windows() as usize,
                cooling: self.estimator.is_cooling(now),
                rearmed: self.estimator.rearmed().is_some(),
                reburst: self
                    .estimator
                    .forecasts_reburst(now, self.park_ttl),
                decision: if action == FleetAction::Hold {
                    "hold"
                } else {
                    "wake"
                },
                action: action.describe(),
                vetoed: false,
            });
            return action;
        }
        let occupancy = serving.iter().map(|l| l.occupancy).sum::<f64>()
            / serving.len() as f64;
        let queue: usize = serving.iter().map(|l| l.queue_depth).sum();
        let attainment = if queue >= self.pressure_queue.max(1) {
            0.0
        } else {
            attainment
        };
        // Pre-observe estimator state: this is what the verdict was
        // judged under (observe may consume the counters or the re-arm).
        let cooling = self.estimator.is_cooling(now);
        let rearmed = self.estimator.rearmed().is_some();
        let decision =
            self.estimator.observe(now, attainment, occupancy, queue);
        let mut action = match decision {
            ScaleDecision::Up => {
                self.scale_up(now, &serving, &parked, free_devices)
            }
            ScaleDecision::Down => self.scale_down(now, &serving),
            ScaleDecision::Hold => FleetAction::Hold,
        };
        let vetoed =
            action == FleetAction::Hold && decision != ScaleDecision::Hold;
        if vetoed {
            // The trigger fired but no action was possible (candidates
            // busy/cooling, pool exhausted, floor reached): re-arm the
            // estimator so it retries at the next window instead of
            // waiting out patience + cooldown while the condition holds.
            self.estimator.refund(decision);
        }
        if action == FleetAction::Hold
            && decision == ScaleDecision::Hold
            && self.mode != PolicyMode::HorizontalOnly
        {
            // Load volume is healthy, but a replica's expert placement may
            // have drifted out of balance with traffic skew: spend the
            // quiet window on a redistribution-only event (same devices,
            // new placement) so the next burst hits balanced EP ranks.
            let candidate = serving
                .iter()
                .filter(|l| {
                    !l.busy
                        && l.imbalance >= self.rebalance_threshold
                        && self.cooled_down(l.id, now)
                })
                .max_by(|a, b| {
                    a.imbalance.total_cmp(&b.imbalance).then(b.id.cmp(&a.id))
                });
            if let Some(l) = candidate {
                self.note_event(l.id, now);
                action = FleetAction::Rebalance { replica: l.id };
            }
        }
        self.explains.push(DecisionExplain {
            t: now,
            pool: self.explain_pool.label(),
            serving: serving.len(),
            attainment: if attainment.is_nan() { -1.0 } else { attainment },
            occupancy,
            queue,
            bad_windows: self.estimator.bad_windows() as usize,
            good_windows: self.estimator.good_windows() as usize,
            cooling,
            rearmed,
            reburst: self.estimator.forecasts_reburst(now, self.park_ttl),
            decision: match decision {
                ScaleDecision::Up => "up",
                ScaleDecision::Down => "down",
                ScaleDecision::Hold => "hold",
            },
            action: action.describe(),
            vetoed,
        });
        action
    }

    fn scale_up(
        &mut self,
        now: f64,
        serving: &[&ReplicaLoad],
        parked: &[&ReplicaLoad],
        free_devices: usize,
    ) -> FleetAction {
        // Cheapest capacity first: a parked replica is a DRAM-warm fast
        // boot away from serving — under every vertical step's worth of
        // new provisioning and far under a cold replica add. Its devices
        // were returned to the pool at park, so re-acquiring them needs
        // pool budget like any other grant (a parked replica resumes at
        // its pre-park size, ≥ the base; the simulator re-checks the
        // exact footprint).
        if self.park_enabled && free_devices >= self.limits.replica_base {
            if let Some(l) =
                parked.iter().find(|l| self.cooled_down(l.id, now))
            {
                self.note_event(l.id, now);
                return FleetAction::Unpark { replica: l.id };
            }
        }
        if self.mode != PolicyMode::HorizontalOnly {
            // Vertical first: the most pressured replica that still has
            // headroom, pool budget, and a lapsed cooldown.
            if free_devices >= self.limits.step {
                let candidate = serving
                    .iter()
                    .filter(|l| {
                        !l.busy
                            && l.devices + self.limits.step
                                <= self.limits.replica_max
                            && self.cooled_down(l.id, now)
                    })
                    .max_by(|a, b| {
                        a.queue_depth
                            .cmp(&b.queue_depth)
                            .then(a.occupancy.total_cmp(&b.occupancy))
                    });
                if let Some(l) = candidate {
                    self.note_event(l.id, now);
                    return FleetAction::VerticalUp {
                        replica: l.id,
                        to_devices: l.devices + self.limits.step,
                    };
                }
            }
            // Live vertical headroom exists but every candidate is
            // mid-scale or cooling down: wait for the fast path instead of
            // paying a whole-replica cold boot (hybrid goes horizontal
            // only when the vertical envelope is genuinely exhausted).
            // Cold-booting replicas don't count — their headroom is not
            // live capacity, and holding on it would serialise replica
            // adds behind each full boot.
            let headroom = serving.iter().any(|l| {
                !l.booting
                    && l.devices + self.limits.step
                        <= self.limits.replica_max
            });
            if headroom && free_devices >= self.limits.step {
                return FleetAction::Hold;
            }
        }
        // Horizontal fallback: a whole fresh replica if the pool allows.
        if self.mode != PolicyMode::VerticalOnly
            && free_devices >= self.limits.replica_base
        {
            return FleetAction::AddReplica;
        }
        FleetAction::Hold
    }

    fn scale_down(
        &mut self,
        now: f64,
        serving: &[&ReplicaLoad],
    ) -> FleetAction {
        // Prefer returning a vertical step from the least loaded replica
        // that has grown beyond its base size.
        if self.mode != PolicyMode::HorizontalOnly {
            let candidate = serving
                .iter()
                .filter(|l| {
                    !l.busy
                        && l.devices
                            >= self.limits.replica_base + self.limits.step
                        && self.cooled_down(l.id, now)
                })
                .min_by(|a, b| {
                    a.queue_depth
                        .cmp(&b.queue_depth)
                        .then(a.occupancy.total_cmp(&b.occupancy))
                });
            if let Some(l) = candidate {
                self.note_event(l.id, now);
                return FleetAction::VerticalDown {
                    replica: l.id,
                    to_devices: l.devices - self.limits.step,
                };
            }
        }
        // Park over teardown when a re-burst is forecast within the TTL
        // (serverless keep-warm): the replica's weights stay
        // DRAM-resident and unpark answers the next burst in seconds.
        // Park is the one action allowed below the replica floor —
        // scale-to-zero is its whole point.
        if self.park_enabled
            && self.estimator.forecasts_reburst(now, self.park_ttl)
        {
            let candidate = serving
                .iter()
                .filter(|l| {
                    !l.busy
                        && l.queue_depth == 0
                        && l.occupancy < 0.05
                        && self.cooled_down(l.id, now)
                })
                .min_by(|a, b| a.occupancy.total_cmp(&b.occupancy));
            if let Some(l) = candidate {
                self.note_event(l.id, now);
                return FleetAction::Park { replica: l.id };
            }
        }
        // Otherwise drain a whole idle replica, keeping the floor.
        if self.mode != PolicyMode::VerticalOnly
            && serving.len() > self.limits.min_replicas
        {
            let candidate = serving
                .iter()
                .filter(|l| {
                    !l.busy
                        && l.queue_depth == 0
                        && self.cooled_down(l.id, now)
                })
                .min_by(|a, b| a.occupancy.total_cmp(&b.occupancy));
            if let Some(l) = candidate {
                self.note_event(l.id, now);
                return FleetAction::DrainReplica { replica: l.id };
            }
        }
        FleetAction::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits() -> FleetLimits {
        FleetLimits {
            pool_devices: 12,
            replica_base: 2,
            replica_max: 6,
            step: 2,
            min_replicas: 1,
        }
    }

    fn policy(mode: PolicyMode) -> FleetPolicy {
        let mut p = FleetPolicy::new(mode, limits(), SloConfig::strict());
        // Deterministic unit tests: no debouncing.
        p.estimator.up_patience = 1;
        p.estimator.down_patience = 1;
        p.estimator.cooldown = 0.0;
        p.replica_cooldown = 0.0;
        p
    }

    fn load(id: usize, devices: usize, occ: f64, queue: usize) -> ReplicaLoad {
        ReplicaLoad {
            id,
            devices,
            occupancy: occ,
            queue_depth: queue,
            busy: false,
            booting: false,
            draining: false,
            parked: false,
            imbalance: 1.0,
            last_heartbeat: 0.0,
            role: PoolRole::Unified,
        }
    }

    fn pool_load(
        id: usize,
        role: PoolRole,
        devices: usize,
        occ: f64,
        queue: usize,
    ) -> ReplicaLoad {
        let mut l = load(id, devices, occ, queue);
        l.role = role;
        l
    }

    #[test]
    fn hybrid_prefers_vertical_on_the_hottest_replica() {
        let mut p = policy(PolicyMode::Hybrid);
        let loads = [load(0, 2, 0.9, 3), load(1, 2, 1.0, 20)];
        let a = p.decide_action(5.0, 0.5, &loads, 8);
        assert_eq!(
            a,
            FleetAction::VerticalUp {
                replica: 1,
                to_devices: 4
            }
        );
    }

    #[test]
    fn hybrid_falls_back_to_add_replica_when_vertical_exhausted() {
        let mut p = policy(PolicyMode::Hybrid);
        // Both replicas at the vertical ceiling.
        let loads = [load(0, 6, 1.0, 10), load(1, 6, 1.0, 10)];
        let a = p.decide_action(5.0, 0.5, &loads, 4);
        assert_eq!(a, FleetAction::AddReplica);
    }

    #[test]
    fn busy_or_cooling_vertical_headroom_holds_instead_of_cold_boot() {
        // Replica 0 is mid-scale, replica 1 just scaled (cooling down):
        // hybrid must wait for the fast vertical path, not cold-boot.
        let mut p = policy(PolicyMode::Hybrid);
        p.replica_cooldown = 100.0;
        p.note_event(1, 4.0);
        let mut busy = load(0, 4, 1.0, 20);
        busy.busy = true;
        let loads = [busy, load(1, 2, 1.0, 15)];
        assert_eq!(p.decide_action(5.0, 0.5, &loads, 6), FleetAction::Hold);
    }

    #[test]
    fn booting_replicas_headroom_does_not_serialise_adds() {
        // Both live replicas at the ceiling, a third still cold-booting:
        // its (not yet live) headroom must not block a concurrent add.
        let mut p = policy(PolicyMode::Hybrid);
        p.limits.pool_devices = 24;
        let mut boot = load(2, 2, 0.0, 0);
        boot.busy = true;
        boot.booting = true;
        let loads = [load(0, 6, 1.0, 20), load(1, 6, 1.0, 20), boot];
        assert_eq!(p.decide_action(5.0, 0.5, &loads, 10), FleetAction::AddReplica);
    }

    #[test]
    fn unactionable_trigger_is_refunded_and_retries_next_window() {
        let mut p = policy(PolicyMode::Hybrid);
        p.estimator.cooldown = 100.0;
        p.replica_cooldown = 0.0;
        // Trigger fires but the only replica is mid-scale: Hold + refund.
        let mut busy = load(0, 2, 1.0, 20);
        busy.busy = true;
        assert_eq!(p.decide_action(5.0, 0.5, &[busy], 6), FleetAction::Hold);
        // Next window the replica is free: despite the 100 s estimator
        // cooldown, the refunded trigger acts immediately.
        let loads = [load(0, 2, 1.0, 20)];
        assert_eq!(
            p.decide_action(10.0, 0.5, &loads, 6),
            FleetAction::VerticalUp {
                replica: 0,
                to_devices: 4
            }
        );
    }

    #[test]
    fn pool_budget_blocks_everything() {
        let mut p = policy(PolicyMode::Hybrid);
        let loads = [load(0, 6, 1.0, 10)];
        assert_eq!(p.decide_action(5.0, 0.5, &loads, 1), FleetAction::Hold);
    }

    #[test]
    fn horizontal_only_never_scales_vertically() {
        let mut p = policy(PolicyMode::HorizontalOnly);
        let loads = [load(0, 2, 1.0, 10)];
        assert_eq!(p.decide_action(5.0, 0.5, &loads, 8), FleetAction::AddReplica);
    }

    #[test]
    fn down_prefers_vertical_shrink_then_drain() {
        let mut p = policy(PolicyMode::Hybrid);
        // Grown replica present: shrink it first.
        let loads = [load(0, 4, 0.1, 0), load(1, 2, 0.1, 0)];
        let a = p.decide_action(5.0, 1.0, &loads, 0);
        assert_eq!(
            a,
            FleetAction::VerticalDown {
                replica: 0,
                to_devices: 2
            }
        );
        // All at base: drain the idler one (floor permitting).
        let mut p = policy(PolicyMode::Hybrid);
        let loads = [load(0, 2, 0.3, 0), load(1, 2, 0.05, 0)];
        let a = p.decide_action(5.0, 1.0, &loads, 0);
        assert_eq!(a, FleetAction::DrainReplica { replica: 1 });
    }

    #[test]
    fn min_replicas_floor_holds() {
        let mut p = policy(PolicyMode::Hybrid);
        let loads = [load(0, 2, 0.05, 0)];
        assert_eq!(p.decide_action(5.0, 1.0, &loads, 0), FleetAction::Hold);
    }

    #[test]
    fn skewed_replica_earns_a_rebalance_in_quiet_windows() {
        let mut p = policy(PolicyMode::Hybrid);
        // Healthy load (good attainment, mid occupancy, no queue) so the
        // estimator holds; replica 1's placement has drifted.
        let mut skew = load(1, 4, 0.5, 0);
        skew.imbalance = 2.0;
        let loads = [load(0, 4, 0.5, 0), skew];
        assert_eq!(
            p.decide_action(5.0, 1.0, &loads, 4),
            FleetAction::Rebalance { replica: 1 }
        );
        // The event starts the replica's cooldown.
        let mut p = policy(PolicyMode::Hybrid);
        p.replica_cooldown = 100.0;
        let mut skew = load(1, 4, 0.5, 0);
        skew.imbalance = 2.0;
        let loads = [load(0, 4, 0.5, 0), skew];
        assert_eq!(
            p.decide_action(5.0, 1.0, &loads, 4),
            FleetAction::Rebalance { replica: 1 }
        );
        assert_eq!(p.decide_action(10.0, 1.0, &loads, 4), FleetAction::Hold);
    }

    #[test]
    fn balanced_or_busy_replicas_do_not_rebalance() {
        let mut p = policy(PolicyMode::Hybrid);
        // Below threshold: hold.
        let mut mild = load(0, 4, 0.5, 0);
        mild.imbalance = 1.2;
        assert_eq!(p.decide_action(5.0, 1.0, &[mild], 4), FleetAction::Hold);
        // Above threshold but mid-transition: hold.
        let mut busy = load(0, 4, 0.5, 0);
        busy.imbalance = 3.0;
        busy.busy = true;
        assert_eq!(p.decide_action(10.0, 1.0, &[busy], 4), FleetAction::Hold);
        // Horizontal-only fleets cannot remap experts.
        let mut p = policy(PolicyMode::HorizontalOnly);
        let mut skew = load(0, 4, 0.5, 0);
        skew.imbalance = 3.0;
        assert_eq!(p.decide_action(5.0, 1.0, &[skew], 4), FleetAction::Hold);
    }

    #[test]
    fn scaling_pressure_outranks_rebalancing() {
        // A violating window scales up even on a skewed replica; the
        // rebalance only fires when the fleet is otherwise holding.
        let mut p = policy(PolicyMode::Hybrid);
        let mut skew = load(0, 2, 1.0, 20);
        skew.imbalance = 3.0;
        assert_eq!(
            p.decide_action(5.0, 0.5, &[skew], 8),
            FleetAction::VerticalUp {
                replica: 0,
                to_devices: 4
            }
        );
    }

    #[test]
    fn idle_replica_parks_when_reburst_is_forecast() {
        let mut p = policy(PolicyMode::Hybrid);
        p.park_enabled = true;
        p.park_ttl = 100.0;
        p.estimator.down_patience = 1;
        // Traffic seen at t=10 (non-NaN attainment)...
        let busy_load = [load(0, 2, 0.6, 0)];
        assert_eq!(p.decide_action(10.0, 1.0, &busy_load, 0), FleetAction::Hold);
        // ...then idle at t=40: park beats drain, even at the floor
        // (min_replicas = 1, single replica).
        let idle = [load(0, 2, 0.0, 0)];
        let a = p.decide_action(40.0, f64::NAN, &idle, 0);
        assert_eq!(a, FleetAction::Park { replica: 0 });
        // Beyond the TTL the forecast expires: drain path (blocked by
        // the floor here -> Hold).
        let mut p = policy(PolicyMode::Hybrid);
        p.park_enabled = true;
        p.park_ttl = 10.0;
        p.estimator.down_patience = 1;
        assert_eq!(p.decide_action(10.0, 1.0, &busy_load, 0), FleetAction::Hold);
        assert_eq!(p.decide_action(200.0, f64::NAN, &idle, 0), FleetAction::Hold);
    }

    #[test]
    fn parked_replica_is_the_first_choice_on_pressure() {
        let mut p = policy(PolicyMode::Hybrid);
        p.park_enabled = true;
        let mut parked = load(1, 0, 0.0, 0);
        parked.parked = true;
        // A violating window with vertical headroom available: unpark
        // still wins (cheapest capacity).
        let loads = [load(0, 2, 1.0, 20), parked];
        assert_eq!(
            p.decide_action(5.0, 0.5, &loads, 8),
            FleetAction::Unpark { replica: 1 }
        );
    }

    #[test]
    fn all_parked_fleet_wakes_on_queued_arrivals() {
        let mut p = policy(PolicyMode::Hybrid);
        p.park_enabled = true;
        let mut parked = load(0, 0, 0.0, 3); // arrivals queued in inbox
        parked.parked = true;
        assert_eq!(
            p.decide_action(5.0, f64::NAN, &[parked], 2),
            FleetAction::Unpark { replica: 0 }
        );
        // No queue: stay parked.
        let mut quiet = load(0, 0, 0.0, 0);
        quiet.parked = true;
        assert_eq!(p.decide_action(10.0, f64::NAN, &[quiet], 2), FleetAction::Hold);
        // Park disabled: an all-parked fleet (however it got there) holds.
        let mut p = policy(PolicyMode::Hybrid);
        let mut parked = load(0, 0, 0.0, 3);
        parked.parked = true;
        assert_eq!(p.decide_action(5.0, f64::NAN, &[parked], 2), FleetAction::Hold);
    }

    #[test]
    fn replica_cooldown_rotates_vertical_events() {
        let mut p = policy(PolicyMode::Hybrid);
        p.replica_cooldown = 100.0;
        let loads = [load(0, 2, 1.0, 20), load(1, 2, 0.9, 5)];
        let a = p.decide_action(5.0, 0.5, &loads, 8);
        assert_eq!(
            a,
            FleetAction::VerticalUp {
                replica: 0,
                to_devices: 4
            }
        );
        // Replica 0 is cooling down: the next event lands on replica 1.
        let loads = [load(0, 4, 1.0, 20), load(1, 2, 0.9, 5)];
        let a = p.decide_action(10.0, 0.5, &loads, 6);
        assert_eq!(
            a,
            FleetAction::VerticalUp {
                replica: 1,
                to_devices: 4
            }
        );
    }

    #[test]
    fn hold_projects_to_the_identity_spec() {
        let p = policy(PolicyMode::Hybrid);
        let mut draining = load(2, 2, 0.0, 0);
        draining.draining = true;
        let loads = [load(0, 4, 0.5, 0), load(1, 2, 0.5, 0), draining];
        let spec = p.project(FleetAction::Hold, &loads);
        // Draining replicas are already leaving: no slot for them.
        assert_eq!(spec.replicas.len(), 2);
        assert_eq!(spec.slot(0).unwrap().devices, 4);
        assert_eq!(spec.slot(1).unwrap().devices, 2);
        assert!(spec.slot(2).is_none());
        assert_eq!(spec.devices_total(), 6);
        assert_eq!(spec.parked_count(), 0);
        assert_eq!(spec.rebalance, None);
    }

    #[test]
    fn actions_project_as_one_slot_deltas() {
        let p = policy(PolicyMode::Hybrid);
        let loads = [load(0, 4, 0.5, 0), load(1, 2, 0.5, 0)];

        let up = p.project(
            FleetAction::VerticalUp { replica: 1, to_devices: 4 },
            &loads,
        );
        assert_eq!(up.slot(1).unwrap().devices, 4);
        assert_eq!(up.slot(0).unwrap().devices, 4, "other slots untouched");

        let drain =
            p.project(FleetAction::DrainReplica { replica: 0 }, &loads);
        assert!(drain.slot(0).is_none());
        assert_eq!(drain.replicas.len(), 1);

        let add = p.project(FleetAction::AddReplica, &loads);
        assert_eq!(add.replicas.len(), 3);
        let new = add.slot(2).unwrap();
        assert_eq!(new.devices, p.limits.replica_base);
        assert!(!new.parked);

        let park = p.project(FleetAction::Park { replica: 1 }, &loads);
        let s = park.slot(1).unwrap();
        assert!(s.parked);
        assert_eq!(s.devices, 0);
        assert_eq!(park.parked_count(), 1);

        let reb =
            p.project(FleetAction::Rebalance { replica: 0 }, &loads);
        assert_eq!(reb.rebalance, Some(0));
        assert_eq!(reb.replicas.len(), 2, "rebalance keeps the identity");
    }

    #[test]
    fn unpark_projects_to_an_unparked_slot_at_unknown_size() {
        let p = policy(PolicyMode::Hybrid);
        let mut parked = load(1, 0, 0.0, 2);
        parked.parked = true;
        let loads = [load(0, 2, 0.5, 0), parked];
        let spec =
            p.project(FleetAction::Unpark { replica: 1 }, &loads);
        let s = spec.slot(1).unwrap();
        assert!(!s.parked);
        // devices 0 = "resume at the simulator-known pre-park size".
        assert_eq!(s.devices, 0);
    }

    #[test]
    fn decide_returns_the_projected_spec() {
        let mut p = policy(PolicyMode::Hybrid);
        let loads = [load(0, 2, 0.9, 3), load(1, 2, 1.0, 20)];
        let spec = p.decide(5.0, 0.5, &loads, 8);
        // Same observation as decide_action: VerticalUp on replica 1.
        assert_eq!(spec.slot(1).unwrap().devices, 4);
        assert_eq!(spec.slot(0).unwrap().devices, 2);
    }

    #[test]
    fn decisions_are_explained_and_drained() {
        let mut p = policy(PolicyMode::Hybrid);
        let loads = [load(0, 2, 1.0, 20)];
        let a = p.decide_action(5.0, 0.5, &loads, 8);
        assert!(matches!(a, FleetAction::VerticalUp { .. }));
        let ex = p.take_explains();
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].decision, "up");
        assert_eq!(ex[0].pool, "unified");
        assert_eq!(ex[0].action, "grow r0->4dev");
        assert_eq!(ex[0].serving, 1);
        assert_eq!(ex[0].queue, 20);
        // queue >= pressure_queue clamps the fed attainment to 0.
        assert_eq!(ex[0].attainment, 0.0);
        assert!(!ex[0].vetoed);
        assert!(p.take_explains().is_empty(), "drain empties the buffer");
    }

    #[test]
    fn refused_trigger_is_marked_vetoed() {
        let mut p = policy(PolicyMode::Hybrid);
        let mut busy = load(0, 2, 1.0, 20);
        busy.busy = true;
        assert_eq!(p.decide_action(5.0, 0.5, &[busy], 6), FleetAction::Hold);
        let ex = p.take_explains();
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].decision, "up");
        assert!(ex[0].vetoed);
        assert_eq!(ex[0].action, "hold");
    }

    #[test]
    fn nan_attainment_is_encoded_for_json() {
        let mut p = policy(PolicyMode::Hybrid);
        let loads = [load(0, 2, 0.1, 0)];
        p.decide_action(5.0, f64::NAN, &loads, 4);
        let ex = p.take_explains();
        assert_eq!(ex[0].attainment, -1.0);
    }

    #[test]
    fn pool_decisions_carry_the_pool_label() {
        let mut p = policy(PolicyMode::Hybrid);
        tune_pool_estimators(&mut p);
        let loads = [
            pool_load(0, PoolRole::Prefill, 2, 1.0, 20),
            pool_load(1, PoolRole::Decode, 2, 0.3, 0),
        ];
        p.decide(5.0, 0.5, &loads, 8);
        let ex = p.take_explains();
        assert_eq!(ex.len(), 2, "one explain per pool kernel call");
        assert_eq!(ex[0].pool, "prefill");
        assert_eq!(ex[1].pool, "decode");
    }

    fn tune_pool_estimators(p: &mut FleetPolicy) {
        for e in [&mut p.prefill_estimator, &mut p.decode_estimator] {
            e.up_patience = 1;
            e.down_patience = 1;
            e.cooldown = 0.0;
        }
    }

    #[test]
    fn long_prompt_pressure_scales_only_the_prefill_pool() {
        let mut p = policy(PolicyMode::Hybrid);
        tune_pool_estimators(&mut p);
        // Prefill pool drowning in queued prompts; decode pool relaxed.
        let loads = [
            pool_load(0, PoolRole::Prefill, 2, 1.0, 20),
            pool_load(1, PoolRole::Decode, 2, 0.3, 0),
        ];
        let spec = p.decide(5.0, 0.5, &loads, 8);
        assert_eq!(spec.slot(0).unwrap().devices, 4, "prefill grew");
        assert_eq!(
            spec.slot(0).unwrap().role,
            PoolRole::Prefill,
            "role survives projection"
        );
        assert_eq!(
            spec.slot(1).unwrap().devices,
            2,
            "unpressured decode pool must not ride the violation"
        );
    }

    #[test]
    fn decode_saturation_scales_only_the_decode_pool() {
        let mut p = policy(PolicyMode::Hybrid);
        tune_pool_estimators(&mut p);
        // Decode batches saturated (long generations); prefill idle.
        let loads = [
            pool_load(0, PoolRole::Prefill, 2, 0.2, 0),
            pool_load(1, PoolRole::Decode, 2, 1.0, 12),
        ];
        let spec = p.decide(5.0, 0.5, &loads, 8);
        assert_eq!(spec.slot(0).unwrap().devices, 2, "prefill holds");
        assert_eq!(spec.slot(1).unwrap().devices, 4, "decode grew");
    }

    #[test]
    fn pool_add_replica_inherits_the_pool_role_and_a_global_id() {
        let mut p = policy(PolicyMode::Hybrid);
        tune_pool_estimators(&mut p);
        // Prefill replica 0 is at the vertical ceiling: the pool falls
        // back to a horizontal add. The fresh slot must carry the pool's
        // role and an id above every live replica (including decode's).
        let loads = [
            pool_load(0, PoolRole::Prefill, 6, 1.0, 20),
            pool_load(7, PoolRole::Decode, 2, 0.3, 0),
        ];
        let spec = p.decide(5.0, 0.5, &loads, 6);
        let fresh = spec.slot(8).expect("new slot at global max id + 1");
        assert_eq!(fresh.role, PoolRole::Prefill);
        assert_eq!(fresh.devices, p.limits.replica_base);
    }

    #[test]
    fn idle_pool_shrinks_while_the_other_pool_is_violating() {
        let mut p = policy(PolicyMode::Hybrid);
        tune_pool_estimators(&mut p);
        p.limits.min_replicas = 1;
        // Decode grew to 4 devices earlier, now idle; prefill pressured.
        // Fleet attainment is violating, but the idle decode pool must
        // observe healthy windows and give its vertical step back.
        let loads = [
            pool_load(0, PoolRole::Prefill, 2, 1.0, 20),
            pool_load(1, PoolRole::Decode, 4, 0.1, 0),
        ];
        let spec = p.decide(5.0, 0.5, &loads, 2);
        assert_eq!(spec.slot(0).unwrap().devices, 4, "prefill grew");
        assert_eq!(spec.slot(1).unwrap().devices, 2, "idle decode shrank");
    }

    #[test]
    fn pool_budget_is_shared_across_pools_in_role_order() {
        let mut p = policy(PolicyMode::Hybrid);
        tune_pool_estimators(&mut p);
        // Both pools pressured but only one step of budget: prefill
        // (decided first) takes it; decode's trigger is refunded.
        let loads = [
            pool_load(0, PoolRole::Prefill, 2, 1.0, 20),
            pool_load(1, PoolRole::Decode, 2, 1.0, 15),
        ];
        let spec = p.decide(5.0, 0.5, &loads, 2);
        assert_eq!(spec.slot(0).unwrap().devices, 4);
        assert_eq!(spec.slot(1).unwrap().devices, 2);
        assert_eq!(spec.devices_total(), 6);
    }
}
