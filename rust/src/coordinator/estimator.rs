//! SLO-aware load estimator (§4.3): tracks windowed SLO attainment and
//! queue pressure, triggering scale-up on persistent violations and
//! scale-down on sustained over-provisioning, with hysteresis and cooldown
//! (the paper's antidote to "aggressive cooldown timers" is fast scaling,
//! but the estimator still debounces).

use crate::config::SloConfig;

/// Autoscaling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Up,
    Down,
    Hold,
}

/// Windowed SLO estimator with hysteresis.
#[derive(Debug, Clone)]
pub struct LoadEstimator {
    pub slo: SloConfig,
    /// Consecutive bad windows before scaling up.
    pub up_patience: u32,
    /// Consecutive comfortable windows before scaling down.
    pub down_patience: u32,
    /// Seconds between scaling actions.
    pub cooldown: f64,
    /// Occupancy (running/batch-capacity) below which down-scaling is
    /// considered.
    pub down_occupancy: f64,
    bad_windows: u32,
    good_windows: u32,
    last_action: f64,
    /// Last window that showed live traffic (finished requests, queued
    /// work, or meaningful occupancy). Drives the re-burst forecast for
    /// park-vs-teardown decisions.
    last_active: f64,
    /// Direction re-armed by [`Self::refund`]: that direction (and only
    /// that direction) may fire through the still-running cooldown. The
    /// opposite direction keeps its full debounce.
    rearmed: Option<ScaleDecision>,
}

impl LoadEstimator {
    pub fn new(slo: SloConfig) -> Self {
        LoadEstimator {
            slo,
            up_patience: 2,
            down_patience: 6,
            cooldown: 30.0,
            down_occupancy: 0.35,
            bad_windows: 0,
            good_windows: 0,
            last_action: f64::NEG_INFINITY,
            last_active: f64::NEG_INFINITY,
            rearmed: None,
        }
    }

    /// Feed one window's observation. `attainment` may be NaN (no traffic).
    pub fn observe(
        &mut self,
        now: f64,
        attainment: f64,
        occupancy: f64,
        queue_depth: usize,
    ) -> ScaleDecision {
        if !attainment.is_nan() || queue_depth > 0 || occupancy > 0.05 {
            self.last_active = now;
        }
        let cooling = now - self.last_action < self.cooldown;
        if cooling && self.rearmed.is_none() {
            return ScaleDecision::Hold;
        }
        if !cooling {
            self.rearmed = None;
        }
        let violating = !attainment.is_nan()
            && attainment < self.slo.target_attainment;
        let pressured = queue_depth > 0 && attainment.is_nan();
        if violating || pressured {
            self.bad_windows += 1;
            self.good_windows = 0;
        } else if !attainment.is_nan() || queue_depth == 0 {
            self.good_windows += 1;
            self.bad_windows = 0;
        }
        if self.bad_windows >= self.up_patience
            && (!cooling || self.rearmed == Some(ScaleDecision::Up))
        {
            self.bad_windows = 0;
            self.good_windows = 0;
            self.last_action = now;
            self.rearmed = None;
            return ScaleDecision::Up;
        }
        if self.good_windows >= self.down_patience
            && occupancy < self.down_occupancy
            && queue_depth == 0
            && (!cooling || self.rearmed == Some(ScaleDecision::Down))
        {
            self.good_windows = 0;
            self.last_action = now;
            self.rearmed = None;
            return ScaleDecision::Down;
        }
        ScaleDecision::Hold
    }

    pub fn reset(&mut self) {
        self.bad_windows = 0;
        self.good_windows = 0;
        self.rearmed = None;
    }

    /// Consecutive violating windows accumulated toward `up_patience`.
    pub fn bad_windows(&self) -> u32 {
        self.bad_windows
    }

    /// Consecutive comfortable windows accumulated toward `down_patience`.
    pub fn good_windows(&self) -> u32 {
        self.good_windows
    }

    /// Whether the post-action cooldown is still running at `now`.
    pub fn is_cooling(&self, now: f64) -> bool {
        now - self.last_action < self.cooldown
    }

    /// Direction re-armed by [`Self::refund`], if any.
    pub fn rearmed(&self) -> Option<ScaleDecision> {
        self.rearmed
    }

    /// Whether traffic is forecast to return within `ttl` seconds of
    /// `now`: a keep-warm heuristic in the serverless tradition —
    /// recently active workloads are the ones that re-burst, so a
    /// replica idled by an on/off trace should park (weights
    /// DRAM-resident) rather than tear down. Never true before any
    /// traffic was seen.
    pub fn forecasts_reburst(&self, now: f64, ttl: f64) -> bool {
        now - self.last_active <= ttl
    }

    /// Undo the state consumption of an `Up`/`Down` decision the caller
    /// could not act on (no eligible replica, pool exhausted): re-arms
    /// the patience counter so one more matching window re-fires that
    /// same direction through the cooldown, instead of waiting out a
    /// full cooldown + patience cycle while the condition persists. Only
    /// the refunded direction is re-armed — the cooldown stamp stays
    /// put, so the *opposite* direction keeps its full debounce (a dead
    /// `Up` must not let a `Down` fire one window later).
    pub fn refund(&mut self, decision: ScaleDecision) {
        match decision {
            ScaleDecision::Up => {
                self.bad_windows = self.up_patience.saturating_sub(1);
                self.rearmed = Some(ScaleDecision::Up);
            }
            ScaleDecision::Down => {
                self.good_windows = self.down_patience.saturating_sub(1);
                self.rearmed = Some(ScaleDecision::Down);
            }
            ScaleDecision::Hold => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est() -> LoadEstimator {
        let mut e = LoadEstimator::new(SloConfig::strict());
        e.cooldown = 0.0;
        e
    }

    #[test]
    fn scale_up_after_persistent_violations() {
        let mut e = est();
        assert_eq!(e.observe(0.0, 0.5, 0.9, 10), ScaleDecision::Hold);
        assert_eq!(e.observe(1.0, 0.6, 0.9, 10), ScaleDecision::Up);
        // Counter reset after action.
        assert_eq!(e.observe(2.0, 0.5, 0.9, 10), ScaleDecision::Hold);
    }

    #[test]
    fn one_bad_window_is_not_enough() {
        let mut e = est();
        assert_eq!(e.observe(0.0, 0.5, 0.9, 5), ScaleDecision::Hold);
        assert_eq!(e.observe(1.0, 0.99, 0.9, 0), ScaleDecision::Hold);
        assert_eq!(e.observe(2.0, 0.5, 0.9, 5), ScaleDecision::Hold);
    }

    #[test]
    fn scale_down_needs_low_occupancy_and_patience() {
        let mut e = est();
        for i in 0..5 {
            assert_eq!(
                e.observe(i as f64, 1.0, 0.2, 0),
                ScaleDecision::Hold
            );
        }
        assert_eq!(e.observe(5.0, 1.0, 0.2, 0), ScaleDecision::Down);
        // High occupancy blocks down-scaling.
        let mut e2 = est();
        for i in 0..20 {
            assert_eq!(
                e2.observe(i as f64, 1.0, 0.8, 0),
                ScaleDecision::Hold
            );
        }
    }

    #[test]
    fn refund_rearms_an_unactionable_trigger() {
        let mut e = LoadEstimator::new(SloConfig::strict());
        e.cooldown = 100.0;
        assert_eq!(e.observe(0.0, 0.5, 0.9, 10), ScaleDecision::Hold);
        assert_eq!(e.observe(1.0, 0.5, 0.9, 10), ScaleDecision::Up);
        // Caller couldn't act: refund. The very next bad window re-fires
        // despite the long cooldown.
        e.refund(ScaleDecision::Up);
        assert_eq!(e.observe(2.0, 0.5, 0.9, 10), ScaleDecision::Up);
    }

    #[test]
    fn up_refund_does_not_disarm_the_down_cooldown() {
        let mut e = LoadEstimator::new(SloConfig::strict());
        e.cooldown = 100.0;
        e.up_patience = 1;
        e.down_patience = 1;
        assert_eq!(e.observe(0.0, 0.5, 0.9, 10), ScaleDecision::Up);
        e.refund(ScaleDecision::Up);
        // One comfortable window inside the cooldown: the old refund
        // wiped `last_action`, letting this fire an undebounced Down.
        assert_eq!(
            e.observe(1.0, 1.0, 0.1, 0),
            ScaleDecision::Hold,
            "a refunded Up must not unlock the opposite direction"
        );
        // The refunded direction itself still re-fires through the
        // cooldown on the next matching window.
        assert_eq!(e.observe(2.0, 0.5, 0.9, 10), ScaleDecision::Up);
        // And after firing, the cooldown debounces normally again.
        assert_eq!(e.observe(3.0, 0.5, 0.9, 10), ScaleDecision::Hold);
    }

    #[test]
    fn reburst_forecast_tracks_recent_traffic() {
        let mut e = est();
        // No traffic ever seen: never forecast.
        assert!(!e.forecasts_reburst(0.0, 1000.0));
        e.observe(10.0, 0.95, 0.5, 0); // live traffic
        assert!(e.forecasts_reburst(50.0, 120.0));
        assert!(!e.forecasts_reburst(200.0, 120.0), "warmth expires");
        // Idle windows (NaN attainment, nothing queued) don't refresh.
        e.observe(60.0, f64::NAN, 0.0, 0);
        assert!(!e.forecasts_reburst(200.0, 120.0));
        // Queued work alone counts as activity.
        e.observe(300.0, f64::NAN, 0.0, 3);
        assert!(e.forecasts_reburst(310.0, 60.0));
    }

    #[test]
    fn cooldown_debounces() {
        let mut e = LoadEstimator::new(SloConfig::strict());
        e.cooldown = 100.0;
        e.up_patience = 1;
        assert_eq!(e.observe(0.0, 0.1, 0.9, 10), ScaleDecision::Up);
        assert_eq!(e.observe(10.0, 0.1, 0.9, 10), ScaleDecision::Hold);
        assert_eq!(e.observe(150.0, 0.1, 0.9, 10), ScaleDecision::Up);
    }
}
