//! The Coordinator (§4.3): entry point for requests, SLO monitoring, and
//! scaling orchestration. [`ServingSim`] is the discrete-event serving loop
//! used by every paper experiment; [`LoadEstimator`] is the SLO-aware
//! autoscaling trigger.

pub mod estimator;
pub mod serving;

pub use estimator::{LoadEstimator, ScaleDecision};
pub use serving::{ServingSim, SimOutput, Trigger};
