//! The Coordinator (§4.3): entry point for requests, SLO monitoring, and
//! scaling orchestration. [`ServingSim`] is the discrete-event serving loop
//! used by every paper experiment; [`LoadEstimator`] is the SLO-aware
//! autoscaling trigger. Above the single instance, [`FleetSim`] runs N
//! replicas behind a pluggable [`Router`] with a [`FleetPolicy`] choosing
//! per window between vertical steps, whole-replica add/drain, and hold —
//! the hybrid deployment shape the paper's §2 motivates.

pub mod estimator;
pub mod fleet;
pub mod policy;
pub mod reconciler;
pub mod reference;
pub mod serving;

pub use estimator::{LoadEstimator, ScaleDecision};
pub use fleet::{FleetOutput, FleetSim, Router};
pub use policy::{
    DecisionExplain, FleetAction, FleetLimits, FleetPolicy, FleetSpec,
    PolicyMode, PoolRole, ReplicaLoad, ReplicaSpec,
};
pub use reconciler::{ReconcileStep, Reconciler};
pub use reference::{
    compare_cores, telemetry_overhead, CoreComparison, TelemetryOverhead,
};
pub use serving::{ServingSim, SimOutput, Trigger};
