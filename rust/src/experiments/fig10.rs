//! Fig 10: SLO compliance vs request rate (DSv2-Lite, TTFT<=1s, TPOT<=1s,
//! 2000-token prompts, 500-750 decode). A scale-up command fires at a
//! fixed time; horizontal is excluded (infeasible in this setup), matching
//! the paper.

use anyhow::Result;

use crate::config::model::dsv2_lite;
use crate::config::SloConfig;
use crate::coordinator::{ServingSim, Trigger};
use crate::device::Timings;
use crate::engine::CostModel;
use crate::util::table::{f, Table};
use crate::workload::{RateProfile, WorkloadGen, WorkloadSpec};

use super::common::{display_name, make_method, par};

const COMMAND_AT: f64 = 30.0;
const HORIZON: f64 = 300.0;

pub fn slo_at_rps(method: &str, rps: f64, decode_scale: f64) -> Result<f64> {
    let m = dsv2_lite();
    let slo = SloConfig::strict();
    let mut meth = make_method(method, &m, 6)?;
    let sim = ServingSim::new(
        CostModel::new(m.clone(), Timings::cloudmatrix()),
        slo,
    );
    let mut g = WorkloadGen::new(WorkloadSpec {
        prompt_len: 2000,
        decode_min: (500.0 * decode_scale) as usize,
        decode_max: (750.0 * decode_scale) as usize,
        profile: RateProfile::Fixed(rps),
        seed: 23,
    });
    let arrivals = g.arrivals_until(HORIZON);
    let out = sim.run(
        meth.as_mut(),
        &par(&m, 4)?,
        arrivals,
        Trigger::Manual(vec![(COMMAND_AT, par(&m, 6)?)]),
        HORIZON,
    )?;
    Ok(out
        .recorder
        .attainment_by_arrival(0.0, HORIZON, &slo))
}

pub fn run(opts: &super::common::ExpOptions) -> Result<String> {
    let fast = opts.fast;
    // Decode lengths are scaled down in fast mode to keep CI quick; the
    // qualitative knee ordering is unchanged.
    let decode_scale = if fast { 0.2 } else { 0.4 };
    let rates: &[f64] = if fast {
        &[1.0, 4.0, 8.0]
    } else {
        &[0.5, 1.0, 2.0, 4.0, 6.0, 8.0, 10.0, 12.0]
    };
    let methods = ["elastic", "cold", "colocated"];
    let mut table = Table::new(
        "Fig 10: SLO compliance (%) vs RPS — dsv2lite, TTFT≤1s TPOT≤1s",
    )
    .header(
        std::iter::once("RPS".to_string())
            .chain(methods.iter().map(|m| display_name(m).to_string())),
    );
    for &rps in rates {
        let mut cells = vec![format!("{rps}")];
        for name in methods {
            let att = slo_at_rps(name, rps, decode_scale)?;
            cells.push(if att.is_nan() {
                "-".into()
            } else {
                f(att * 100.0, 1)
            });
        }
        table.row(cells);
    }
    let mut out = table.render();
    out.push_str(
        "\nExpected shape: ElasticMoE holds ≥90% to the highest RPS knee; \
         Naive Cold Start degrades steadily with load (downtime backlog); \
         Concurrent/Colocated collapses early (permanently shrunken KV).\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_ordering_under_pressure() {
        // Colocated's permanently shrunken KV and Cold Restart's downtime
        // bite once the load approaches capacity.
        let e = slo_at_rps("elastic", 8.0, 0.2).unwrap();
        let c = slo_at_rps("cold", 8.0, 0.2).unwrap();
        let l = slo_at_rps("colocated", 8.0, 0.2).unwrap();
        // Cold Restart's downtime must cost it outright; colocated's
        // derated transition may or may not bite at this load (its
        // collapse in the paper needs KV-heavy models), so allow ties.
        assert!(e > c, "elastic {e} vs cold {c}");
        assert!(e + 0.03 >= l, "elastic {e} vs colocated {l}");
    }

    #[test]
    fn elastic_sustains_low_load_perfectly() {
        let e = slo_at_rps("elastic", 1.0, 0.2).unwrap();
        assert!(e > 0.9, "{e}");
    }
}
