//! Fleet scenarios (beyond the paper): N elastically resizable replicas
//! behind a router, a hybrid vertical×horizontal policy, and diverse
//! traffic — diurnal, flash-crowd and multi-tenant mixes. Demonstrates the
//! paper's §2 argument at deployment scale: fast vertical steps absorb
//! bursts that replica-granular horizontal autoscaling can only chase with
//! cold boots.

use anyhow::Result;

use crate::config::model::dsv2_lite;
use crate::config::SloConfig;
use crate::coordinator::{
    FleetAction, FleetLimits, FleetOutput, FleetPolicy, FleetSim,
    PolicyMode, Router,
};
use crate::device::Timings;
use crate::engine::CostModel;
use crate::hmm::control::HmmOptions;
use crate::imm::manager::ImmOptions;
use crate::scaling::{ColdRestart, ScalingMethod};
use crate::util::table::{f, Table};
use crate::workload::{
    MultiTenantGen, RateProfile, Request, TenantSpec, WorkloadGen,
    WorkloadSpec,
};

use super::common::{elastic_with_opts, KV_BYTES};

const REPLICA_MAX: usize = 8;

fn limits() -> FleetLimits {
    FleetLimits {
        pool_devices: 12,
        replica_base: 2,
        replica_max: REPLICA_MAX,
        step: 2,
        min_replicas: 2,
    }
}

fn policy(mode: PolicyMode) -> FleetPolicy {
    let mut p =
        FleetPolicy::new(mode, limits(), SloConfig::scale_up_demo());
    p.estimator.up_patience = 1;
    p.estimator.cooldown = 10.0;
    p.replica_cooldown = 10.0;
    p
}

fn sim(router: Router) -> FleetSim {
    FleetSim::new(
        CostModel::new(dsv2_lite(), Timings::cloudmatrix()),
        SloConfig::scale_up_demo(),
        router,
    )
}

fn elastic_factory(
) -> impl FnMut(usize) -> Result<Box<dyn ScalingMethod>> {
    move |_| {
        Ok(Box::new(elastic_with_opts(
            &dsv2_lite(),
            REPLICA_MAX,
            HmmOptions::default(),
            ImmOptions::default(),
        )) as Box<dyn ScalingMethod>)
    }
}

fn cold_factory() -> impl FnMut(usize) -> Result<Box<dyn ScalingMethod>> {
    use std::cell::RefCell;
    use std::rc::Rc;
    move |_| {
        let c = Rc::new(RefCell::new(crate::device::Cluster::cloudmatrix(
            REPLICA_MAX,
        )));
        Ok(Box::new(ColdRestart::new(c, dsv2_lite(), KV_BYTES))
            as Box<dyn ScalingMethod>)
    }
}

fn workload(profile: RateProfile, seed: u64, horizon: f64) -> Vec<Request> {
    let mut g = WorkloadGen::new(WorkloadSpec {
        prompt_len: 2000,
        decode_min: 100,
        decode_max: 150,
        profile,
        seed,
    });
    g.arrivals_until(horizon)
}

fn summarize(out: &FleetOutput) -> (usize, usize, usize, usize) {
    let v_up = out.count_actions(|a| {
        matches!(a, FleetAction::VerticalUp { .. })
    });
    let v_down = out.count_actions(|a| {
        matches!(a, FleetAction::VerticalDown { .. })
    });
    let peak = out
        .device_timeline
        .iter()
        .map(|&(_, d)| d)
        .max()
        .unwrap_or(0);
    (v_up, v_down, out.cold_boots, peak)
}

/// The fleet scenario suite: flash crowd (hybrid vs horizontal-only vs
/// vertical-only), diurnal tracking, and a multi-tenant mix. The shared
/// `--seed` (see [`super::common::ExpOptions`]) perturbs every workload
/// generator so a failing run is reproducible from its printed value;
/// unset keeps the canonical seeds.
pub fn run(opts: &super::common::ExpOptions) -> Result<String> {
    let fast = opts.fast;
    let base = opts.seed.unwrap_or(0);
    let mut report = String::new();

    // Scenario 1 — flash crowd (§2.2's "10x within minutes").
    let horizon = if fast { 180.0 } else { 300.0 };
    let burst = RateProfile::Burst {
        base: 0.8,
        factor: 10.0,
        start: 60.0,
        len: if fast { 45.0 } else { 90.0 },
    };
    let slo = SloConfig::scale_up_demo();
    let mut table = Table::new(
        "Fleet: flash crowd x10 — 2 replicas, 12-device pool, JSQ router",
    )
    .header([
        "policy",
        "SLO %",
        "vert up",
        "vert down",
        "cold boots",
        "peak devices",
        "unserved",
    ]);
    for (label, mode) in [
        ("hybrid (ElasticMoE)", PolicyMode::Hybrid),
        ("vertical-only", PolicyMode::VerticalOnly),
        ("horizontal-only", PolicyMode::HorizontalOnly),
    ] {
        let s = sim(Router::JoinShortestQueue);
        let mut p = policy(mode);
        let out = if mode == PolicyMode::HorizontalOnly {
            s.run(
                &mut p,
                &mut cold_factory(),
                2,
                workload(burst.clone(), 17 ^ base, horizon),
                horizon,
            )?
        } else {
            s.run(
                &mut p,
                &mut elastic_factory(),
                2,
                workload(burst.clone(), 17 ^ base, horizon),
                horizon,
            )?
        };
        let att =
            out.recorder.attainment_by_arrival(0.0, horizon, &slo);
        let (v_up, v_down, boots, peak) = summarize(&out);
        table.row([
            label.to_string(),
            f(att * 100.0, 1),
            v_up.to_string(),
            v_down.to_string(),
            boots.to_string(),
            peak.to_string(),
            out.truncated.to_string(),
        ]);
    }
    report.push_str(&table.render());
    report.push_str(
        "\nExpected shape: hybrid absorbs the burst with vertical steps \
         (0 cold boots) and the highest SLO attainment; horizontal-only \
         pays whole-replica cold boots that land after the burst.\n\n",
    );

    // Scenario 2 — diurnal cycle: the fleet breathes with the day.
    let horizon2 = if fast { 240.0 } else { 480.0 };
    let diurnal = RateProfile::Diurnal {
        base: 1.2,
        amp: 0.9,
        period: horizon2 / 2.0,
    };
    let s = sim(Router::RoundRobin);
    let mut p = policy(PolicyMode::Hybrid);
    let out = s.run(
        &mut p,
        &mut elastic_factory(),
        2,
        workload(diurnal, 31 ^ base, horizon2),
        horizon2,
    )?;
    let att = out.recorder.attainment_by_arrival(0.0, horizon2, &slo);
    let (v_up, v_down, boots, peak) = summarize(&out);
    let min_dev = out
        .device_timeline
        .iter()
        .map(|&(_, d)| d)
        .min()
        .unwrap_or(0);
    let mut t2 = Table::new(
        "Fleet: diurnal cycle — hybrid policy, round-robin router",
    )
    .header(["SLO %", "vert up", "vert down", "cold boots", "devices min..peak"]);
    t2.row([
        f(att * 100.0, 1),
        v_up.to_string(),
        v_down.to_string(),
        boots.to_string(),
        format!("{min_dev}..{peak}"),
    ]);
    report.push_str(&t2.render());
    report.push_str(
        "\nExpected shape: devices track the sinusoid (grow at the crest, \
         shrink in the trough) without replica churn.\n\n",
    );

    // Scenario 3 — tenant mix: chat (strict SLO) + agent (relaxed SLO),
    // session-affinity routing, per-tenant attainment.
    let horizon3 = if fast { 150.0 } else { 300.0 };
    let tenants = MultiTenantGen::new(vec![
        TenantSpec::new(
            "chat",
            WorkloadSpec {
                prompt_len: 1000,
                decode_min: 50,
                decode_max: 100,
                profile: RateProfile::Fixed(0.8),
                seed: 41 ^ base,
            },
            SloConfig::strict(),
        ),
        TenantSpec::new(
            "agent",
            WorkloadSpec {
                prompt_len: 3000,
                decode_min: 200,
                decode_max: 300,
                profile: RateProfile::Burst {
                    base: 0.3,
                    factor: 6.0,
                    start: horizon3 / 3.0,
                    len: horizon3 / 5.0,
                },
                seed: 43 ^ base,
            },
            SloConfig::new(8.0, 2.0),
        ),
    ]);
    let s = sim(Router::SessionAffinity);
    let mut p = policy(PolicyMode::Hybrid);
    let arrivals = tenants.arrivals_until(horizon3);
    let out = s.run(&mut p, &mut elastic_factory(), 2, arrivals, horizon3)?;
    let mut t3 = Table::new(
        "Fleet: tenant mix — session-affinity router, per-tenant SLOs",
    )
    .header(["tenant", "SLO", "attainment %"]);
    for (i, t) in tenants.tenants.iter().enumerate() {
        let att = out.recorder.attainment_for_tenant(i as u32, &t.slo);
        t3.row([
            t.name.clone(),
            format!("TTFT<={}s TPOT<={}s", t.slo.ttft, t.slo.tpot),
            if att.is_nan() {
                "-".into()
            } else {
                f(att * 100.0, 1)
            },
        ]);
    }
    report.push_str(&t3.render());
    report.push_str(
        "\nExpected shape: the agent tenant's burst is absorbed without \
         dragging the chat tenant below its stricter SLO.\n",
    );

    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_report_renders_all_three_scenarios() {
        let r = run(&super::common::ExpOptions::fast(true)).unwrap();
        assert!(r.contains("flash crowd"));
        assert!(r.contains("diurnal"));
        assert!(r.contains("tenant mix"));
        assert!(r.contains("hybrid (ElasticMoE)"));
    }
}
