//! Fig 8: peak memory during scale-up (DSv2-Lite, 4->6 NPUs) across
//! methods, summed over all involved NPUs.

use anyhow::Result;

use crate::config::model::dsv2_lite;
use crate::util::table::{f, Table};

use super::common::{display_name, make_method, par, par_on, METHODS};

pub fn run() -> Result<String> {
    let m = dsv2_lite();
    let (from_n, to_n) = (4usize, 6);
    let mut table = Table::new(
        "Fig 8: scale-up peak memory (GB, summed over involved NPUs) — \
         dsv2lite 4→6",
    )
    .header(["method", "peak (GB)", "devices involved", "downtime (s)"]);

    for &name in METHODS {
        let outcome = match name {
            "horizontal" => {
                // 4->6 is not a doubling; the paper shows horizontal's peak
                // for its smallest feasible step (4->8).
                let mut meth = make_method(name, &m, 8)?;
                meth.boot(&par(&m, from_n)?)?;
                meth.scale(&par_on(&m, 4..8)?)?
            }
            "extravagant" => {
                let mut meth = make_method(name, &m, from_n + to_n)?;
                meth.boot(&par(&m, from_n)?)?;
                meth.scale(&par_on(&m, from_n..from_n + to_n)?)?
            }
            _ => {
                let mut meth = make_method(name, &m, to_n)?;
                meth.boot(&par(&m, from_n)?)?;
                meth.scale(&par(&m, to_n)?)?
            }
        };
        table.row([
            display_name(name).to_string(),
            f(outcome.metrics.peak_gb(), 1),
            outcome.peak_devices.to_string(),
            f(outcome.metrics.downtime, 1),
        ]);
    }
    let mut out = table.render();
    out.push_str(
        "\nExpected shape: Horizontal/Extravagant highest (full second \
         instance in parallel); Cold Restart lowest (teardown first) but \
         with downtime; ElasticMoE within a few % of Cold Restart with \
         zero downtime (paper: 2-3% higher, 35-40% below Extravagant).\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig 8 ordering, asserted end-to-end.
    #[test]
    fn peak_memory_ordering_matches_paper() {
        let m = dsv2_lite();
        let peak = |name: &str| -> f64 {
            let out = match name {
                "extravagant" => {
                    let mut meth = make_method(name, &m, 10).unwrap();
                    meth.boot(&par(&m, 4).unwrap()).unwrap();
                    meth.scale(&par_on(&m, 4..10).unwrap()).unwrap()
                }
                _ => {
                    let mut meth = make_method(name, &m, 6).unwrap();
                    meth.boot(&par(&m, 4).unwrap()).unwrap();
                    meth.scale(&par(&m, 6).unwrap()).unwrap()
                }
            };
            out.metrics.peak_gb()
        };
        let elastic = peak("elastic");
        let cold = peak("cold");
        let extravagant = peak("extravagant");
        let colocated = peak("colocated");
        // Cold lowest; elastic within 10% of cold; extravagant well above.
        assert!(elastic < cold * 1.15, "elastic {elastic} vs cold {cold}");
        assert!(
            extravagant > elastic * 1.25,
            "extravagant {extravagant} vs elastic {elastic}"
        );
        assert!(colocated > cold, "colocated {colocated} vs cold {cold}");
    }
}
