//! Shared experiment plumbing: method construction, standard configs.

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::config::model::{self, ModelConfig};
use crate::config::ParallelConfig;
use crate::device::{Cluster, Timings};
use crate::hmm::control::{HmmControl, HmmOptions};
use crate::imm::manager::{ImmOptions, InstanceManager};
use crate::scaling::{
    ColdRestart, Colocated, ElasticMoE, Extravagant, Horizontal,
    ScalingMethod,
};
use crate::util::cli::Args;

/// Standard per-device KV reservation used by the scaling experiments.
pub const KV_BYTES: u64 = 8 << 30;

/// The flags every experiment shares, parsed in exactly one place
/// (`repro`'s `print_usage` documents them once; experiment modules take
/// an `&ExpOptions` instead of re-declaring `fast`/`seed` parameters).
///
/// - `fast`: smaller scenario set / shorter horizons (CI smoke runs).
/// - `seed`: workload + fault-schedule override. Experiments that
///   ignore it are bit-identical with or without; `fleet` perturbs its
///   workload generators with it, `chaos` derives its fault schedule
///   from it and prints it so any failing cell can be replayed, `tier`
///   seeds its bursty trace.
/// - `trace_out` / `metrics_out`: telemetry export paths. Experiments
///   that run a serving simulator (`chaos`, `kvmigrate`) turn the
///   registry on and write a Chrome trace-event JSON / Prometheus
///   exposition of their *first* simulated run; the others ignore them.
#[derive(Debug, Clone, Default)]
pub struct ExpOptions {
    pub fast: bool,
    pub seed: Option<u64>,
    pub trace_out: Option<String>,
    pub metrics_out: Option<String>,
}

impl ExpOptions {
    /// Parse from a `repro exp` command line.
    pub fn from_args(args: &Args) -> Result<Self> {
        use anyhow::Context;
        let seed = match args.get("seed") {
            Some(v) => {
                Some(v.parse().context("--seed expects an integer")?)
            }
            None => None,
        };
        Ok(ExpOptions {
            fast: args.flag("fast"),
            seed,
            trace_out: args.get("trace-out").map(str::to_string),
            metrics_out: args.get("metrics-out").map(str::to_string),
        })
    }

    /// Fast/slow with no seed override.
    pub fn fast(fast: bool) -> Self {
        ExpOptions {
            fast,
            ..Default::default()
        }
    }

    /// The seed to use, falling back to an experiment's canonical one.
    pub fn seed_or(&self, default: u64) -> u64 {
        self.seed.unwrap_or(default)
    }

    /// Whether any telemetry export was requested.
    pub fn wants_obs(&self) -> bool {
        self.trace_out.is_some() || self.metrics_out.is_some()
    }

    /// Write the requested telemetry exports for a finished run.
    pub fn export_telemetry(
        &self,
        tel: Option<&crate::obs::Telemetry>,
    ) -> Result<()> {
        let Some(tel) = tel else {
            return Ok(());
        };
        if let Some(path) = &self.trace_out {
            crate::obs::export::write_trace(tel, path)?;
        }
        if let Some(path) = &self.metrics_out {
            crate::obs::export::write_metrics(tel, path)?;
        }
        Ok(())
    }
}

/// Method names in the paper's order.
pub const METHODS: &[&str] = &[
    "elastic",
    "cold",
    "extravagant",
    "colocated",
    "horizontal",
];

pub fn display_name(method: &str) -> &'static str {
    match method {
        "elastic" => "ElasticMoE",
        "cold" => "Vertical (Cold Restart)",
        "extravagant" => "Vertical (Extravagant)",
        "colocated" => "Vertical (Colocated)",
        "horizontal" => "Horizontal (Replica)",
        _ => "?",
    }
}

pub fn cluster(n: usize) -> Rc<RefCell<Cluster>> {
    Rc::new(RefCell::new(Cluster::cloudmatrix(n)))
}

/// Build a scaling method over a fresh cluster of `cluster_n` devices.
pub fn make_method(
    name: &str,
    m: &ModelConfig,
    cluster_n: usize,
) -> Result<Box<dyn ScalingMethod>> {
    let c = cluster(cluster_n);
    Ok(match name {
        "elastic" => Box::new(elastic_with_opts(
            m,
            cluster_n,
            HmmOptions::default(),
            ImmOptions::default(),
        )),
        "cold" => Box::new(ColdRestart::new(c, m.clone(), KV_BYTES)),
        "extravagant" => Box::new(Extravagant::new(c, m.clone(), KV_BYTES)),
        "colocated" => Box::new(Colocated::new(c, m.clone(), KV_BYTES)),
        "horizontal" => Box::new(Horizontal::new(c, m.clone(), KV_BYTES)),
        other => bail!("unknown method '{other}'"),
    })
}

/// ElasticMoE with explicit ablation options.
pub fn elastic_with_opts(
    m: &ModelConfig,
    cluster_n: usize,
    hmm_opts: HmmOptions,
    imm_opts: ImmOptions,
) -> ElasticMoE {
    let c = cluster(cluster_n);
    ElasticMoE::new(
        HmmControl::new(c, m.clone(), hmm_opts),
        InstanceManager::new(imm_opts, Timings::cloudmatrix()),
        KV_BYTES,
    )
}

/// Standard layout on devices `0..n` with the model's fixed TP.
pub fn par(m: &ModelConfig, n: usize) -> Result<ParallelConfig> {
    if n % m.tp != 0 {
        bail!("{n} devices not divisible by TP{}", m.tp);
    }
    Ok(ParallelConfig::standard(n / m.tp, m.tp, (0..n).collect())?)
}

/// Layout on an explicit device range (for fresh-device baselines).
pub fn par_on(
    m: &ModelConfig,
    devices: std::ops::Range<usize>,
) -> Result<ParallelConfig> {
    let v: Vec<usize> = devices.collect();
    if v.len() % m.tp != 0 {
        bail!("{} devices not divisible by TP{}", v.len(), m.tp);
    }
    Ok(ParallelConfig::standard(v.len() / m.tp, m.tp, v)?)
}

/// The three paper models.
pub fn paper_models() -> Vec<ModelConfig> {
    vec![model::dsv2_lite(), model::qwen30b(), model::dsv3()]
}

/// Scale-step schedule per model (§7.4): fixed 2-NPU steps for the small
/// models, progressively larger jumps for DSv3. DSv3's fixed TP=8
/// quantizes its steps to multiples of 8 (the paper's +2/+4 steps imply a
/// lower TP on their testbed; the *progressively larger jumps* shape is
/// preserved).
pub fn transitions(m: &ModelConfig) -> Vec<(usize, usize)> {
    match m.name {
        "dsv3" => vec![(32, 40), (32, 48), (32, 64)],
        _ => vec![(2, 4), (4, 6), (6, 8), (8, 10)],
    }
    .into_iter()
    .filter(|&(a, b)| {
        a >= m.min_devices && a % m.tp == 0 && b % m.tp == 0
    })
    .collect()
}
