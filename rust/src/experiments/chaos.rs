//! Chaos conformance experiment (beyond the paper's tables): drive the
//! full serving stack through scaling events under injected faults and
//! machine-check the trace invariants in every cell.
//!
//! The scenario matrix sweeps **method × scale direction × fault type**:
//! ElasticMoE (migrating handoff) under every fault, plus the
//! drain-and-recompute policy and the cold-restart baseline on the
//! fault-free cells, across a scale-up (DP4→DP6) and a scale-down
//! (DP4→DP3) with long-context traffic mid-stream at the command.
//! Fault types: none, P2P link failure mid-copy-leg, device loss, HBM
//! pressure (migration budget shrunk to zero), and a straggler device
//! stretching its fabric legs 4×.
//!
//! Every cell must satisfy the full invariant catalog
//! ([`crate::chaos::invariants`]): KV block conservation (including
//! across aborts), exactly-once finish with no token loss, migration
//! bytes within the effective budget, bounded intake pauses, and
//! exactly-once suspend disposition. Injected-fault cells must end in a
//! clean rollback — configuration unchanged, zero lost or
//! double-finished sequences — and an aborted scale-up must leave
//! throughput no worse than never having scaled (checked against a
//! never-scaled reference run on the identical trace). Any violation
//! aborts the experiment with the seed needed to replay it
//! (`repro exp chaos --seed N`).

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::chaos::{
    check_all, FaultInjector, FaultKind, FaultPlan, TraceEvent, Violation,
};
use crate::config::model::dsv2_lite;
use crate::config::SloConfig;
use crate::coordinator::{ServingSim, Trigger};
use crate::device::Timings;
use crate::engine::CostModel;
use crate::kvmigrate::{KvHandoffPolicy, KvHandoffStats};
use crate::scaling::{ColdRestart, ScalingMethod};
use crate::util::table::{f, Table};
use crate::workload::{RateProfile, Request, WorkloadGen, WorkloadSpec};

use super::common::{cluster, elastic_with_opts, par, KV_BYTES};

/// Default seed when `--seed` is not given.
pub const DEFAULT_SEED: u64 = 23;

const COMMAND_AT: f64 = 40.0;
const HORIZON: f64 = 160.0;
const PROMPT: usize = 5000;
/// Devices in every cell's simulated cluster (DP6 ceiling at TP2).
const CLUSTER: usize = 12;
/// Devices of the starting configuration (DP4).
const FROM_N: usize = 8;

fn cost() -> CostModel {
    CostModel::new(dsv2_lite(), Timings::cloudmatrix())
}

fn capacity(n: usize) -> f64 {
    cost().steady_throughput_rps(
        &par(&dsv2_lite(), n).unwrap(),
        64 << 30,
        PROMPT,
        200,
    )
}

fn workload(rps: f64, seed: u64) -> Vec<Request> {
    let mut g = WorkloadGen::new(WorkloadSpec {
        prompt_len: PROMPT,
        decode_min: 150,
        decode_max: 250,
        profile: RateProfile::Fixed(rps),
        seed,
    });
    g.arrivals_until(HORIZON)
}

/// Scale direction of one cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dir {
    /// DP4 → DP6 (8 → 12 devices).
    Up,
    /// DP4 → DP3 (8 → 6 devices).
    Down,
    /// Never scale (the throughput reference for aborted scale-ups).
    Hold,
}

impl Dir {
    fn label(self) -> &'static str {
        match self {
            Dir::Up => "up DP4→DP6",
            Dir::Down => "down DP4→DP3",
            Dir::Hold => "hold",
        }
    }

    fn to_n(self) -> usize {
        match self {
            Dir::Up => 12,
            Dir::Down => 6,
            Dir::Hold => FROM_N,
        }
    }

    /// ASCII cell-name fragment for report filenames and headings.
    fn slug(self) -> &'static str {
        match self {
            Dir::Up => "up",
            Dir::Down => "down",
            Dir::Hold => "hold",
        }
    }
}

/// Map a fault name to the concrete fault for this direction and seed.
/// The seed varies the failing leg / lost device so repeated runs probe
/// different abort points, all reproducible from the printed seed.
fn fault_kind(name: &str, dir: Dir, seed: u64) -> Option<FaultKind> {
    match name {
        "none" => None,
        "p2p-link" => Some(FaultKind::P2pLinkFail {
            after_legs: 1 + (seed % 7) as usize,
        }),
        "device-loss" => Some(FaultKind::DeviceLoss {
            dev: match dir {
                // A newcomer receiving weights vs a departing source.
                Dir::Up => 8 + (seed % 4) as usize,
                _ => 6 + (seed % 2) as usize,
            },
        }),
        "hbm-pressure" => Some(FaultKind::HbmPressure { budget_factor: 0.0 }),
        "straggler" => Some(FaultKind::Straggler {
            dev: if dir == Dir::Up { 8 } else { 6 },
            stretch: 4.0,
        }),
        other => panic!("unknown fault '{other}'"),
    }
}

/// One cell's measurements.
struct CellResult {
    method: &'static str,
    dir: Dir,
    fault: &'static str,
    arrived: usize,
    completed: usize,
    aborted: bool,
    rolled_back: bool,
    fault_fired: bool,
    violations: Vec<Violation>,
    end_time: f64,
    attainment: f64,
    scale_latency: f64,
    handoff: KvHandoffStats,
    devices_final: usize,
    state_hash: u64,
    telemetry: Option<crate::obs::Telemetry>,
}

/// Run one (method, direction, fault) cell on the seeded workload.
fn run_cell(
    method: &'static str,
    dir: Dir,
    fault_name: &'static str,
    seed: u64,
) -> Result<CellResult> {
    run_cell_obs(method, dir, fault_name, seed, false)
}

/// [`run_cell`] with the telemetry registry optionally enabled — the
/// determinism sweep flips it to prove the digest is unchanged, and
/// `--trace-out`/`--metrics-out` export from an obs cell.
fn run_cell_obs(
    method: &'static str,
    dir: Dir,
    fault_name: &'static str,
    seed: u64,
    obs: bool,
) -> Result<CellResult> {
    let (out, arrived) = run_cell_raw(method, dir, fault_name, seed, obs)?;
    let slo = report_slo();
    let violations = check_all(&out.trace);
    let ev = out.scaling_events.first();
    let w = out.recorder.window(0.0, out.end_time + 1.0, &slo);
    Ok(CellResult {
        method,
        dir,
        fault: fault_name,
        arrived,
        completed: out.recorder.count(),
        aborted: ev.map(|e| e.aborted.is_some()).unwrap_or(false),
        rolled_back: ev
            .and_then(|e| e.aborted.as_ref())
            .map(|a| a.rolled_back)
            .unwrap_or(false),
        fault_fired: out
            .trace
            .count(|e| matches!(e, TraceEvent::FaultFired { .. }))
            > 0,
        violations,
        end_time: out.end_time,
        attainment: w.slo_attainment,
        scale_latency: ev.map(|e| e.metrics.scale_latency).unwrap_or(0.0),
        handoff: out.handoff,
        devices_final: out
            .device_timeline
            .last()
            .map(|&(_, d)| d)
            .unwrap_or(0),
        state_hash: out.state_hash,
        telemetry: out.telemetry,
    })
}

/// The SLO every chaos cell is judged against (shared with
/// [`crate::report`], which re-derives attainment timelines from the
/// raw recorder).
pub fn report_slo() -> SloConfig {
    SloConfig::new(8.0, 1.5)
}

/// Run one cell and hand back the complete [`SimOutput`] — trace,
/// recorder, telemetry — instead of the summarized [`CellResult`].
/// `repro report` consumes this to price scaling events and render the
/// attainment timeline.
fn run_cell_raw(
    method: &'static str,
    dir: Dir,
    fault_name: &'static str,
    seed: u64,
    obs: bool,
) -> Result<(crate::coordinator::SimOutput, usize)> {
    let slo = report_slo();
    let mut sim = ServingSim::new(cost(), slo);
    sim.obs = obs;
    let fault = fault_kind(fault_name, dir, seed);
    let inj = Rc::new(RefCell::new(FaultInjector::new(match fault {
        Some(kind) => FaultPlan::single(0, kind),
        None => FaultPlan::none(),
    })));
    sim.injector = Some(inj.clone());

    let mut m: Box<dyn ScalingMethod> = match method {
        "elastic" | "elastic-drain" => {
            let mut e = elastic_with_opts(
                &dsv2_lite(),
                CLUSTER,
                Default::default(),
                Default::default(),
            );
            if method == "elastic-drain" {
                e.kv_policy = KvHandoffPolicy::DrainRecompute;
            }
            e.hmm.set_fault_injector(inj.clone());
            Box::new(e)
        }
        "cold" => Box::new(ColdRestart::new(
            cluster(CLUSTER),
            dsv2_lite(),
            KV_BYTES,
        )),
        other => bail!("unknown chaos method '{other}'"),
    };

    let rps = match dir {
        Dir::Down => capacity(6) * 0.45,
        _ => capacity(FROM_N) * 0.55,
    };
    let arrivals = workload(rps, seed);
    let arrived = arrivals.len();
    let trigger = match dir {
        Dir::Hold => Trigger::Manual(vec![]),
        _ => Trigger::Manual(vec![(
            COMMAND_AT,
            par(&dsv2_lite(), dir.to_n())?,
        )]),
    };
    let out = sim.run(
        m.as_mut(),
        &par(&dsv2_lite(), FROM_N)?,
        arrivals,
        trigger,
        HORIZON,
    )?;
    Ok((out, arrived))
}

/// One fully-instrumented chaos cell for `repro report`: the complete
/// run output (trace, recorder, device timeline, telemetry spans) plus
/// the invariant verdict. Telemetry is always on — the report's
/// concurrent-vs-switchover split reads the span timeline.
pub struct ReportCell {
    /// `method/direction/fault`, e.g. `elastic/up/p2p-link`.
    pub name: String,
    pub arrived: usize,
    pub out: crate::coordinator::SimOutput,
    pub violations: Vec<Violation>,
}

/// Run the chaos matrix with full instrumentation for `repro report`.
pub fn report_cells(seed: u64, fast: bool) -> Result<Vec<ReportCell>> {
    let mut cells = Vec::new();
    for (method, dir, fault) in matrix(fast) {
        let (out, arrived) = run_cell_raw(method, dir, fault, seed, true)?;
        let violations = check_all(&out.trace);
        cells.push(ReportCell {
            name: format!("{method}/{}/{fault}", dir.slug()),
            arrived,
            out,
            violations,
        });
    }
    Ok(cells)
}

/// One cell of [`conformance`]: the fields the determinism sweep
/// (`rust/tests/determinism.rs`) compares across seeds and re-runs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConformanceCell {
    pub method: &'static str,
    pub direction: &'static str,
    pub fault: &'static str,
    pub arrived: usize,
    pub completed: usize,
    pub aborted: bool,
    pub rolled_back: bool,
    /// Invariant violations found by [`check_all`] (must be zero).
    pub violations: usize,
    /// The run's [`crate::coordinator::SimOutput::state_hash`] — equal
    /// across same-seed re-runs.
    pub state_hash: u64,
}

/// Run the fast chaos matrix end to end for one seed and return every
/// cell's invariant/violation summary plus its run digest. Entry point
/// for the seed-sweep determinism suite.
pub fn conformance(seed: u64) -> Result<Vec<ConformanceCell>> {
    conformance_with_obs(seed, false)
}

/// [`conformance`] with the telemetry registry on or off: the
/// determinism suite runs each cell both ways and asserts the digests
/// are bit-identical (telemetry must be a pure observer).
pub fn conformance_with_obs(
    seed: u64,
    obs: bool,
) -> Result<Vec<ConformanceCell>> {
    let mut cells = Vec::new();
    for (method, dir, fault) in matrix(true) {
        let r = run_cell_obs(method, dir, fault, seed, obs)?;
        cells.push(ConformanceCell {
            method,
            direction: dir.label(),
            fault,
            arrived: r.arrived,
            completed: r.completed,
            aborted: r.aborted,
            rolled_back: r.rolled_back,
            violations: r.violations.len(),
            state_hash: r.state_hash,
        });
    }
    Ok(cells)
}

/// Per-cell acceptance: invariants hold, injected-fault cells roll back
/// cleanly to the origin configuration, fault-free and degraded cells
/// complete, and no cell loses or double-finishes a sequence.
fn assert_cell(r: &CellResult, seed: u64) -> Result<()> {
    let cell = format!("{} × {} × {}", r.method, r.dir.label(), r.fault);
    if !r.violations.is_empty() {
        bail!(
            "cell [{cell}] violated {} invariant(s) (replay with \
             `repro exp chaos --seed {seed}`): {}",
            r.violations.len(),
            r.violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("; ")
        );
    }
    if r.completed != r.arrived {
        bail!(
            "cell [{cell}]: {} of {} requests completed (seed {seed})",
            r.completed,
            r.arrived
        );
    }
    let should_abort = matches!(r.fault, "p2p-link" | "device-loss");
    if should_abort {
        if !(r.aborted && r.rolled_back && r.fault_fired) {
            bail!(
                "cell [{cell}]: injected fault must abort and roll back \
                 (aborted={}, rolled_back={}, fired={}, seed {seed})",
                r.aborted,
                r.rolled_back,
                r.fault_fired
            );
        }
        if r.devices_final != FROM_N {
            bail!(
                "cell [{cell}]: rollback must restore {FROM_N} devices, \
                 got {} (seed {seed})",
                r.devices_final
            );
        }
    } else {
        if r.aborted {
            bail!("cell [{cell}]: unexpected abort (seed {seed})");
        }
        if r.devices_final != r.dir.to_n() {
            bail!(
                "cell [{cell}]: expected {} devices after the event, got \
                 {} (seed {seed})",
                r.dir.to_n(),
                r.devices_final
            );
        }
    }
    Ok(())
}

/// All matrix cells for one seed. `fast` keeps a 3-cell core (fault-free
/// scale-up, aborted scale-up, aborted scale-down).
fn matrix(fast: bool) -> Vec<(&'static str, Dir, &'static str)> {
    if fast {
        return vec![
            ("elastic", Dir::Up, "none"),
            ("elastic", Dir::Up, "p2p-link"),
            ("elastic", Dir::Down, "device-loss"),
        ];
    }
    let mut cells = Vec::new();
    for dir in [Dir::Up, Dir::Down] {
        for fault in
            ["none", "p2p-link", "device-loss", "hbm-pressure", "straggler"]
        {
            cells.push(("elastic", dir, fault));
        }
        cells.push(("elastic-drain", dir, "none"));
        cells.push(("cold", dir, "none"));
    }
    cells
}

/// `repro exp chaos [--seed N]`.
pub fn run(opts: &super::common::ExpOptions) -> Result<String> {
    let fast = opts.fast;
    let seed = opts.seed_or(DEFAULT_SEED);
    // Never-scaled reference on the scale-up trace: the bound an aborted
    // scale-up must not fall below.
    let reference = run_cell("elastic", Dir::Hold, "none", seed)?;
    assert_cell(&reference, seed)?;

    let mut results = Vec::new();
    for (i, (method, dir, fault)) in matrix(fast).into_iter().enumerate() {
        // Telemetry exports come from the first cell (fault-free
        // elastic scale-up) when requested.
        let obs = i == 0 && opts.wants_obs();
        let r = run_cell_obs(method, dir, fault, seed, obs)?;
        if obs {
            opts.export_telemetry(r.telemetry.as_ref())?;
        }
        assert_cell(&r, seed)?;
        results.push(r);
    }

    // Cross-cell shape assertions.
    let find = |method: &str, dir: Dir, fault: &str| {
        results.iter().find(|r| {
            r.method == method && r.dir == dir && r.fault == fault
        })
    };
    if let Some(ab) = find("elastic", Dir::Up, "p2p-link") {
        // ISSUE acceptance: an aborted scale-up leaves throughput no
        // worse than never having scaled (same trace, same seed; the
        // only extra cost is the brief rollback barrier).
        if ab.end_time > reference.end_time + 5.0 {
            bail!(
                "aborted scale-up drained at {:.1}s vs {:.1}s never-scaled \
                 (seed {seed})",
                ab.end_time,
                reference.end_time
            );
        }
        if ab.attainment < reference.attainment - 0.05 {
            bail!(
                "aborted scale-up attainment {:.3} fell below the \
                 never-scaled {:.3} (seed {seed})",
                ab.attainment,
                reference.attainment
            );
        }
    }
    if let (Some(st), Some(none)) = (
        find("elastic", Dir::Up, "straggler"),
        find("elastic", Dir::Up, "none"),
    ) {
        if st.scale_latency <= none.scale_latency {
            bail!(
                "straggler must stretch the event: {:.3}s vs {:.3}s \
                 (seed {seed})",
                st.scale_latency,
                none.scale_latency
            );
        }
    }
    if let Some(pr) = find("elastic", Dir::Down, "hbm-pressure") {
        if pr.handoff.copied != 0 || pr.handoff.recomputed == 0 {
            bail!(
                "zero-budget pressure must force recompute-only handoff \
                 (copied {}, recomputed {}, seed {seed})",
                pr.handoff.copied,
                pr.handoff.recomputed
            );
        }
    }

    let mut table = Table::new(
        "Chaos conformance: method × direction × fault, all trace \
         invariants checked per cell (DSv2-Lite, command at t=40)",
    )
    .header([
        "method",
        "direction",
        "fault",
        "outcome",
        "done",
        "remap",
        "copy",
        "recomp",
        "SLO%",
        "violations",
    ]);
    for r in std::iter::once(&reference).chain(results.iter()) {
        table.row([
            r.method.to_string(),
            r.dir.label().to_string(),
            r.fault.to_string(),
            if r.aborted {
                "aborted+rolled-back".to_string()
            } else {
                "completed".to_string()
            },
            format!("{}/{}", r.completed, r.arrived),
            r.handoff.remapped.to_string(),
            r.handoff.copied.to_string(),
            r.handoff.recomputed.to_string(),
            f(r.attainment * 100.0, 1),
            r.violations.len().to_string(),
        ]);
    }
    let mut out = table.render();
    out.push_str(&format!(
        "\nseed {seed} — every cell above passed block conservation, \
         exactly-once finish, byte budget and bounded intake pause; \
         injected-fault cells rolled back with zero lost sequences. \
         Replay any cell with `repro exp chaos --seed {seed}`.\n"
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ISSUE acceptance: an aborted scale-up (P2P link failure mid-plan)
    /// rolls back cleanly and leaves throughput no worse than never
    /// having scaled, with every trace invariant intact.
    #[test]
    fn aborted_scale_up_is_no_worse_than_never_scaling() {
        let reference =
            run_cell("elastic", Dir::Hold, "none", DEFAULT_SEED).unwrap();
        let aborted =
            run_cell("elastic", Dir::Up, "p2p-link", DEFAULT_SEED).unwrap();
        assert!(aborted.aborted && aborted.rolled_back);
        assert!(aborted.fault_fired);
        assert!(
            aborted.violations.is_empty(),
            "{:?}",
            aborted.violations
        );
        assert_eq!(aborted.completed, aborted.arrived);
        assert_eq!(reference.completed, aborted.completed);
        assert_eq!(aborted.devices_final, FROM_N, "config restored");
        assert!(
            aborted.end_time <= reference.end_time + 5.0,
            "aborted {:.2}s vs never-scaled {:.2}s",
            aborted.end_time,
            reference.end_time
        );
        assert!(
            aborted.attainment >= reference.attainment - 0.05,
            "aborted {:.3} vs never-scaled {:.3}",
            aborted.attainment,
            reference.attainment
        );
    }

    /// Device loss during a scale-down aborts after the departing shard
    /// was already released — the deepest rollback — and the trace stays
    /// conformant.
    #[test]
    fn device_loss_scale_down_rolls_back_cleanly() {
        let r =
            run_cell("elastic", Dir::Down, "device-loss", 7).unwrap();
        assert!(r.aborted && r.rolled_back && r.fault_fired);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.completed, r.arrived);
        assert_eq!(r.devices_final, FROM_N);
    }

    /// An HBM pressure spike (budget → 0) degrades instead of aborting:
    /// the event completes, movers fall back to recompute, and the
    /// byte-budget invariant holds at zero copies.
    #[test]
    fn hbm_pressure_forces_recompute_within_budget() {
        let r =
            run_cell("elastic", Dir::Down, "hbm-pressure", DEFAULT_SEED)
                .unwrap();
        assert!(!r.aborted);
        assert!(r.fault_fired, "pressure must be recorded");
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.devices_final, 6);
        assert_eq!(r.handoff.copied, 0, "zero budget admits no copies");
        assert!(r.handoff.recomputed > 0, "movers must re-prefill");
        // The unshrunk run on the same trace copies its movers instead.
        let ok = run_cell("elastic", Dir::Down, "none", DEFAULT_SEED)
            .unwrap();
        assert!(ok.handoff.copied > 0, "budget restores the copy path");
    }
}
